"""Minitron-8B [arXiv:2407.14679; hf:nvidia/Minitron-8B-Base].

Pruned Nemotron-4: 32L, d_model=4096, 32 heads (GQA kv=8, head_dim=128),
squared-ReLU MLP d_ff=16384, vocab 256000, full attention, untied embeddings.
"""
from repro.configs.base import BLOCK_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    ffn_type="sq_relu",
    pattern=(BLOCK_ATTN,),
)
