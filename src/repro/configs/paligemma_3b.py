"""PaliGemma-3B [arXiv:2407.07726; hf:google/paligemma-3b-pt-224].

SigLIP vision tower (STUB: precomputed patch embeddings, 256 patches) +
Gemma-2B text backbone: 18L, d_model=2048, 8 heads (MQA kv=1,
head_dim=256), GeGLU d_ff=16384, vocab 257216, prefix-LM masking
(bidirectional over image prefix, causal over text).
"""
from repro.configs.base import BLOCK_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    ffn_type="geglu",
    pattern=(BLOCK_ATTN,),
    frontend="image_patches",
    n_prefix=256,
    tie_embeddings=True,
    embed_scale=True,
)
