"""OLMoE-1B-7B [arXiv:2409.02060; hf:allenai/OLMoE-1B-7B-0924].

16L, d_model=2048, 16 heads (kv=16, i.e. MHA, head_dim=128), MoE with 64
experts top-8 (d_ff_expert=1024, SwiGLU), vocab 50304, full attention,
QK-norm.
"""
from repro.configs.base import BLOCK_ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    ffn_type="swiglu",
    pattern=(BLOCK_ATTN,),
    qk_norm=True,
    moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024),
)
