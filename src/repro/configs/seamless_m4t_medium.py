"""SeamlessM4T-medium backbone [arXiv:2308.11596; hf:facebook/seamless-m4t-medium].

Encoder-decoder transformer BACKBONE only: 12 encoder + 12 decoder layers,
d_model=1024, 16 heads (MHA kv=16, head_dim=64), GELU d_ff=4096 (paper's FFN
dim 4096 applies to the text stack), vocab 256206.  The speech frontend
(w2v-BERT conformer feature extractor) is a STUB per the brief:
``input_specs`` supplies precomputed frame embeddings (B, T_frames, d_model).
"""
from repro.configs.base import BLOCK_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,                # decoder layers
    n_enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    ffn_type="gelu",
    pattern=(BLOCK_ATTN,),
    frontend="audio_frames",
    tie_embeddings=True,
)
