"""StarCoder2-3B [arXiv:2402.19173; hf:bigcode/starcoder2-3b].

30L, d_model=3072, 24 heads (GQA kv=2, head_dim=128), GELU MLP d_ff=12288,
vocab 49152, RoPE, sliding-window attention (4096).
"""
from repro.configs.base import BLOCK_LOCAL, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    ffn_type="gelu",
    pattern=(BLOCK_LOCAL,),
    window=4096,
    rope_theta=1e5,
    tie_embeddings=True,
)
