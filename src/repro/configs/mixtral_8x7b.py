"""Mixtral 8x7B [arXiv:2401.04088; hf:mistralai/Mixtral-8x7B-v0.1].

32L, d_model=4096, 32 heads (GQA kv=8, head_dim=128), SwiGLU MoE with 8
experts top-2 (d_ff_expert=14336), vocab 32000, sliding-window attention
(window 4096), rope_theta=1e6.
"""
from repro.configs.base import BLOCK_LOCAL, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    ffn_type="swiglu",
    pattern=(BLOCK_LOCAL,),
    window=4096,
    rope_theta=1e6,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=14336),
)
