"""Gemma3-27B [hf:google/gemma-3-27b-pt; unverified tier].

62L, d_model=5376, 32 heads (GQA kv=16, head_dim=128), GeGLU d_ff=21504,
vocab 262144, hybrid 5 local (window 1024) : 1 global attention, QK-norm,
gemma embedding scaling, 128k context (500k decode exercised via
seq-sharded global-layer caches).
"""
from repro.configs.base import BLOCK_GLOBAL, BLOCK_LOCAL, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    ffn_type="geglu",
    pattern=(BLOCK_LOCAL,) * 5 + (BLOCK_GLOBAL,),
    window=1024,
    rope_theta=1e6,
    qk_norm=True,
    tie_embeddings=True,
    embed_scale=True,
)
