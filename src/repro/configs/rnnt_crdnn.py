"""The paper's own architecture: SpeechBrain Librispeech RNN-T recipe
[Ravanelli et al. 2021; Graves 2012].

CRDNN encoder (2 CNN blocks, 4 bi-LSTM layers of 512/dir, 2 DNN layers to
1024) + prediction network (256-d embedding, 1-layer GRU 512) + joint
network (single linear fusing 1024-d representations into 1000 BPE units).
PGM selects subsets using the joint-network gradient (paper §2, §5).
"""
from repro.configs.base import ModelConfig, RNNTConfig

CONFIG = ModelConfig(
    name="rnnt-crdnn",
    family="rnnt",
    n_layers=4,                  # bi-LSTM layers (descriptive; see RNNTConfig)
    d_model=1024,
    n_heads=1,
    n_kv_heads=1,
    head_dim=1024,
    d_ff=1024,
    vocab_size=1000,
    rnnt=RNNTConfig(),
)
