"""Config registry: ``get_config(name)`` / ``list_archs()`` / shape lookup."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401 (public re-exports)
    LONG_500K,
    DECODE_32K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    ModelConfig,
    MoEConfig,
    PGMConfig,
    RNNTConfig,
    ShapeConfig,
    TrainConfig,
    reduce_for_smoke,
)

_ARCH_MODULES: Dict[str, str] = {
    "mixtral-8x7b": "mixtral_8x7b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "minitron-8b": "minitron_8b",
    "starcoder2-3b": "starcoder2_3b",
    "gemma3-27b": "gemma3_27b",
    "gemma-7b": "gemma_7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "rwkv6-3b": "rwkv6_3b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "paligemma-3b": "paligemma_3b",
    "rnnt-crdnn": "rnnt_crdnn",
}

ASSIGNED_ARCHS: List[str] = [a for a in _ARCH_MODULES if a != "rnnt-crdnn"]


def get_config(name: str) -> ModelConfig:
    smoke = name.endswith("-smoke")
    base = name[: -len("-smoke")] if smoke else name
    if base not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[base]}")
    cfg: ModelConfig = mod.CONFIG
    return reduce_for_smoke(cfg) if smoke else cfg


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def list_archs(include_paper: bool = True) -> List[str]:
    return list(_ARCH_MODULES) if include_paper else list(ASSIGNED_ARCHS)


def cells(include_skips: bool = False):
    """Yield every (arch, shape) dry-run cell.  ``long_500k`` is skipped for
    pure full-attention archs (DESIGN.md §4) unless include_skips."""
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.is_subquadratic():
                if include_skips:
                    yield arch, shape.name, "skip"
                continue
            yield (arch, shape.name, "run") if include_skips else (arch, shape.name)
