"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427; unverified tier].

38L, d_model=4096, 16 heads (MQA kv=1, head_dim=256), GeGLU d_ff=12288,
vocab 256000, hybrid RG-LRU : local attention at 2:1 (pattern
(rec, rec, attn) repeating; window 2048), lru_width=4096, temporal conv
width 4.
"""
from repro.configs.base import BLOCK_LOCAL, BLOCK_REC, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    ffn_type="geglu",
    pattern=(BLOCK_REC, BLOCK_REC, BLOCK_LOCAL),
    window=2048,
    lru_width=4096,
    conv_width=4,
    tie_embeddings=True,
    embed_scale=True,
)
