"""Gemma-7B [arXiv:2403.08295; hf:google/gemma-7b].

28L, d_model=3072, 16 heads (kv=16, head_dim=256 -> q_dim 4096 != d_model),
GeGLU d_ff=24576, vocab 256000, full attention, tied embeddings with
sqrt(d_model) embedding scaling.
"""
from repro.configs.base import BLOCK_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    ffn_type="geglu",
    pattern=(BLOCK_ATTN,),
    tie_embeddings=True,
    embed_scale=True,
)
