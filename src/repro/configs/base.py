"""Config dataclasses for the repro framework.

Every assigned architecture is described by a :class:`ModelConfig`; input-shape
cells by :class:`ShapeConfig`.  Configs are plain frozen dataclasses so they are
hashable (usable as jit static args) and trivially serializable for checkpoint
manifests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer-stack patterns
# ---------------------------------------------------------------------------
# A homogeneous decoder stack is described by ``pattern=("attn",)``.
# Hybrid stacks repeat a group, e.g. gemma3 = ("local","local","local","local",
# "local","global") and recurrentgemma = ("rec","rec","attn").  The stack is
# ``pattern * (n_layers // len(pattern))`` followed by
# ``pattern[:n_layers % len(pattern)]``.

BLOCK_ATTN = "attn"          # full causal attention
BLOCK_LOCAL = "local"        # sliding-window attention
BLOCK_GLOBAL = "global"      # full attention inside a hybrid stack
BLOCK_REC = "rec"            # RG-LRU recurrent block (Griffin)
BLOCK_RWKV = "rwkv"          # RWKV6 time-mix block


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.  One instance per assigned architecture."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm | rnnt
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    ffn_type: str = "swiglu"         # swiglu | geglu | gelu | sq_relu
    pattern: Tuple[str, ...] = (BLOCK_ATTN,)
    window: int = 0                  # sliding-window size for local blocks (0 = none)
    rope_theta: float = 10000.0
    qk_norm: bool = False
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma-style sqrt(d_model) embedding scaling
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    # --- hybrid (RG-LRU) extras ---
    lru_width: int = 0
    conv_width: int = 4
    # --- rwkv extras ---
    rwkv_head_dim: int = 64
    # --- encoder-decoder extras ---
    n_enc_layers: int = 0
    # --- modality frontend stubs (audio / vlm) ---
    frontend: str = "none"           # none | audio_frames | image_patches
    n_prefix: int = 0                # number of frontend positions (e.g. patches)
    # --- rnnt extras (paper's own arch) ---
    rnnt: Optional["RNNTConfig"] = None
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        reps = self.n_layers // len(self.pattern)
        rem = self.n_layers % len(self.pattern)
        return tuple(self.pattern) * reps + tuple(self.pattern[:rem])

    def is_subquadratic(self) -> bool:
        """True when the arch can serve 500k-token contexts without an
        unbounded full-attention KV cache in every layer (see DESIGN.md §4)."""
        kinds = set(self.layer_kinds())
        if kinds <= {BLOCK_REC, BLOCK_RWKV, BLOCK_LOCAL}:
            return True
        # hybrid local:global (gemma3): bounded local caches + few seq-sharded
        # global layers -> runnable
        if BLOCK_GLOBAL in kinds and BLOCK_LOCAL in kinds:
            return True
        if kinds & {BLOCK_REC, BLOCK_RWKV}:
            return True
        return False

    def n_params(self) -> int:
        """Analytic parameter count (embedding + stack + head)."""
        if self.rnnt is not None:
            return self.rnnt.n_params()
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        embed = V * d * (1 if self.tie_embeddings else 2)
        n = embed
        for kind in self.layer_kinds():
            if kind in (BLOCK_ATTN, BLOCK_LOCAL, BLOCK_GLOBAL):
                attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                n += attn
            elif kind == BLOCK_REC:
                w = self.lru_width or d
                # rg-lru block: in/out proj + conv + gates
                n += 2 * d * w + self.conv_width * w + 3 * w
            elif kind == BLOCK_RWKV:
                # r,k,v,g,o projections + decay lora + token-shift mus
                n += 5 * d * d + 2 * d * 96 + 6 * d
            # ffn (moe or dense) attaches to attn/local/global/rwkv blocks;
            # rec blocks in griffin also carry an MLP
            if self.moe is not None and kind != BLOCK_REC:
                e = self.moe
                n += e.n_experts * 3 * d * e.d_ff_expert + d * e.n_experts
            else:
                mult = 3 if self.ffn_type in ("swiglu", "geglu") else 2
                n += mult * d * ff
        if self.n_enc_layers:
            for _ in range(self.n_enc_layers):
                attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                mult = 3 if self.ffn_type in ("swiglu", "geglu") else 2
                n += attn + mult * d * ff
                # cross attention in decoder accounted approximately here
                n += attn
        return n

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.n_params()
        e = self.moe
        total = self.n_params()
        expert_params = (
            len(self.layer_kinds()) * e.n_experts * 3 * self.d_model * e.d_ff_expert
        )
        active = (
            len(self.layer_kinds()) * e.top_k * 3 * self.d_model * e.d_ff_expert
        )
        return total - expert_params + active


@dataclass(frozen=True)
class RNNTConfig:
    """Paper's own architecture: SpeechBrain Librispeech transducer recipe.

    CRDNN encoder (2 CNN blocks -> 4 bi-LSTM layers -> 2 DNN layers),
    prediction net (embedding + 1-layer GRU), joint = single linear
    projecting 1024-d fused representation to 1000 BPE vocab.
    """

    n_feats: int = 80
    cnn_channels: Tuple[int, int] = (64, 128)
    lstm_layers: int = 4
    lstm_hidden: int = 512           # per direction
    dnn_dim: int = 1024
    pred_embed: int = 256
    pred_hidden: int = 512
    joint_dim: int = 1024
    vocab_size: int = 1000           # BPE units + blank
    time_reduction: int = 4          # cnn striding
    # transducer-loss path (DESIGN.md §2): "fused" = custom_vjp
    # alpha/beta lattice with a vocab-streamed joint (never materializes
    # the (B,T,U+1,V) tensor); "dense" = the autodiff parity oracle
    loss_impl: str = "fused"
    # vocab-chunk size for the fused loss's streamed logsumexp/backward
    # (<= 0: one chunk of the full vocab — right for smoke vocabs; set
    # to e.g. 512 when V is large enough that a (B,U+1,V) row dominates)
    loss_vocab_chunk: int = 0

    def n_params(self) -> int:
        n = 0
        c_in = 1
        for c in self.cnn_channels:
            n += c_in * c * 9 + c
            c_in = c
        feat = self.cnn_channels[-1] * (self.n_feats // 4)
        d_in = feat
        for _ in range(self.lstm_layers):
            n += 2 * 4 * (d_in * self.lstm_hidden + self.lstm_hidden ** 2
                          + self.lstm_hidden)
            d_in = 2 * self.lstm_hidden
        n += d_in * self.dnn_dim + self.dnn_dim * self.dnn_dim
        n += self.vocab_size * self.pred_embed
        n += 3 * (self.pred_embed * self.pred_hidden + self.pred_hidden ** 2)
        n += (self.dnn_dim + self.pred_hidden) * self.joint_dim
        n += self.joint_dim * self.vocab_size
        return n


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclass(frozen=True)
class PGMConfig:
    """Paper hyper-parameters (§5): selection interval R, partitions D,
    warm-start epochs, subset fraction, OMP regularization/tolerance."""

    subset_fraction: float = 0.3
    n_partitions: int = 8            # D; paper: 7 (100H) / 50 (960H)
    select_every: int = 5            # R
    warm_start_epochs: int = 2
    val_matching: bool = False       # 'Val' flag (noisy/robust mode)
    lam: float = 0.5                 # l2 reg on weights (lambda)
    eps: float = 1e-10               # OMP stopping tolerance
    sketch_dim_h: int = 64           # tensor-JL sketch dims (beyond-paper)
    sketch_dim_v: int = 64
    use_sketch: bool = True          # False -> paper-faithful exact gradients
    nonneg_weights: bool = True      # clip OMP weights at 0 (GradMatch impl.)
    # sparse-expert (MoE) selection gradients (DESIGN.md §8): append the
    # per-unit router-weight gradient (task + load-balance aux) to the
    # last-layer head representation.  Opt-in — it costs one autodiff
    # backward per unit vs the closed-form head path; default False is
    # the paper-faithful last-layer-only definition.  Ignored for
    # non-MoE families.
    moe_router_term: bool = False
    # selection-round kernel backend (kernels/backend.py): "auto" uses
    # the fused Pallas grad-sketch + Gram kernels on TPU and the XLA
    # streamed paths elsewhere; "pallas"/"xla" force one side ("pallas"
    # off-TPU runs the interpreter — parity/debug only, it is slow).
    kernel_impl: str = "auto"


@dataclass(frozen=True)
class TrainConfig:
    batch_size: int = 8              # global batch for SGD
    lr: float = 1.0
    optimizer: str = "sgd"           # sgd | adamw
    momentum: float = 0.0
    weight_decay: float = 0.0
    grad_clip: float = 5.0
    epochs: int = 30
    # newbob (paper's scheduler): anneal lr by `anneal_factor` when relative
    # validation-loss improvement < `improvement_threshold`
    anneal_factor: float = 0.8
    improvement_threshold: float = 0.0025
    seed: int = 0
    # cross-pod gradient compression (DESIGN.md §5): when the training
    # mesh carries a `pod_axis` axis, the scanned engine computes per-pod
    # gradients and runs an explicit `train/compress.py:compressed_psum`
    # over it inside the epoch scan — "none" keeps that collective dense
    # fp32, "bf16" halves its wire width, "topk" sends the k largest
    # entries per leaf with error feedback carried in the scan state
    compress_mode: str = "none"      # none | bf16 | topk
    compress_k_frac: float = 0.05    # top-k fraction per gradient leaf
    pod_axis: str = "pod"            # mesh axis name of the slow pod axis
    # fault tolerance (DESIGN.md §10): with `nonfinite_guard` the step
    # checks loss/grads for NaN/Inf *inside* the jitted epoch scan and
    # gates a non-finite step into a bit-exact no-op (optim.gate_step,
    # the same select that implements weight-0 padding rows) — no host
    # sync, no retrace; skipped-step counts ride the donated carry.
    # `max_skipped_steps` arms the host-side divergence watchdog: K
    # consecutive skipped steps (or a non-finite train/val loss) roll
    # the run back to the newest intact checkpoint with re-keyed batch
    # plans.  0 disables the consecutive-skip trigger.
    nonfinite_guard: bool = False
    max_skipped_steps: int = 0
    pgm: PGMConfig = field(default_factory=PGMConfig)


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant used by CPU smoke tests: few layers, small
    widths/vocab/experts so one forward+train step runs in seconds."""
    kw = dict(
        n_layers=min(cfg.n_layers, 2 * max(1, len(cfg.pattern))),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        head_dim=16,
        d_ff=128,
        vocab_size=277,
        window=min(cfg.window, 16) if cfg.window else 0,
        lru_width=64 if cfg.lru_width else 0,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        n_prefix=8 if cfg.n_prefix else 0,
        compute_dtype="float32",
    )
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(
            n_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff_expert=32
        )
    if cfg.rnnt is not None:
        kw["rnnt"] = RNNTConfig(
            n_feats=8, cnn_channels=(4, 8), lstm_layers=1, lstm_hidden=16,
            dnn_dim=32, pred_embed=16, pred_hidden=16, joint_dim=32,
            vocab_size=37,
        )
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **kw)
