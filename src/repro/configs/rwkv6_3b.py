"""RWKV6-3B "Finch" [arXiv:2404.05892; hf:RWKV/rwkv-6-world-3b].

32L, d_model=2560 (attention-free; 40 WKV heads of 64), channel-mix
d_ff=8960, vocab 65536, data-dependent decay (ddlerp token-shift + decay
LoRA).
"""
from repro.configs.base import BLOCK_RWKV, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,                 # 2560 / rwkv_head_dim
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    ffn_type="sq_relu",         # rwkv channel-mix uses squared relu
    pattern=(BLOCK_RWKV,),
    rwkv_head_dim=64,
)
