"""Top-k Mixture-of-Experts with GShard-style capacity dispatch.

Tokens are grouped (``group_size``); per group, each expert accepts up to
``capacity = ceil(cf * group * top_k / E)`` tokens.  Dispatch/combine are
one-hot einsums so expert parallelism lowers to an explicit all-to-all in
the compiled HLO (visible to the roofline collective parser).

Router: full softmax -> top-k -> renormalize (Mixtral style).  Load-balance
auxiliary loss per Switch Transformer [arXiv:2101.03961].
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import IDENTITY_SHARDER, Sharder, dense_init, ffn_act, split
from repro.models.ffn import is_gated

DEFAULT_GROUP = 2048


def init_moe_params(key, d_model: int, moe_cfg, ffn_type: str) -> Dict:
    E, dff = moe_cfg.n_experts, moe_cfg.d_ff_expert
    ks = split(key, 4)
    p = {
        "router": dense_init(ks[0], d_model, E),
        "w_in": jax.vmap(lambda k: dense_init(k, d_model, dff))(
            jax.random.split(ks[1], E)),
        "w_out": jax.vmap(lambda k: dense_init(k, dff, d_model))(
            jax.random.split(ks[2], E)),
    }
    if is_gated(ffn_type):
        p["w_gate"] = jax.vmap(lambda k: dense_init(k, d_model, dff))(
            jax.random.split(ks[3], E))
    return p


def _topk_dispatch(gates: jax.Array, top_k: int, capacity: int):
    """gates: (G, S, E) softmax probs.  Returns dispatch (G,S,E,C) bf16-able
    mask and combine (G,S,E,C) weights, plus load-balance aux loss.

    Capacity overflow is drop-and-renormalize, deterministically: position
    bookkeeping runs in int32 — a float cumsum in ``gates.dtype`` loses
    integer exactness past 256 tokens under bf16, silently multi-filling
    capacity slots and skewing the gate mean — and a token whose slot
    overflows is dropped from that expert while its combine weights
    renormalize over the experts that kept it (weights in fp32, cast back
    at the end)."""
    G, S, E = gates.shape
    # top-k selection, iteratively to keep position bookkeeping exact
    remaining = gates.astype(jnp.float32)
    counts = jnp.zeros((G, E), jnp.int32)
    dispatch = jnp.zeros((G, S, E, capacity), gates.dtype)
    combine = jnp.zeros((G, S, E, capacity), jnp.float32)
    topk_sum = jnp.zeros((G, S), jnp.float32)
    for _ in range(top_k):
        idx = jnp.argmax(remaining, axis=-1)                    # (G,S)
        w = jnp.take_along_axis(remaining, idx[..., None], -1)[..., 0]
        onehot_i = jax.nn.one_hot(idx, E, dtype=jnp.int32)      # (G,S,E)
        pos = counts[:, None, :] + jnp.cumsum(onehot_i, axis=1) - 1
        pos_in_e = jnp.sum(pos * onehot_i, axis=-1)             # (G,S)
        keep = pos_in_e < capacity
        # one_hot of the out-of-range index `capacity` is an all-zero row:
        # dropped tokens contribute to no slot
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos_in_e, capacity),
                                capacity, dtype=gates.dtype)    # (G,S,C)
        d = onehot_i.astype(gates.dtype)[..., None] * pos_oh[:, :, None, :]
        dispatch = dispatch + d
        combine = combine + d.astype(jnp.float32) * w[..., None, None]
        topk_sum = topk_sum + w * keep.astype(jnp.float32)
        counts = counts + jnp.sum(onehot_i * keep[..., None].astype(jnp.int32),
                                  axis=1)
        remaining = remaining * (1.0 - onehot_i)
    # renormalize combine weights over the *kept* expert assignments
    combine = combine / jnp.maximum(topk_sum, 1e-9)[..., None, None]
    return dispatch, combine.astype(gates.dtype)


def moe_forward(
    params, cfg, x: jax.Array, shard: Sharder = IDENTITY_SHARDER,
    group_size: int = DEFAULT_GROUP, decode: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,d) -> (out (B,S,d), aux_loss scalar).  ``decode`` uses a
    no-drop capacity (= group size) so single-token steps match training
    routing exactly."""
    moe = cfg.moe
    B, S, d = x.shape
    dt = x.dtype
    tokens = B * S
    g = min(group_size, tokens)
    n_groups = tokens // g
    assert n_groups * g == tokens, (tokens, g)
    xg = x.reshape(n_groups, g, d)

    logits = (xg @ params["router"].astype(dt)).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)                     # (G,S,E)
    capacity = (g if decode else
                max(1, int(moe.capacity_factor * g * moe.top_k / moe.n_experts)))
    dispatch, combine = _topk_dispatch(gates.astype(dt), moe.top_k, capacity)
    dispatch = shard(dispatch, "moe_dispatch")

    # (G,S,E,C),(G,S,d) -> (E,G,C,d): the all-to-all boundary under EP
    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch, xg)
    expert_in = shard(expert_in, "moe_expert_in")
    h = jnp.einsum("egcd,edf->egcf", expert_in, params["w_in"].astype(dt))
    act = ffn_act(cfg.ffn_type)
    if "w_gate" in params:
        gt = jnp.einsum("egcd,edf->egcf", expert_in, params["w_gate"].astype(dt))
        h = act(gt) * h
    else:
        h = act(h)
    out_e = jnp.einsum("egcf,efd->egcd", h, params["w_out"].astype(dt))
    out_e = shard(out_e, "moe_expert_out")
    out = jnp.einsum("gsec,egcd->gsd", combine, out_e)

    # Switch-style load balancing aux loss
    density = jnp.mean(dispatch.sum(-1), axis=1)                # (G,E) frac routed
    router_prob = jnp.mean(gates, axis=1)                       # (G,E)
    aux = moe.n_experts * jnp.mean(
        jnp.sum(density.astype(jnp.float32) * router_prob, axis=-1))
    return out.reshape(B, S, d), aux * moe.router_aux_coef
