"""The paper's own architecture: CRDNN RNN-Transducer (SpeechBrain
Librispeech transducer recipe; Graves 2012, Ravanelli et al. 2021).

Transcription network: 2 CNN blocks (3x3, stride 2x2) -> 4 bi-LSTM layers
-> 2 DNN layers.  Prediction network: embedding + 1-layer GRU.  Joint
network: Linear(enc) + Linear(pred) -> tanh -> Linear to vocab (the layer
whose gradient PGM matches).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, embed_init, split


# ---------------------------------------------------------------------------
# Recurrent cells (lax.scan)
# ---------------------------------------------------------------------------

def init_lstm(key, d_in, d_h):
    ks = split(key, 2)
    return {"wx": dense_init(ks[0], d_in, 4 * d_h),
            "wh": dense_init(ks[1], d_h, 4 * d_h),
            "b": jnp.zeros((4 * d_h,))}


def lstm_scan(p, x, reverse=False):
    """x: (B,T,d_in) -> (B,T,d_h)."""
    B, T, _ = x.shape
    d_h = p["wh"].shape[0]
    xw = x @ p["wx"].astype(x.dtype) + p["b"].astype(x.dtype)

    def step(carry, xt):
        h, c = carry
        gates = xt + h @ p["wh"].astype(xt.dtype)
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    h0 = jnp.zeros((B, d_h), x.dtype)
    _, hs = jax.lax.scan(step, (h0, h0), jnp.moveaxis(xw, 1, 0),
                         reverse=reverse)
    return jnp.moveaxis(hs, 0, 1)


def init_gru(key, d_in, d_h):
    ks = split(key, 2)
    return {"wx": dense_init(ks[0], d_in, 3 * d_h),
            "wh": dense_init(ks[1], d_h, 3 * d_h),
            "b": jnp.zeros((3 * d_h,))}


def gru_scan(p, x, h0=None):
    B, T, _ = x.shape
    d_h = p["wh"].shape[0]
    xw = x @ p["wx"].astype(x.dtype) + p["b"].astype(x.dtype)

    def step(h, xt):
        xr, xz, xn = jnp.split(xt, 3, axis=-1)
        hr, hz, hn = jnp.split(h @ p["wh"].astype(xt.dtype), 3, axis=-1)
        r = jax.nn.sigmoid(xr + hr)
        z = jax.nn.sigmoid(xz + hz)
        n = jnp.tanh(xn + r * hn)
        h = (1 - z) * n + z * h
        return h, h

    if h0 is None:
        h0 = jnp.zeros((B, d_h), x.dtype)
    h_last, hs = jax.lax.scan(step, h0, jnp.moveaxis(xw, 1, 0))
    return jnp.moveaxis(hs, 0, 1), h_last


def gru_step(p, x_t, h):
    """Single GRU step for greedy transducer decoding. x_t: (B,d_in)."""
    y, h_new = gru_scan(p, x_t[:, None], h0=h)
    return y[:, 0], h_new


#: Transducer blank symbol.  Training reserves id 0 for blank/pad
#: everywhere (``data/synthetic.py`` samples labels from ``[1, V)``;
#: ``core/rnnt_loss.py`` scores the blank arc on column 0), so decoding
#: uses the same convention.
BLANK_ID = 0


# ---------------------------------------------------------------------------
# RNN-T model
# ---------------------------------------------------------------------------

def init_params(cfg, key) -> Dict:
    r = cfg.rnnt
    ks = split(key, 16)
    p: Dict = {}
    c_in = 1
    for i, c in enumerate(r.cnn_channels):
        std = 1.0 / jnp.sqrt(9.0 * c_in)
        p[f"conv{i}"] = {
            "w": jax.random.normal(ks[i], (3, 3, c_in, c)) * std,
            "b": jnp.zeros((c,)),
        }
        c_in = c
    feat = r.cnn_channels[-1] * (r.n_feats // 4)
    d_in = feat
    for i in range(r.lstm_layers):
        p[f"lstm{i}_f"] = init_lstm(ks[4 + 2 * i], d_in, r.lstm_hidden)
        p[f"lstm{i}_b"] = init_lstm(ks[5 + 2 * i], d_in, r.lstm_hidden)
        d_in = 2 * r.lstm_hidden
    p["dnn0"] = {"w": dense_init(ks[12], d_in, r.dnn_dim),
                 "b": jnp.zeros((r.dnn_dim,))}
    p["dnn1"] = {"w": dense_init(ks[13], r.dnn_dim, r.dnn_dim),
                 "b": jnp.zeros((r.dnn_dim,))}
    p["pred_embed"] = {"w": embed_init(ks[14], r.vocab_size, r.pred_embed)}
    p["pred_gru"] = init_gru(ks[15], r.pred_embed, r.pred_hidden)
    kj = split(jax.random.fold_in(key, 7), 3)
    p["joint"] = {
        "w_enc": dense_init(kj[0], r.dnn_dim, r.joint_dim),
        "w_pred": dense_init(kj[1], r.pred_hidden, r.joint_dim),
        "w_out": dense_init(kj[2], r.joint_dim, r.vocab_size),
    }
    return p


def encode(params, cfg, feats):
    """feats: (B,T,F) -> (B, T//4, dnn_dim)."""
    r = cfg.rnnt
    x = feats[..., None]                                  # (B,T,F,1)
    for i in range(len(r.cnn_channels)):
        w, b = params[f"conv{i}"]["w"], params[f"conv{i}"]["b"]
        x = jax.lax.conv_general_dilated(
            x, w.astype(x.dtype), window_strides=(2, 2), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x + b.astype(x.dtype))
    B, T4, F4, C = x.shape
    x = x.reshape(B, T4, F4 * C)
    for i in range(r.lstm_layers):
        f = lstm_scan(params[f"lstm{i}_f"], x)
        bwd = lstm_scan(params[f"lstm{i}_b"], x, reverse=True)
        x = jnp.concatenate([f, bwd], axis=-1)
    x = jax.nn.relu(x @ params["dnn0"]["w"].astype(x.dtype)
                    + params["dnn0"]["b"].astype(x.dtype))
    x = jax.nn.relu(x @ params["dnn1"]["w"].astype(x.dtype)
                    + params["dnn1"]["b"].astype(x.dtype))
    return x


def predict(params, cfg, tokens):
    """tokens: (B,U) -> (B, U+1, pred_hidden): position u conditions on
    tokens[<u]; position 0 is the blank-start state."""
    emb = jnp.take(params["pred_embed"]["w"], tokens, axis=0)
    emb = jnp.pad(emb, ((0, 0), (1, 0), (0, 0)))          # start token = 0
    g, _ = gru_scan(params["pred_gru"], emb)
    return g


def joint_factors(params, cfg, feats, tokens):
    """Factors of the joint for the fused transducer loss (DESIGN.md §2):
    -> (ze (B,T',J), zp (B,U+1,J)).  ``tanh(ze[:,:,None] + zp[:,None])``
    is ``joint_hidden``; the fused loss (``core/rnnt_loss.py``) forms it
    row-by-row inside its scan instead of materializing (B,T',U+1,J)."""
    enc = encode(params, cfg, feats)
    pred = predict(params, cfg, tokens)
    dt = enc.dtype
    ze = enc @ params["joint"]["w_enc"].astype(dt)        # (B,T,J)
    zp = pred @ params["joint"]["w_pred"].astype(dt)      # (B,U1,J)
    return ze, zp


def pred_step(params, cfg, tokens, h):
    """One prediction-network step for streaming greedy decode.

    ``tokens``: (B,) int32 — the symbol just emitted; any id < 0 means
    the blank-start state (a zero embedding, exactly what ``predict``
    feeds at position 0 via its left pad).  ``h``: (B, pred_hidden) GRU
    state.  Returns ``(g (B, pred_hidden), h_new)`` — feeding the label
    sequence through this step token by token reproduces ``predict``'s
    rows exactly (tests/test_serve_engine.py).
    """
    emb = jnp.take(params["pred_embed"]["w"], jnp.maximum(tokens, 0), axis=0)
    emb = jnp.where((tokens >= 0)[:, None], emb, 0.0)
    return gru_step(params["pred_gru"], emb, h)


def pred_start(params, cfg, batch_size: int, dtype=jnp.float32):
    """Blank-start prediction state: ``(g0, h0)`` — what ``predict``
    produces at u=0 before any label is consumed."""
    r = cfg.rnnt
    h0 = jnp.zeros((batch_size, r.pred_hidden), dtype)
    return pred_step(params, cfg, jnp.full((batch_size,), -1, jnp.int32), h0)


def joint_step(params, enc_t, g):
    """Joint network at one (frame, pred-state) point: ``enc_t``
    (B, dnn_dim), ``g`` (B, pred_hidden) -> logits (B, V).  Identical
    math to one (t, u) cell of ``joint_hidden`` + ``joint_logits``."""
    dt = enc_t.dtype
    ze = enc_t @ params["joint"]["w_enc"].astype(dt)
    zp = g @ params["joint"]["w_pred"].astype(dt)
    return jnp.tanh(ze + zp) @ params["joint"]["w_out"].astype(dt)


def joint_hidden(params, enc, pred):
    """(B,T,De),(B,U1,Dp) -> pre-vocab joint activations (B,T,U1,J).
    This is the activation whose outer product with dL/dlogits forms the
    joint-network gradient PGM matches."""
    dt = enc.dtype
    ze = enc @ params["joint"]["w_enc"].astype(dt)        # (B,T,J)
    zp = pred @ params["joint"]["w_pred"].astype(dt)      # (B,U1,J)
    return jnp.tanh(ze[:, :, None, :] + zp[:, None, :, :])


def joint_logits(params, z):
    return z @ params["joint"]["w_out"].astype(z.dtype)


def forward(params, cfg, feats, tokens):
    """-> logits (B, T', U+1, V)."""
    enc = encode(params, cfg, feats)
    pred = predict(params, cfg, tokens)
    z = joint_hidden(params, enc, pred)
    return joint_logits(params, z)
