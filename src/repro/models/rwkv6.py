"""RWKV6 "Finch" time-mix and channel-mix [arXiv:2404.05892].

Time-mix: data-dependent token-shift (ddlerp via a small LoRA MLP),
data-dependent per-channel decay w_t, bonus u, and the WKV linear
recurrence  S_t = diag(w_t) S_{t-1} + k_t^T v_t,
            y_t = r_t (S_{t-1} + diag(u) k_t^T v_t).

Two execution paths, numerically equivalent (tested):
  * ``wkv_scan``    — sequential lax.scan (decode / oracle);
  * ``wkv_chunked`` — chunk-parallel formulation with within-chunk pairwise
    decays, the TPU-native (MXU-friendly) path mirrored by the
    ``kernels/rwkv6_scan`` Pallas kernel.  All pairwise exponents are
    differences of cumulative log-decays with j <= i, hence <= 0: stable.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split

TM_EXTRA = 32     # ddlerp lora dim
TD_EXTRA = 64     # decay lora dim
CHUNK = 64


def init_tmix_params(key, d: int, n_heads: int, head_dim: int) -> Dict:
    ks = split(key, 12)
    p = {
        "mu_x": jnp.zeros((d,)), "mu_w": jnp.zeros((d,)),
        "mu_k": jnp.zeros((d,)), "mu_v": jnp.zeros((d,)),
        "mu_r": jnp.zeros((d,)), "mu_g": jnp.zeros((d,)),
        "ddlerp_w1": dense_init(ks[0], d, 5 * TM_EXTRA, scale=0.1),
        "ddlerp_w2": (jax.random.normal(ks[1], (5, TM_EXTRA, d)) * 0.01),
        "decay_base": jnp.full((n_heads, head_dim), -1.0),
        "decay_w1": dense_init(ks[2], d, TD_EXTRA, scale=0.1),
        "decay_w2": dense_init(ks[3], TD_EXTRA, n_heads * head_dim, scale=0.1),
        "bonus": jnp.full((n_heads, head_dim), 0.5),
        "wr": dense_init(ks[4], d, n_heads * head_dim),
        "wk": dense_init(ks[5], d, n_heads * head_dim),
        "wv": dense_init(ks[6], d, n_heads * head_dim),
        "wg": dense_init(ks[7], d, n_heads * head_dim),
        "wo": dense_init(ks[8], n_heads * head_dim, d),
        "ln_g": jnp.ones((n_heads * head_dim,)),
        "ln_b": jnp.zeros((n_heads * head_dim,)),
    }
    return p


def init_cmix_params(key, d: int, d_ff: int) -> Dict:
    ks = split(key, 3)
    return {
        "mu_k": jnp.zeros((d,)), "mu_r": jnp.zeros((d,)),
        "wk": dense_init(ks[0], d, d_ff),
        "wv": dense_init(ks[1], d_ff, d),
        "wr": dense_init(ks[2], d, d),
    }


# ---------------------------------------------------------------------------
# WKV recurrence — sequential oracle
# ---------------------------------------------------------------------------

def wkv_scan(r, k, v, w, u, state0):
    """r,k,v: (B,S,H,N); w: (B,S,H,N) decays in (0,1); u: (H,N);
    state0: (B,H,N,N) keyed [k-dim, v-dim].  Returns (y (B,S,H,N), state)."""
    B, S, H, N = r.shape

    def step(S_, inp):
        r_t, k_t, v_t, w_t = inp                       # (B,H,N) each
        a = k_t[..., :, None] * v_t[..., None, :]      # (B,H,N,N)
        y = jnp.einsum("bhn,bhnm->bhm", r_t, S_ + u[..., :, None] * a)
        S_new = w_t[..., :, None] * S_ + a
        return S_new, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1), state


# ---------------------------------------------------------------------------
# WKV recurrence — chunk-parallel (TPU-native path)
# ---------------------------------------------------------------------------

def wkv_chunked(r, k, v, w, u, state0, chunk: int = CHUNK):
    B, S, H, N = r.shape
    C = min(chunk, S)
    assert S % C == 0, (S, C)
    nC = S // C
    f32 = jnp.float32
    rc = r.astype(f32).reshape(B, nC, C, H, N).transpose(1, 0, 3, 2, 4)
    kc = k.astype(f32).reshape(B, nC, C, H, N).transpose(1, 0, 3, 2, 4)
    vc = v.astype(f32).reshape(B, nC, C, H, N).transpose(1, 0, 3, 2, 4)
    lw = jnp.log(jnp.clip(w.astype(f32), 1e-8, 1.0))
    lwc = lw.reshape(B, nC, C, H, N).transpose(1, 0, 3, 2, 4)  # (nC,B,H,C,N)

    def chunk_step(S_, inp):
        rr, kk, vv, lww = inp                           # (B,H,C,N)
        cum = jnp.cumsum(lww, axis=2)                   # cum_i = sum_{j<=i} lw_j
        cum_prev = cum - lww                            # sum_{j<i}
        # inter-chunk: y_i += (r_i * exp(cum_{i-1})) @ S
        r_dec = rr * jnp.exp(cum_prev)
        y = jnp.einsum("bhcn,bhnm->bhcm", r_dec, S_)
        # intra-chunk strict-lower pairwise decays (exponents <= 0)
        dif = cum_prev[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,H,C,C,N)
        mask = jnp.tril(jnp.ones((C, C), bool), k=-1)[None, None, :, :, None]
        e = jnp.where(mask, jnp.exp(jnp.minimum(dif, 0.0)), 0.0)
        A = jnp.einsum("bhin,bhjn,bhijn->bhij", rr, kk, e)
        y = y + jnp.einsum("bhij,bhjm->bhim", A, vv)
        # diagonal bonus term: y_i += (r_i . (u*k_i)) v_i
        diag = jnp.einsum("bhcn,bhcn->bhc", rr, kk * u[..., None, :])
        y = y + diag[..., None] * vv
        # state update: S' = diag(exp(cum_C)) S + sum_j (k_j exp(cum_C-cum_j))^T v_j
        tot = cum[:, :, -1:, :]                          # (B,H,1,N)
        k_dec = kk * jnp.exp(tot - cum)
        S_new = jnp.exp(tot[:, :, 0, :])[..., :, None] * S_ + \
            jnp.einsum("bhjn,bhjm->bhnm", k_dec, vv)
        return S_new, y

    state, ys = jax.lax.scan(chunk_step, state0.astype(f32), (rc, kc, vc, lwc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, S, H, N)
    return y.astype(r.dtype), state


# ---------------------------------------------------------------------------
# Full time-mix / channel-mix blocks
# ---------------------------------------------------------------------------

def _token_shift(x, x_prev_last=None):
    """x: (B,S,d) -> previous-token tensor; decode passes carried x_prev."""
    if x_prev_last is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    # carried state is stored fp32; compute in x's dtype to avoid promotion
    return jnp.concatenate([x_prev_last.astype(x.dtype)[:, None],
                            x[:, :-1]], axis=1)


def tmix_forward(p, cfg, x, state0=None, x_prev=None, chunked=None):
    """x: (B,S,d).  Returns (y, (wkv_state, last_x))."""
    B, S, d = x.shape
    H, N = cfg.n_heads, cfg.rwkv_head_dim
    dt = x.dtype
    xp = _token_shift(x, x_prev)
    sx = xp - x
    xxx = x + sx * p["mu_x"].astype(dt)
    lora = jnp.tanh(xxx @ p["ddlerp_w1"].astype(dt))            # (B,S,5*E)
    lora = lora.reshape(B, S, 5, TM_EXTRA)
    adj = jnp.einsum("bste,ted->bstd", lora, p["ddlerp_w2"].astype(dt))
    mus = jnp.stack([p["mu_w"], p["mu_k"], p["mu_v"], p["mu_r"], p["mu_g"]]).astype(dt)
    xw, xk, xv, xr, xg = [x + sx * (mus[i] + adj[:, :, i]) for i in range(5)]

    r = (xr @ p["wr"].astype(dt)).reshape(B, S, H, N)
    k = (xk @ p["wk"].astype(dt)).reshape(B, S, H, N)
    v = (xv @ p["wv"].astype(dt)).reshape(B, S, H, N)
    g = jax.nn.silu(xg @ p["wg"].astype(dt))

    dd = jnp.tanh(xw @ p["decay_w1"].astype(dt)) @ p["decay_w2"].astype(dt)
    logit = p["decay_base"].reshape(-1).astype(jnp.float32) + dd.astype(jnp.float32)
    w = jnp.exp(-jnp.exp(logit)).reshape(B, S, H, N)            # (0,1)
    u = p["bonus"].astype(jnp.float32)

    if state0 is None:
        state0 = jnp.zeros((B, H, N, N), jnp.float32)
    # module-level CHUNK is a tuning knob (EXPERIMENTS.md §Perf rwkv cell):
    # the within-chunk pairwise-decay tensor is O(C^2 N) per chunk, total
    # HBM traffic O(S*C*N) — smaller chunks trade matmul efficiency for
    # bandwidth on the non-fused path (the Pallas kernel keeps it in VMEM)
    use_chunked = chunked if chunked is not None else (S % CHUNK == 0 and S >= 2 * CHUNK)
    if use_chunked:
        y, state = wkv_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                               v.astype(jnp.float32), w, u, state0,
                               chunk=CHUNK)
    else:
        y, state = wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), w, u, state0)
    y = y.reshape(B, S, H * N)
    # per-head group norm
    yh = y.reshape(B, S, H, N).astype(jnp.float32)
    mu = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
    y = yh.reshape(B, S, H * N) * p["ln_g"] + p["ln_b"]
    y = y.astype(dt) * g
    return y @ p["wo"].astype(dt), (state, x[:, -1])


def cmix_forward(p, x, x_prev=None):
    dt = x.dtype
    xp = _token_shift(x, x_prev)
    sx = xp - x
    xk = x + sx * p["mu_k"].astype(dt)
    xr = x + sx * p["mu_r"].astype(dt)
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(dt)))
    kv = k @ p["wv"].astype(dt)
    return jax.nn.sigmoid(xr @ p["wr"].astype(dt)) * kv, x[:, -1]
