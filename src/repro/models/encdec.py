"""Encoder-decoder transformer backbone (SeamlessM4T-medium cell).

Encoder: bidirectional attention over precomputed frame embeddings (the
speech frontend is a stub per the brief).  Decoder: causal self-attention +
cross-attention + FFN.  Both stacks are scanned (one compiled body each).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models.common import (
    IDENTITY_SHARDER,
    Sharder,
    dense_init,
    embed_init,
    rms_norm,
    split,
)


def _init_enc_layer(key, cfg):
    ks = split(key, 2)
    return {
        "ln1": jnp.zeros((cfg.d_model,)),
        "attn": attn.init_attn_params(ks[0], cfg),
        "ln2": jnp.zeros((cfg.d_model,)),
        "mlp": ffn_mod.init_ffn_params(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_type),
    }


def _init_dec_layer(key, cfg):
    ks = split(key, 3)
    return {
        "ln1": jnp.zeros((cfg.d_model,)),
        "self": attn.init_attn_params(ks[0], cfg),
        "lnx": jnp.zeros((cfg.d_model,)),
        "cross": attn.init_attn_params(ks[1], cfg),
        "ln2": jnp.zeros((cfg.d_model,)),
        "mlp": ffn_mod.init_ffn_params(ks[2], cfg.d_model, cfg.d_ff, cfg.ffn_type),
    }


def init_params(cfg, key) -> Dict:
    ks = split(key, cfg.n_enc_layers + cfg.n_layers + 2)
    enc = [_init_enc_layer(ks[i], cfg) for i in range(cfg.n_enc_layers)]
    dec = [_init_dec_layer(ks[cfg.n_enc_layers + i], cfg)
           for i in range(cfg.n_layers)]
    stack = lambda ls: jax.tree.map(lambda *xs: jnp.stack(xs), *ls)
    params = {
        "embed": {"w": embed_init(ks[-1], cfg.vocab_size, cfg.d_model)},
        "encoder": stack(enc),
        "decoder": stack(dec),
        "enc_norm": jnp.zeros((cfg.d_model,)),
        "final_norm": jnp.zeros((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": dense_init(ks[-2], cfg.d_model, cfg.vocab_size)}
    return params


def encode(params, cfg, frames, *, shard: Sharder = IDENTITY_SHARDER,
           remat: bool = True):
    """frames: (B, T, d) stub frontend embeddings -> (B, T, d)."""
    x = frames.astype(jnp.dtype(cfg.compute_dtype))

    def body(xx, lp):
        from repro.models.transformer import cast_block_params
        lp = cast_block_params(lp, cfg)
        h = rms_norm(xx, lp["ln1"], cfg.norm_eps)
        xx = xx + attn.attn_forward(lp["attn"], cfg, h, kind="bidir",
                                    shard=shard)
        h2 = rms_norm(xx, lp["ln2"], cfg.norm_eps)
        xx = xx + ffn_mod.ffn_forward(lp["mlp"], h2, cfg.ffn_type, shard=shard)
        return shard(xx, "act_bsd"), None

    body = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def decode_train(params, cfg, tokens, enc_out, *,
                 shard: Sharder = IDENTITY_SHARDER, remat: bool = True,
                 collect_cache: bool = False, cache_len: int = 0):
    """Teacher-forced decoder pass.  Returns (hidden (B,U,d), cache|None)."""
    from repro.models.transformer import embed_tokens  # avoid cycle
    x = embed_tokens(params, cfg, tokens)
    B, U, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(U), (B, U))

    def body(xx, lp):
        from repro.models.transformer import cast_block_params
        lp = cast_block_params(lp, cfg)
        h = rms_norm(xx, lp["ln1"], cfg.norm_eps)
        xx = xx + attn.attn_forward(lp["self"], cfg, h, kind="attn",
                                    q_positions=pos, kv_positions=pos,
                                    shard=shard)
        hx = rms_norm(xx, lp["lnx"], cfg.norm_eps)
        xx = xx + attn.attn_forward(lp["cross"], cfg, hx, kind="cross",
                                    kv_x=enc_out, shard=shard)
        h2 = rms_norm(xx, lp["ln2"], cfg.norm_eps)
        xx = xx + ffn_mod.ffn_forward(lp["mlp"], h2, cfg.ffn_type, shard=shard)
        out = None
        if collect_cache:
            from repro.models.transformer import _prefill_attn_cache
            self_cache = _prefill_attn_cache(lp["self"], cfg, h, "attn", pos,
                                             cache_len)
            # cross K/V are static during decode: precompute once
            epos = jnp.broadcast_to(jnp.arange(enc_out.shape[1]),
                                    (B, enc_out.shape[1]))
            _, ck, cv = attn._project_qkv(lp["cross"], cfg, hx, enc_out,
                                          pos, epos, False)
            out = {"self": self_cache, "ck": ck, "cv": cv}
        return shard(xx, "act_bsd"), out

    body = jax.checkpoint(body) if remat else body
    x, caches = jax.lax.scan(body, x, params["decoder"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, caches


def decode_step(params, cfg, x_t, cache, *, shard: Sharder = IDENTITY_SHARDER):
    """One decoder token.  cache: {"self": kv-cache, "ck","cv"} stacked over
    layers.  Returns (hidden (B,1,d), new cache)."""
    scale = 1.0 / jnp.sqrt(cfg.head_dim).astype(jnp.float32)

    def body(xx, xs):
        lp, c = xs
        h = rms_norm(xx, lp["ln1"], cfg.norm_eps)
        y, self_cache = attn.attn_decode(lp["self"], cfg, h, c["self"],
                                         kind="attn", shard=shard)
        xx = xx + y
        hx = rms_norm(xx, lp["lnx"], cfg.norm_eps)
        B = hx.shape[0]
        q = (hx @ lp["cross"]["wq"].astype(hx.dtype)).reshape(
            B, 1, cfg.n_heads, cfg.head_dim)
        if cfg.qk_norm:
            q = rms_norm(q, lp["cross"]["q_norm"], cfg.norm_eps)
        q = q.reshape(B, 1, cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads,
                      cfg.head_dim)
        mask = jnp.ones((B, 1, c["ck"].shape[1]), bool)
        y = attn._mha_full(q, c["ck"].astype(q.dtype), c["cv"].astype(q.dtype),
                           mask, scale)
        xx = xx + y.reshape(B, 1, cfg.q_dim) @ lp["cross"]["wo"].astype(hx.dtype)
        h2 = rms_norm(xx, lp["ln2"], cfg.norm_eps)
        xx = xx + ffn_mod.ffn_forward(lp["mlp"], h2, cfg.ffn_type, shard=shard)
        return xx, dict(c, self=self_cache)

    x, new_cache = jax.lax.scan(body, x_t, (params["decoder"], cache))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_cache
