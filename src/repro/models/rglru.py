"""RG-LRU recurrent block (Griffin / RecurrentGemma) [arXiv:2402.19427].

Block: x -> (linear -> causal depthwise conv(width 4) -> RG-LRU) gated by a
parallel GeLU branch -> output projection.

RG-LRU:  r_t = sigmoid(W_a x_t + b_a)   (recurrence gate)
         i_t = sigmoid(W_x x_t + b_x)   (input gate)
         log a_t = -c * softplus(Lambda) * r_t          (c = 8)
         h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses an associative scan over the sequence; decode carries
(h, conv window) state.  sqrt(1-a^2) computed as sqrt(-expm1(2 log a)).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split

RGLRU_C = 8.0


def init_rglru_params(key, d_model: int, width: int, conv_width: int) -> Dict:
    ks = split(key, 6)
    return {
        "w_in": dense_init(ks[0], d_model, width),
        "w_gate_branch": dense_init(ks[1], d_model, width),
        "conv_w": jax.random.normal(ks[2], (conv_width, width)) * 0.1,
        "conv_b": jnp.zeros((width,)),
        "wa": dense_init(ks[3], width, width),
        "ba": jnp.zeros((width,)),
        "wx": dense_init(ks[4], width, width),
        "bx": jnp.zeros((width,)),
        "lam": jnp.linspace(0.3, 1.7, width),    # softplus(lam) spread
        "w_out": dense_init(ks[5], width, d_model),
    }


def _causal_conv(x, w, b, state: Optional[jax.Array] = None):
    """Depthwise causal conv via shifted adds.  x: (B,S,w); state: (B,cw-1,w)
    holds the trailing inputs from the previous segment (decode)."""
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # (B, S+cw-1, w)
    out = sum(xp[:, i : i + x.shape[1]] * w[cw - 1 - i].astype(x.dtype)
              for i in range(cw))
    new_state = xp[:, -(cw - 1):] if cw > 1 else jnp.zeros_like(pad)
    return out + b.astype(x.dtype), new_state


def _rg_lru(x, r, i, lam, h0: Optional[jax.Array]):
    """x,r,i: (B,S,w) fp32.  Returns (h (B,S,w), h_last (B,w))."""
    log_a = -RGLRU_C * jax.nn.softplus(lam) * r                  # <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a), 0.0)) * (i * x)
    if h0 is not None:
        # fold carried state in as a virtual step at t=-1 with a=1,b=h0
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        gated = jnp.concatenate([h0[:, None].astype(gated.dtype), gated], axis=1)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, gated), axis=1)
    if h0 is not None:
        hh = hh[:, 1:]
    return hh, hh[:, -1]


def rglru_forward(p, cfg, x, state=None) -> Tuple[jax.Array, Dict]:
    """x: (B,S,d).  state: {"h": (B,w), "conv": (B,cw-1,w)} or None.
    Returns (out (B,S,d), new_state)."""
    dt = x.dtype
    u = x @ p["w_in"].astype(dt)
    gate = jax.nn.gelu(x @ p["w_gate_branch"].astype(dt))
    conv_state = None if state is None else state["conv"]
    u, new_conv = _causal_conv(u, p["conv_w"], p["conv_b"], conv_state)
    u32 = u.astype(jnp.float32)
    r = jax.nn.sigmoid(u32 @ p["wa"].astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(u32 @ p["wx"].astype(jnp.float32) + p["bx"])
    h0 = None if state is None else state["h"]
    h, h_last = _rg_lru(u32, r, i, p["lam"], h0)
    out = (h.astype(dt) * gate) @ p["w_out"].astype(dt)
    # recurrent state is carried fp32 across decode steps
    return out, {"h": h_last.astype(jnp.float32),
                 "conv": new_conv.astype(jnp.float32)}


def init_rglru_state(batch: int, width: int, conv_width: int):
    return {"h": jnp.zeros((batch, width), jnp.float32),
            "conv": jnp.zeros((batch, conv_width - 1, width), jnp.float32)}
