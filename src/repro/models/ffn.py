"""Dense FFN variants: SwiGLU / GeGLU (gated) and GELU / squared-ReLU."""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.models.common import IDENTITY_SHARDER, Sharder, dense_init, ffn_act, split


def is_gated(ffn_type: str) -> bool:
    return ffn_type in ("swiglu", "geglu")


def init_ffn_params(key, d_model: int, d_ff: int, ffn_type: str) -> Dict:
    ks = split(key, 3)
    p = {"w_in": dense_init(ks[0], d_model, d_ff),
         "w_out": dense_init(ks[1], d_ff, d_model)}
    if is_gated(ffn_type):
        p["w_gate"] = dense_init(ks[2], d_model, d_ff)
    return p


def ffn_forward(params, x, ffn_type: str, shard: Sharder = IDENTITY_SHARDER):
    dt = x.dtype
    act = ffn_act(ffn_type)
    h = x @ params["w_in"].astype(dt)
    if is_gated(ffn_type):
        g = x @ params["w_gate"].astype(dt)
        h = act(g) * h
    else:
        h = act(h)
    h = shard(h, "act_ff")
    return h @ params["w_out"].astype(dt)
