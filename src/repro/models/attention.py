"""Attention: GQA/MQA/MHA; full-causal, sliding-window (band), hybrid
local:global, prefix-LM; train/prefill (optionally blockwise-"flash") and
single-step decode against full or ring KV caches.

Design notes (DESIGN.md §5):
  * masked-full-scan flash keeps XLA compile O(1) in sequence length;
  * sliding-window uses an O(S·(W+C)) band gather, not O(S²) masking;
  * caches carry an explicit per-slot position vector so full and ring
    caches share one masking rule (pos < 0 -> invalid slot).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.common import (
    IDENTITY_SHARDER,
    Sharder,
    apply_rope,
    dense_init,
    rms_norm,
    split,
)

NEG_INF = -1e30
FLASH_THRESHOLD = 4096      # Sq*avg_Sk above which the kv-block scan is used
KV_BLOCK = 512
Q_BLOCK = 1024

MaskFn = Callable[[jax.Array, jax.Array], jax.Array]   # (q_pos, kv_pos) -> bool


# ---------------------------------------------------------------------------
# Mask functions
# ---------------------------------------------------------------------------

def causal_mask(q_pos, kv_pos):
    return q_pos[..., :, None] >= kv_pos[..., None, :]


def window_mask(window: int) -> MaskFn:
    def fn(q_pos, kv_pos):
        d = q_pos[..., :, None] - kv_pos[..., None, :]
        return (d >= 0) & (d < window)
    return fn


def prefix_lm_mask(n_prefix: int) -> MaskFn:
    """Bidirectional within the first ``n_prefix`` positions, causal after."""
    def fn(q_pos, kv_pos):
        causal = q_pos[..., :, None] >= kv_pos[..., None, :]
        in_prefix = kv_pos[..., None, :] < n_prefix
        return causal | in_prefix
    return fn


def bidir_mask(q_pos, kv_pos):
    return jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], kv_pos.shape[-1]), bool)


def _valid(kv_pos):
    return kv_pos >= 0


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attn_params(key, cfg, d_kv_src: Optional[int] = None) -> Dict:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    d_kv_src = d_kv_src or d
    ks = split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, qd),
        "wk": dense_init(ks[1], d_kv_src, kvd),
        "wv": dense_init(ks[2], d_kv_src, kvd),
        "wo": dense_init(ks[3], qd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.head_dim,))
        p["k_norm"] = jnp.zeros((cfg.head_dim,))
    return p


def _project_qkv(params, cfg, x, kv_x, q_pos, kv_pos, rope: bool):
    """-> q (B,Sq,KV,G,hd), k,v (B,Sk,KV,hd)."""
    B, Sq, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(B, Sq, H, hd)
    k = (kv_x @ params["wk"].astype(dt)).reshape(B, kv_x.shape[1], KV, hd)
    v = (kv_x @ params["wv"].astype(dt)).reshape(B, kv_x.shape[1], KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k = apply_rope(k, kv_pos, cfg.rope_theta)
    q = q.reshape(B, Sq, KV, H // KV, hd)
    return q, k, v


# ---------------------------------------------------------------------------
# Core grouped attention (materialized scores)
# ---------------------------------------------------------------------------

def _mha_full(q, k, v, mask, scale):
    # q: (B,Sq,KV,G,hd) k,v: (B,Sk,KV,hd) mask: (B?,Sq,Sk) bool
    # bf16 inputs, fp32 accumulation (MXU-native mixed precision)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    while mask.ndim < scores.ndim:
        mask = mask[:, None]
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    return out


def _mha_flash(q, k, v, q_pos, kv_pos, mask_fn, scale, block=KV_BLOCK):
    """Online-softmax scan over kv blocks; numerically matches _mha_full."""
    B, Sq, KV, G, hd = q.shape
    Sk = k.shape[1]
    n_blocks = -(-Sk // block)
    pad = n_blocks * block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    kb = k.reshape(B, n_blocks, block, KV, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blocks, block, KV, hd).transpose(1, 0, 2, 3, 4)
    pb = kv_pos.reshape(B, n_blocks, block).transpose(1, 0, 2)

    def step(carry, blk):
        m, l, acc = carry
        kc, vc, pc = blk
        # bf16 operands, fp32 accumulation: collectives that move k/v (and
        # their cotangents) stay in bf16 (§Perf iter 5)
        s = jnp.einsum("bqkgh,bskh->bkgqs", q, kc,
                       preferred_element_type=jnp.float32) * scale
        mask = mask_fn(q_pos, pc) & _valid(pc)[..., None, :]   # (B,Sq,block)
        s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p.astype(q.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    # remat the step: backward recomputes the (.., Sq, block) score matrix
    # instead of saving one per kv block (perf iteration, §Perf)
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(step), (m0, l0, a0),
                                  (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)      # (B,Sq,KV,G,hd)


def _mha_band(q, k, v, q_pos, kv_pos, window, scale, q_block=Q_BLOCK):
    """Sliding-window attention via per-q-block band gather: O(S*(W+C))."""
    B, Sq, KV, G, hd = q.shape
    Sk = k.shape[1]
    C = min(q_block, Sq)
    nq = -(-Sq // C)
    band = window + C
    if Sk < band:   # short sequence: full path is cheaper/correct
        mask = window_mask(window)(q_pos, kv_pos) & _valid(kv_pos)[..., None, :]
        return _mha_full(q, k, v, mask, scale)

    qb = q.reshape(B, nq, C, KV, G, hd)

    def one_block(i):
        start = jnp.clip(i * C + C - band, 0, Sk - band)
        kc = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
        pc = jax.lax.dynamic_slice_in_dim(kv_pos, start, band, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(q_pos, i * C, C, axis=1)
        mask = window_mask(window)(qp, pc) & _valid(pc)[..., None, :]
        return _mha_full(qb[:, i], kc, vc, mask, scale)

    outs = jax.lax.map(one_block, jnp.arange(nq))            # (nq,B,C,KV,G,hd)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KV, G, hd)


# ---------------------------------------------------------------------------
# Public train/prefill forward
# ---------------------------------------------------------------------------

def attn_forward(
    params,
    cfg,
    x: jax.Array,                      # (B,Sq,d)
    *,
    kind: str = "attn",                # attn | local | global | cross | bidir
    mask_fn: Optional[MaskFn] = None,
    kv_x: Optional[jax.Array] = None,  # cross attention source
    q_positions: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
    shard: Sharder = IDENTITY_SHARDER,
) -> jax.Array:
    B, Sq, _ = x.shape
    kv_x = x if kv_x is None else kv_x
    Sk = kv_x.shape[1]
    q_pos = (jnp.broadcast_to(jnp.arange(Sq), (B, Sq))
             if q_positions is None else q_positions)
    kv_pos = (jnp.broadcast_to(jnp.arange(Sk), (B, Sk))
              if kv_positions is None else kv_positions)
    rope = kind != "cross"
    q, k, v = _project_qkv(params, cfg, x, kv_x, q_pos, kv_pos, rope)
    # perf iteration 1 (EXPERIMENTS.md §Perf): repeat KV heads so the
    # grouped head axis aligns with the TP degree; scores then shard over
    # heads instead of requiring per-block all-reduces over head_dim
    rep = shard.kv_repeat(cfg.n_heads, cfg.n_kv_heads)
    if rep > 1:
        KV, G, hd = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.head_dim
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        q = q.reshape(B, Sq, KV * rep, G // rep, hd)
    q = shard(q, "act_q")
    k = shard(k, "act_kv")
    v = shard(v, "act_kv")
    scale = 1.0 / jnp.sqrt(cfg.head_dim).astype(jnp.float32)

    if mask_fn is None:
        mask_fn = {
            "attn": causal_mask, "global": causal_mask,
            "local": window_mask(cfg.window) if cfg.window else causal_mask,
            "cross": bidir_mask, "bidir": bidir_mask,
        }[kind]

    if kind == "local" and cfg.window and Sq == Sk and Sq > cfg.window + Q_BLOCK:
        out = _mha_band(q, k, v, q_pos, kv_pos, cfg.window, scale)
    elif Sq * Sk > FLASH_THRESHOLD ** 2:
        out = _mha_flash(q, k, v, q_pos, kv_pos, mask_fn, scale)
    else:
        mask = mask_fn(q_pos, kv_pos) & _valid(kv_pos)[..., None, :]
        out = _mha_full(q, k, v, mask, scale)
    out = out.reshape(B, Sq, cfg.q_dim)
    out = shard(out, "act_q_flat")
    return out @ params["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------

def init_kv_cache(cfg, batch: int, length: int, window: bool, dtype=jnp.bfloat16):
    """``length`` = full context for global/full layers, window size for local.
    ``pos`` holds the absolute position stored in each slot (-1 = empty)."""
    L = min(length, cfg.window) if (window and cfg.window) else length
    return {
        "k": jnp.zeros((batch, L, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, L, cfg.n_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.full((batch, L), -1, jnp.int32),
        "t": jnp.zeros((), jnp.int32),
    }


def cache_write(cache, k_new, v_new, pos_new):
    """Write one step (Sq=1) at ring/full slot derived from cache['t']."""
    L = cache["k"].shape[1]
    slot = cache["t"] % L
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)
    pos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], pos_new.astype(jnp.int32), slot, axis=1)
    return {"k": k, "v": v, "pos": pos, "t": cache["t"] + 1}


def cache_prefill(cache, k_all, v_all, pos_all):
    """Bulk-fill after prefill: keeps the last L positions.

    ``t`` (the next decode position) is derived from the *positions*, not
    the buffer length: with natural positions ``max(pos)+1 == S``, and a
    right-padded bucketed prompt (pads carry pos -1, serve/engine.py)
    resumes decode at the true prompt length, writing over the invalid
    pad slots first."""
    L = cache["k"].shape[1]
    S = k_all.shape[1]
    t_next = (jnp.max(pos_all) + 1).astype(jnp.int32)
    if S >= L:
        # keep last L positions, placed at their natural ring slots
        # (position p -> slot p % L) so subsequent writes evict oldest-first
        shift = (S - L) % L
        sl = lambda a: jnp.roll(a[:, S - L:], shift, axis=1)
        return {"k": sl(k_all).astype(cache["k"].dtype),
                "v": sl(v_all).astype(cache["v"].dtype),
                "pos": sl(pos_all).astype(jnp.int32),
                "t": t_next}
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_all.astype(cache["k"].dtype), 0, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_all.astype(cache["v"].dtype), 0, axis=1)
    pos = jax.lax.dynamic_update_slice_in_dim(cache["pos"], pos_all.astype(jnp.int32), 0, axis=1)
    return {"k": k, "v": v, "pos": pos, "t": t_next}


def attn_decode(
    params, cfg, x_t: jax.Array, cache, *, kind: str = "attn",
    mask_fn: Optional[MaskFn] = None, shard: Sharder = IDENTITY_SHARDER,
):
    """One decode step.  x_t: (B,1,d).  Returns (out (B,1,d), new cache)."""
    B = x_t.shape[0]
    t = cache["t"]
    q_pos = jnp.broadcast_to(t, (B, 1))
    q, k_new, v_new = _project_qkv(params, cfg, x_t, x_t, q_pos, q_pos, True)
    cache = cache_write(cache, k_new.astype(cache["k"].dtype),
                        v_new.astype(cache["v"].dtype), q_pos)
    k, v, kv_pos = cache["k"], cache["v"], cache["pos"]
    if mask_fn is None:
        mask_fn = window_mask(cfg.window) if (kind == "local" and cfg.window) \
            else causal_mask
    mask = mask_fn(q_pos, kv_pos) & _valid(kv_pos)[..., None, :]
    scale = 1.0 / jnp.sqrt(cfg.head_dim).astype(jnp.float32)
    out = _mha_full(q, k.astype(q.dtype), v.astype(q.dtype), mask, scale)
    out = out.reshape(B, 1, cfg.q_dim)
    return out @ params["wo"].astype(x_t.dtype), cache
