"""Shared building blocks: norms, RoPE, dense init/apply, dtype policy,
and the Sharder protocol that keeps model code mesh-agnostic."""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Sharding callback: model code annotates key activations by logical name;
# the launcher maps names to PartitionSpecs.  Tests pass None (identity).
# ---------------------------------------------------------------------------
class Sharder:
    """Maps logical activation names -> sharding constraints.  Base class is
    the identity (single-device tests).  repro.sharding.specs provides the
    mesh-aware implementation."""

    def __call__(self, x: jax.Array, name: str) -> jax.Array:
        return x

    def kv_repeat(self, n_heads: int, n_kv_heads: int) -> int:
        """How many times attention should repeat KV heads so the grouped
        head axis aligns with tensor parallelism (perf iteration 1,
        EXPERIMENTS.md §Perf).  Identity sharder: never."""
        return 1


IDENTITY_SHARDER = Sharder()


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float = 1.0):
    std = scale / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32)).astype(dtype)


def split(key, n: int):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Norms (computed in fp32, cast back)
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    # gemma-style (1 + gamma) parameterization: zero-init gamma == identity
    return (y * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    dt = x.dtype
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                         # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    sin = jnp.sin(angles)[..., None, :]                   # (..., S, 1, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : hd // 2], x32[..., hd // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def activation(name: str) -> Callable[[jax.Array], jax.Array]:
    if name == "gelu":
        return jax.nn.gelu
    if name == "silu":
        return jax.nn.silu
    if name == "sq_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "tanh":
        return jnp.tanh
    raise ValueError(name)


def ffn_act(ffn_type: str):
    return {"swiglu": jax.nn.silu, "geglu": jax.nn.gelu,
            "gelu": jax.nn.gelu, "sq_relu": activation("sq_relu")}[ffn_type]


# ---------------------------------------------------------------------------
# dtype policy helpers
# ---------------------------------------------------------------------------

def cast_compute(x, cfg) -> jax.Array:
    return x.astype(jnp.dtype(cfg.compute_dtype))


def tree_size_bytes(tree) -> int:
    return sum(np.prod(l.shape) * l.dtype.itemsize for l in jax.tree.leaves(tree))


def count_params(tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))
