"""Unified decoder stack for all LM-family architectures.

The layer stack is organized into *pattern groups* (DESIGN.md §5): the
config's ``pattern`` (e.g. gemma3 ``(local x5, global)``, recurrentgemma
``(rec, rec, local)``) is scanned with ``lax.scan`` so XLA compiles one
body per group regardless of depth; the ``n_layers % len(pattern)``
remainder layers form an unscanned tail.  Each block kind owns its param
and cache structure; caches carry explicit slot-position vectors.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.common import (
    IDENTITY_SHARDER,
    Sharder,
    dense_init,
    embed_init,
    rms_norm,
    split,
)

ATTN_KINDS = ("attn", "local", "global")


# ---------------------------------------------------------------------------
# Per-block params
# ---------------------------------------------------------------------------

def _init_block(key, cfg, kind: str) -> Dict:
    d = cfg.d_model
    ks = split(key, 3)
    p: Dict[str, Any] = {"ln1": jnp.zeros((d,)), "ln2": jnp.zeros((d,))}
    if kind in ATTN_KINDS:
        p["attn"] = attn.init_attn_params(ks[0], cfg)
    elif kind == "rec":
        p["rec"] = rglru_mod.init_rglru_params(
            ks[0], d, cfg.lru_width or d, cfg.conv_width)
    elif kind == "rwkv":
        p["tmix"] = rwkv_mod.init_tmix_params(
            ks[0], d, cfg.n_heads, cfg.rwkv_head_dim)
    else:
        raise ValueError(kind)
    if kind == "rwkv":
        p["cmix"] = rwkv_mod.init_cmix_params(ks[1], d, cfg.d_ff)
    elif cfg.moe is not None:
        p["moe"] = moe_mod.init_moe_params(ks[1], d, cfg.moe, cfg.ffn_type)
    else:
        p["mlp"] = ffn_mod.init_ffn_params(ks[1], d, cfg.d_ff, cfg.ffn_type)
    return p


def init_params(cfg, key) -> Dict:
    kinds = cfg.layer_kinds()
    P = len(cfg.pattern)
    n_groups = cfg.n_layers // P
    keys = split(key, cfg.n_layers + 3)
    per_layer = [_init_block(keys[i], cfg, kinds[i]) for i in range(cfg.n_layers)]
    groups = tuple(
        jax.tree.map(lambda *xs: jnp.stack(xs),
                     *[per_layer[g * P + pos] for g in range(n_groups)])
        for pos in range(P)
    ) if n_groups else tuple()
    tail = tuple(per_layer[n_groups * P:])
    params = {
        "embed": {"w": embed_init(keys[-1], cfg.vocab_size, cfg.d_model)},
        "stack": {"groups": groups, "tail": tail},
        "final_norm": jnp.zeros((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": dense_init(keys[-2], cfg.d_model, cfg.vocab_size)}
    return params


# ---------------------------------------------------------------------------
# Block forwards (training / prefill)
# ---------------------------------------------------------------------------

def cast_block_params(bp, cfg):
    """Pre-cast a block's fp32 master params to the compute dtype once, so
    FSDP all-gathers move bf16 (half the wire bytes) instead of fp32
    (perf iteration 3, EXPERIMENTS.md §Perf).  No-op when compute dtype is
    fp32 (smoke tests)."""
    dt = jnp.dtype(cfg.compute_dtype)
    if dt == jnp.float32:
        return bp
    return jax.tree.map(
        lambda l: l.astype(dt) if l.dtype == jnp.float32 else l, bp)


def block_forward(bp, cfg, kind, x, *, positions=None, mask_fn=None,
                  shard: Sharder = IDENTITY_SHARDER,
                  collect_cache: bool = False, cache_len: int = 0):
    """Returns (x, aux, cache_entry_or_None)."""
    bp = cast_block_params(bp, cfg)
    aux = jnp.zeros((), jnp.float32)
    cache_entry = None
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    if kind in ATTN_KINDS:
        y = attn.attn_forward(
            bp["attn"], cfg, h, kind=kind, mask_fn=mask_fn,
            q_positions=positions, kv_positions=positions, shard=shard)
        if collect_cache:
            cache_entry = _prefill_attn_cache(bp["attn"], cfg, h, kind,
                                              positions, cache_len)
    elif kind == "rec":
        y, state = rglru_mod.rglru_forward(bp["rec"], cfg, h)
        if collect_cache:
            cache_entry = state
    elif kind == "rwkv":
        y, (S, last_x) = rwkv_mod.tmix_forward(bp["tmix"], cfg, h)
        if collect_cache:
            cache_entry = {"S": S, "x_tmix": last_x}
    x = x + y
    h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
    if kind == "rwkv":
        y2, last_x2 = rwkv_mod.cmix_forward(bp["cmix"], h2)
        if collect_cache:
            cache_entry["x_cmix"] = last_x2
    elif "moe" in bp:
        y2, aux = moe_mod.moe_forward(bp["moe"], cfg, h2, shard=shard)
    else:
        y2 = ffn_mod.ffn_forward(bp["mlp"], h2, cfg.ffn_type, shard=shard)
    x = shard(x + y2, "act_bsd")
    return x, aux, cache_entry


def _prefill_attn_cache(ap, cfg, h, kind, positions, cache_len):
    """Recompute K/V for the cache after prefill (cheap vs attention)."""
    B, S, _ = h.shape
    pos = (jnp.broadcast_to(jnp.arange(S), (B, S))
           if positions is None else positions)
    _, k, v = attn._project_qkv(ap, cfg, h, h, pos, pos, kind != "cross")
    window = kind == "local"
    cache = attn.init_kv_cache(cfg, B, cache_len, window,
                               dtype=jnp.dtype(cfg.compute_dtype))
    return attn.cache_prefill(cache, k, v, pos)


# ---------------------------------------------------------------------------
# Block decode (one token)
# ---------------------------------------------------------------------------

def block_decode(bp, cfg, kind, x_t, cache_entry, *,
                 shard: Sharder = IDENTITY_SHARDER, mask_fn=None):
    bp = cast_block_params(bp, cfg)
    h = rms_norm(x_t, bp["ln1"], cfg.norm_eps)
    if kind in ATTN_KINDS:
        y, cache_entry = attn.attn_decode(
            bp["attn"], cfg, h, cache_entry, kind=kind, mask_fn=mask_fn,
            shard=shard)
    elif kind == "rec":
        y, cache_entry = rglru_mod.rglru_forward(bp["rec"], cfg, h,
                                                 state=cache_entry)
    elif kind == "rwkv":
        y, (S, last_x) = rwkv_mod.tmix_forward(
            bp["tmix"], cfg, h, state0=cache_entry["S"],
            x_prev=cache_entry["x_tmix"], chunked=False)
        cache_entry = dict(cache_entry, S=S.astype(cache_entry["S"].dtype),
                           x_tmix=last_x.astype(
                               cache_entry["x_tmix"].dtype))
    x_t = x_t + y
    h2 = rms_norm(x_t, bp["ln2"], cfg.norm_eps)
    if kind == "rwkv":
        y2, last_x2 = rwkv_mod.cmix_forward(bp["cmix"], h2,
                                            x_prev=cache_entry["x_cmix"])
        cache_entry = dict(cache_entry,
                           x_cmix=last_x2.astype(
                               cache_entry["x_cmix"].dtype))
    elif "moe" in bp:
        y2, _ = moe_mod.moe_forward(bp["moe"], cfg, h2, shard=shard,
                                    decode=True)
    else:
        y2 = ffn_mod.ffn_forward(bp["mlp"], h2, cfg.ffn_type, shard=shard)
    return x_t + y2, cache_entry


# ---------------------------------------------------------------------------
# Full stack
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg, tokens):
    x = jnp.take(params["embed"]["w"], tokens, axis=0)
    x = x.astype(jnp.dtype(cfg.compute_dtype))
    if cfg.embed_scale:
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(params, cfg, x):
    w = (params["embed"]["w"].T if cfg.tie_embeddings
         else params["lm_head"]["w"])
    return x @ w.astype(x.dtype)


def forward_hidden(params, cfg, x, *, positions=None, mask_fn=None,
                   shard: Sharder = IDENTITY_SHARDER, remat: bool = True,
                   collect_cache: bool = False, cache_len: int = 0):
    """Runs the stack on embedded input ``x`` -> (final hidden, aux,
    cache_or_None).  ``mask_fn`` overrides attention masking (prefix-LM)."""
    pattern = cfg.pattern
    n_groups = cfg.n_layers // len(pattern)

    def group_body(carry, gp):
        xx = carry
        auxes = []
        caches = []
        for pos, kind in enumerate(pattern):
            bp = gp[pos]
            xx, aux, ce = block_forward(
                bp, cfg, kind, xx, positions=positions, mask_fn=mask_fn,
                shard=shard, collect_cache=collect_cache, cache_len=cache_len)
            auxes.append(aux)
            caches.append(ce)
        return xx, (jnp.stack(auxes).sum(), tuple(caches))

    body = jax.checkpoint(group_body) if remat else group_body
    aux_total = jnp.zeros((), jnp.float32)
    group_caches = None
    if n_groups:
        x, (aux_g, group_caches) = jax.lax.scan(
            body, x, params["stack"]["groups"])
        aux_total = aux_total + aux_g.sum()
    tail_caches = []
    kinds = cfg.layer_kinds()
    for i, bp in enumerate(params["stack"]["tail"]):
        kind = kinds[n_groups * len(pattern) + i]
        x, aux, ce = block_forward(
            bp, cfg, kind, x, positions=positions, mask_fn=mask_fn,
            shard=shard, collect_cache=collect_cache, cache_len=cache_len)
        aux_total = aux_total + aux
        tail_caches.append(ce)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    cache = ({"groups": group_caches, "tail": tuple(tail_caches)}
             if collect_cache else None)
    return x, aux_total, cache


def init_cache(cfg, batch: int, cache_len: int, dtype=None):
    """Zero-initialized decode cache matching forward_hidden's structure."""
    dtype = jnp.dtype(cfg.compute_dtype) if dtype is None else dtype
    pattern = cfg.pattern
    n_groups = cfg.n_layers // len(pattern)
    kinds = cfg.layer_kinds()

    def one(kind):
        if kind in ATTN_KINDS:
            return attn.init_kv_cache(cfg, batch, cache_len,
                                      window=(kind == "local"), dtype=dtype)
        if kind == "rec":
            return rglru_mod.init_rglru_state(batch, cfg.lru_width or cfg.d_model,
                                              cfg.conv_width)
        if kind == "rwkv":
            return {
                "S": jnp.zeros((batch, cfg.n_heads, cfg.rwkv_head_dim,
                                cfg.rwkv_head_dim), jnp.float32),
                "x_tmix": jnp.zeros((batch, cfg.d_model), jnp.float32),
                "x_cmix": jnp.zeros((batch, cfg.d_model), jnp.float32),
            }
        raise ValueError(kind)

    groups = tuple(
        jax.tree.map(lambda l: jnp.broadcast_to(l, (n_groups,) + l.shape)
                     .copy(), one(kind))
        for kind in pattern
    ) if n_groups else tuple()
    tail = tuple(one(kinds[n_groups * len(pattern) + i])
                 for i in range(cfg.n_layers - n_groups * len(pattern)))
    return {"groups": groups, "tail": tail}


def decode_step(params, cfg, x_t, cache, *, shard: Sharder = IDENTITY_SHARDER,
                mask_fn=None):
    """x_t: (B,1,d) embedded token.  Returns (hidden (B,1,d), new cache)."""
    pattern = cfg.pattern
    n_groups = cfg.n_layers // len(pattern)
    kinds = cfg.layer_kinds()

    def group_body(carry, xs):
        xx = carry
        gp, gc = xs
        new_caches = []
        for pos, kind in enumerate(pattern):
            xx, ce = block_decode(gp[pos], cfg, kind, xx, gc[pos],
                                  shard=shard, mask_fn=mask_fn)
            new_caches.append(ce)
        return xx, tuple(new_caches)

    new_group_caches = cache["groups"]
    x = x_t
    if n_groups:
        x, new_group_caches = jax.lax.scan(
            group_body, x, (params["stack"]["groups"], cache["groups"]))
    new_tail = []
    for i, bp in enumerate(params["stack"]["tail"]):
        kind = kinds[n_groups * len(pattern) + i]
        x, ce = block_decode(bp, cfg, kind, x, cache["tail"][i],
                             shard=shard, mask_fn=mask_fn)
        new_tail.append(ce)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, {"groups": new_group_caches, "tail": tuple(new_tail)}
