"""Unified model API: every architecture exposes the same bundle of pure
functions (init / loss / per-example loss / PGM last-layer hooks / prefill /
decode / input specs).  This is the surface the trainer, server, PGM core,
and the multi-pod dry-run all build on.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.rnnt_loss import rnnt_loss_from_logits, rnnt_loss_fused
from repro.models import encdec as encdec_mod
from repro.models import rnnt as rnnt_mod
from repro.models import transformer as tfm
from repro.models.attention import prefix_lm_mask
from repro.models.common import IDENTITY_SHARDER, Sharder

Batch = Dict[str, jax.Array]


def softmax_xent(logits, targets, mask):
    """Per-example mean cross-entropy.  logits (B,S,V); targets (B,S);
    mask (B,S).  Computed in fp32.  The gold logit is extracted with a
    one-hot contraction (not take_along_axis) so a vocab-sharded logits
    tensor reduces to partial sums + a tiny all-reduce instead of a full
    logits all-gather (DESIGN.md §5)."""
    lv = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lv, axis=-1)
    onehot = jax.nn.one_hot(targets, lv.shape[-1], dtype=lv.dtype)
    gold = jnp.einsum("bsv,bsv->bs", lv, onehot)
    nll = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(axis=-1), 1.0)
    return nll.sum(axis=-1) / denom                     # (B,)


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    init_params: Callable[..., Any]
    per_example_loss: Callable[..., jax.Array]          # (params, batch) -> (B,)
    loss_fn: Callable[..., Tuple[jax.Array, Dict]]      # weighted scalar + metrics
    final_hidden: Callable[..., Tuple]                  # PGM last-layer hook
    head_weight: Callable[[Any], jax.Array]             # (d, V) last-layer W
    prefill: Callable[..., Tuple[jax.Array, Any]]
    decode: Callable[..., Tuple[jax.Array, Any]]
    init_cache: Callable[..., Any]
    input_specs: Callable[[ShapeConfig], Dict[str, jax.ShapeDtypeStruct]]
    make_batch: Callable[..., Batch]


def _weights_of(batch: Batch, B: int):
    w = batch.get("weights")
    return jnp.ones((B,), jnp.float32) if w is None else w.astype(jnp.float32)


def _weighted(per_ex: jax.Array, batch: Batch, aux) -> Tuple[jax.Array, Dict]:
    w = _weights_of(batch, per_ex.shape[0])
    loss = jnp.sum(per_ex * w) / jnp.maximum(jnp.sum(w), 1e-9)
    total = loss + aux
    return total, {"loss": loss, "aux_loss": aux, "total_loss": total}


# ===========================================================================
# Decoder-only LMs (dense / moe / ssm / hybrid) and VLM
# ===========================================================================

def _build_lm(cfg: ModelConfig) -> ModelBundle:
    is_vlm = cfg.family == "vlm"
    P = cfg.n_prefix if is_vlm else 0
    mask_fn = prefix_lm_mask(P) if is_vlm else None

    def assemble(params, batch):
        """-> (x_embedded (B,S,d), targets, loss_mask, text_offset)."""
        tokens = batch["tokens"]
        x = tfm.embed_tokens(params, cfg, tokens)
        if is_vlm:
            patches = batch["patches"].astype(x.dtype)
            x = jnp.concatenate([patches, x], axis=1)
        # position i predicts token i+1 of the text stream
        targets = tokens[:, 1:]
        mask = batch.get("loss_mask")
        mask = (jnp.ones_like(targets, jnp.float32) if mask is None
                else mask[:, 1:].astype(jnp.float32))
        return x, targets, mask

    def hidden(params, batch, shard=IDENTITY_SHARDER, remat=True):
        x, targets, mask = assemble(params, batch)
        h, aux, _ = tfm.forward_hidden(params, cfg, x, mask_fn=mask_fn,
                                       shard=shard, remat=remat)
        # text hidden states aligned with next-token targets
        S_txt = batch["tokens"].shape[1]
        h_txt = h[:, P : P + S_txt - 1] if is_vlm else h[:, :-1]
        return h_txt, targets, mask, aux

    def per_example_loss(params, batch, shard=IDENTITY_SHARDER, remat=True):
        h, targets, mask, aux = hidden(params, batch, shard, remat)
        logits = tfm.unembed(params, cfg, h)
        return softmax_xent(logits, targets, mask)

    def loss_fn(params, batch, shard=IDENTITY_SHARDER, remat=True):
        h, targets, mask, aux = hidden(params, batch, shard, remat)
        logits = tfm.unembed(params, cfg, h)
        per_ex = softmax_xent(logits, targets, mask)
        return _weighted(per_ex, batch, aux)

    def head_weight(params):
        return (params["embed"]["w"].T if cfg.tie_embeddings
                else params["lm_head"]["w"])

    def prefill(params, batch, shard=IDENTITY_SHARDER, cache_len=None,
                prompt_lens=None):
        """Prefill the decode cache.  With ``prompt_lens`` (B,) the prompt
        is treated as right-padded to its bucket length: pad positions get
        position id -1, which every attention mask rule treats as invalid
        (``attention._valid``), so the returned last-token logits and the
        cache contents are bit-identical to an unpadded prefill of the
        live prefix — the retrace-free bucketed-prompt contract of the
        serving engine (DESIGN.md §4)."""
        x, _, _ = assemble(params, batch)
        S_total = x.shape[1]
        cache_len = cache_len or S_total
        if prompt_lens is None:
            h, _, cache = tfm.forward_hidden(
                params, cfg, x, mask_fn=mask_fn, shard=shard, remat=False,
                collect_cache=True, cache_len=cache_len)
            logits = tfm.unembed(params, cfg, h[:, -1:])
            return logits[:, 0], cache
        if is_vlm:
            raise NotImplementedError(
                "bucketed (prompt_lens) prefill is text-LM only; VLM "
                "prompts carry a fixed patch prefix")
        B = x.shape[0]
        pos = jnp.broadcast_to(jnp.arange(S_total), (B, S_total))
        pos = jnp.where(pos < prompt_lens[:, None], pos, -1)
        h, _, cache = tfm.forward_hidden(
            params, cfg, x, positions=pos, mask_fn=mask_fn, shard=shard,
            remat=False, collect_cache=True, cache_len=cache_len)
        last = jnp.clip(prompt_lens - 1, 0, S_total - 1)
        h_last = jnp.take_along_axis(
            h, last[:, None, None].astype(jnp.int32), axis=1)
        logits = tfm.unembed(params, cfg, h_last)
        return logits[:, 0], cache

    def decode(params, cache, tokens, shard=IDENTITY_SHARDER):
        """tokens: (B,) next input token ids."""
        x_t = tfm.embed_tokens(params, cfg, tokens[:, None])
        h, cache = tfm.decode_step(params, cfg, x_t, cache, shard=shard,
                                   mask_fn=mask_fn)
        logits = tfm.unembed(params, cfg, h)
        return logits[:, 0], cache

    def init_cache(batch_size: int, cache_len: int, dtype=None):
        return tfm.init_cache(cfg, batch_size, cache_len, dtype=dtype)

    def input_specs(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            specs = {
                "tokens": jax.ShapeDtypeStruct((B, S - P), i32),
                "loss_mask": jax.ShapeDtypeStruct((B, S - P), jnp.float32),
                "weights": jax.ShapeDtypeStruct((B,), jnp.float32),
            }
        elif shape.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((B, S - P), i32)}
        else:  # decode
            specs = {"tokens": jax.ShapeDtypeStruct((B,), i32)}
        if is_vlm and shape.kind != "decode":
            specs["patches"] = jax.ShapeDtypeStruct(
                (B, P, cfg.d_model), jnp.float32)
        return specs

    def make_batch(key, B: int, S: int) -> Batch:
        ks = jax.random.split(key, 3)
        batch = {
            "tokens": jax.random.randint(ks[0], (B, S - P), 0, cfg.vocab_size),
            "loss_mask": jnp.ones((B, S - P), jnp.float32),
            "weights": jnp.ones((B,), jnp.float32),
        }
        if is_vlm:
            batch["patches"] = jax.random.normal(
                ks[1], (B, P, cfg.d_model), jnp.float32)
        return batch

    return ModelBundle(
        cfg=cfg,
        init_params=lambda key: tfm.init_params(cfg, key),
        per_example_loss=per_example_loss,
        loss_fn=loss_fn,
        final_hidden=hidden,
        head_weight=head_weight,
        prefill=prefill,
        decode=decode,
        init_cache=init_cache,
        input_specs=input_specs,
        make_batch=make_batch,
    )


# ===========================================================================
# Encoder-decoder (seamless-m4t)
# ===========================================================================

def _build_encdec(cfg: ModelConfig) -> ModelBundle:

    def hidden(params, batch, shard=IDENTITY_SHARDER, remat=True):
        enc = encdec_mod.encode(params, cfg, batch["frames"], shard=shard,
                                remat=remat)
        dec_in = batch["tokens"][:, :-1]
        targets = batch["tokens"][:, 1:]
        mask = batch.get("loss_mask")
        mask = (jnp.ones_like(targets, jnp.float32) if mask is None
                else mask[:, 1:].astype(jnp.float32))
        h, _ = encdec_mod.decode_train(params, cfg, dec_in, enc, shard=shard,
                                       remat=remat)
        return h, targets, mask, jnp.zeros((), jnp.float32)

    def per_example_loss(params, batch, shard=IDENTITY_SHARDER, remat=True):
        h, targets, mask, _ = hidden(params, batch, shard, remat)
        logits = tfm.unembed(params, cfg, h)
        return softmax_xent(logits, targets, mask)

    def loss_fn(params, batch, shard=IDENTITY_SHARDER, remat=True):
        per_ex = per_example_loss(params, batch, shard, remat)
        return _weighted(per_ex, batch, jnp.zeros((), jnp.float32))

    def head_weight(params):
        return (params["embed"]["w"].T if cfg.tie_embeddings
                else params["lm_head"]["w"])

    def prefill(params, batch, shard=IDENTITY_SHARDER, cache_len=None):
        enc = encdec_mod.encode(params, cfg, batch["frames"], shard=shard,
                                remat=False)
        dec_in = batch["tokens"]
        cache_len = cache_len or dec_in.shape[1]
        h, cache = encdec_mod.decode_train(
            params, cfg, dec_in, enc, shard=shard, remat=False,
            collect_cache=True, cache_len=cache_len)
        logits = tfm.unembed(params, cfg, h[:, -1:])
        return logits[:, 0], cache

    def decode(params, cache, tokens, shard=IDENTITY_SHARDER):
        x_t = tfm.embed_tokens(params, cfg, tokens[:, None])
        h, cache = encdec_mod.decode_step(params, cfg, x_t, cache, shard=shard)
        logits = tfm.unembed(params, cfg, h)
        return logits[:, 0], cache

    def init_cache(batch_size: int, cache_len: int, dtype=None,
                   src_len: Optional[int] = None):
        dtype = jnp.dtype(cfg.compute_dtype) if dtype is None else dtype
        from repro.models.attention import init_kv_cache
        L = cfg.n_layers
        src_len = src_len or cache_len
        one = init_kv_cache(cfg, batch_size, cache_len, window=False,
                            dtype=dtype)
        stack = lambda t: jax.tree.map(
            lambda l: jnp.broadcast_to(l, (L,) + l.shape).copy(), t)
        return {
            "self": stack(one),
            "ck": jnp.zeros((L, batch_size, src_len, cfg.n_kv_heads,
                             cfg.head_dim), dtype),
            "cv": jnp.zeros((L, batch_size, src_len, cfg.n_kv_heads,
                             cfg.head_dim), dtype),
        }

    def input_specs(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        T_src, U = S // 2, S // 2
        i32 = jnp.int32
        if shape.kind == "train":
            return {
                "frames": jax.ShapeDtypeStruct((B, T_src, cfg.d_model),
                                               jnp.float32),
                "tokens": jax.ShapeDtypeStruct((B, U), i32),
                "loss_mask": jax.ShapeDtypeStruct((B, U), jnp.float32),
                "weights": jax.ShapeDtypeStruct((B,), jnp.float32),
            }
        if shape.kind == "prefill":
            return {
                "frames": jax.ShapeDtypeStruct((B, T_src, cfg.d_model),
                                               jnp.float32),
                "tokens": jax.ShapeDtypeStruct((B, U), i32),
            }
        return {"tokens": jax.ShapeDtypeStruct((B,), i32)}

    def make_batch(key, B: int, S: int) -> Batch:
        ks = jax.random.split(key, 2)
        T_src, U = max(S // 2, 4), max(S // 2, 4)
        return {
            "frames": jax.random.normal(ks[0], (B, T_src, cfg.d_model)),
            "tokens": jax.random.randint(ks[1], (B, U), 0, cfg.vocab_size),
            "loss_mask": jnp.ones((B, U), jnp.float32),
            "weights": jnp.ones((B,), jnp.float32),
        }

    return ModelBundle(
        cfg=cfg,
        init_params=lambda key: encdec_mod.init_params(cfg, key),
        per_example_loss=per_example_loss,
        loss_fn=loss_fn,
        final_hidden=hidden,
        head_weight=head_weight,
        prefill=prefill,
        decode=decode,
        init_cache=init_cache,
        input_specs=input_specs,
        make_batch=make_batch,
    )


# ===========================================================================
# RNN-T (the paper's architecture)
# ===========================================================================

def _build_rnnt(cfg: ModelConfig) -> ModelBundle:
    r = cfg.rnnt
    if r.loss_impl not in ("fused", "dense"):
        raise ValueError(f"rnnt.loss_impl must be 'fused' or 'dense', "
                         f"got {r.loss_impl!r}")

    def _t_lens(batch):
        return jnp.maximum(batch["feat_lens"] // r.time_reduction, 1)

    def per_example_nll(params, batch, shard=IDENTITY_SHARDER):
        """Per-example transducer NLL, path keyed by ``r.loss_impl``
        (DESIGN.md §2): ``fused`` streams the joint inside a custom_vjp
        (no (B,T,U+1,V) tensor, analytic gradients); ``dense`` is the
        materialized autodiff parity oracle.  The joint factors are
        pinned with ``shard(..., "act_bsd")`` (batch over data,
        replicated elsewhere) — on a mesh this anchors GSPMD's
        propagation at the custom_vjp boundary, which XLA:CPU SPMD
        otherwise mispartitions through the CRDNN encoder (wrong
        *values*, not just reordering; see tests/test_sharded_engine.py)."""
        if r.loss_impl == "fused":
            ze, zp = rnnt_mod.joint_factors(params, cfg, batch["feats"],
                                            batch["tokens"])
            ze = shard(ze, "act_bsd")
            zp = shard(zp, "act_bsd")
            return rnnt_loss_fused(
                ze, zp, params["joint"]["w_out"], batch["tokens"],
                _t_lens(batch), batch["token_lens"],
                vocab_chunk=r.loss_vocab_chunk)
        logits = rnnt_mod.forward(params, cfg, batch["feats"], batch["tokens"])
        return rnnt_loss_from_logits(logits, batch["tokens"], _t_lens(batch),
                                     batch["token_lens"])

    def per_example_loss(params, batch, shard=IDENTITY_SHARDER, remat=True):
        return per_example_nll(params, batch, shard) \
            / jnp.maximum(batch["token_lens"].astype(jnp.float32), 1.0)

    def loss_fn(params, batch, shard=IDENTITY_SHARDER, remat=True):
        per_ex = per_example_loss(params, batch, shard, remat)
        return _weighted(per_ex, batch, jnp.zeros((), jnp.float32))

    def hidden(params, batch, shard=IDENTITY_SHARDER, remat=True):
        """PGM hook: joint pre-vocab activations + what's needed for the
        loss-to-logits error signal."""
        enc = rnnt_mod.encode(params, cfg, batch["feats"])
        pred = rnnt_mod.predict(params, cfg, batch["tokens"])
        z = rnnt_mod.joint_hidden(params, enc, pred)
        return z, batch["tokens"], None, jnp.zeros((), jnp.float32)

    def head_weight(params):
        return params["joint"]["w_out"]

    def input_specs(shape: ShapeConfig):
        B = shape.global_batch
        T = shape.seq_len // 8          # audio frames per "token budget"
        U = shape.seq_len // 32
        return {
            "feats": jax.ShapeDtypeStruct((B, T, r.n_feats), jnp.float32),
            "feat_lens": jax.ShapeDtypeStruct((B,), jnp.int32),
            "tokens": jax.ShapeDtypeStruct((B, U), jnp.int32),
            "token_lens": jax.ShapeDtypeStruct((B,), jnp.int32),
            "weights": jax.ShapeDtypeStruct((B,), jnp.float32),
        }

    def make_batch(key, B: int, S: int, T: Optional[int] = None,
                   U: Optional[int] = None) -> Batch:
        ks = jax.random.split(key, 2)
        T = T or max(S // 2, 16)
        U = U or max(S // 8, 4)
        return {
            "feats": jax.random.normal(ks[0], (B, T, r.n_feats)),
            "feat_lens": jnp.full((B,), T, jnp.int32),
            "tokens": jax.random.randint(ks[1], (B, U), 1, r.vocab_size),
            "token_lens": jnp.full((B,), U, jnp.int32),
            "weights": jnp.ones((B,), jnp.float32),
        }

    # -- streaming greedy transducer serve hooks (DESIGN.md §4) --------
    # The LM serve contract maps onto the transducer search: "prefill"
    # runs the CRDNN encoder once and seeds the blank-start prediction
    # state; "decode" is one *joint step* — it consumes the previously
    # sampled symbol (blank advances the frame cursor, a label advances
    # the prediction GRU) and returns the next joint logits.  The cache
    # is the per-utterance decode state: the encoder output buffer, the
    # frame cursor/limit, the prediction-net state and the
    # symbols-emitted-this-frame counter (the per-frame emission cap is
    # enforced by forcing blank logits once the cap is hit, which is
    # exactly where the non-streaming reference breaks its inner loop).

    def rnnt_prefill(params, batch, shard=IDENTITY_SHARDER, cache_len=None,
                     max_symbols: int = 8):
        feats = batch["feats"]
        enc = rnnt_mod.encode(params, cfg, feats)
        B, T_enc, _ = enc.shape
        t_len = jnp.minimum(_t_lens(batch), T_enc).astype(jnp.int32)
        g, h = rnnt_mod.pred_start(params, cfg, B, dtype=enc.dtype)
        logits = rnnt_mod.joint_step(params, enc[:, 0], g)
        cache = {
            "enc": enc,
            "t": jnp.zeros((B,), jnp.int32),
            "t_len": t_len,
            "g": g,
            "h": h,
            "syms": jnp.zeros((B,), jnp.int32),
            "max_syms": jnp.full((B,), max_symbols, jnp.int32),
        }
        return logits, cache

    def rnnt_decode(params, cache, tokens, shard=IDENTITY_SHARDER):
        """tokens: (B,) the symbol sampled from the previous logits."""
        blank = tokens == rnnt_mod.BLANK_ID
        g_new, h_new = rnnt_mod.pred_step(params, cfg, tokens, cache["h"])
        g = jnp.where(blank[:, None], cache["g"], g_new)
        h = jnp.where(blank[:, None], cache["h"], h_new)
        t = cache["t"] + blank.astype(jnp.int32)
        syms = jnp.where(blank, 0, cache["syms"] + 1)
        T_enc = cache["enc"].shape[1]
        t_idx = jnp.clip(t, 0, T_enc - 1)
        enc_t = jnp.take_along_axis(
            cache["enc"], t_idx[:, None, None], axis=1)[:, 0]
        logits = rnnt_mod.joint_step(params, enc_t, g)
        # per-frame emission cap: force blank so greedy search advances —
        # the same place the reference inner loop stops (DESIGN.md §4)
        forced = jnp.full_like(logits, -1e30)
        forced = forced.at[:, rnnt_mod.BLANK_ID].set(0.0)
        logits = jnp.where((syms >= cache["max_syms"])[:, None],
                           forced, logits)
        cache = dict(cache, t=t, g=g, h=h, syms=syms)
        return logits, cache

    def rnnt_init_cache(batch_size: int, cache_len: int, dtype=None,
                        max_symbols: int = 8):
        """Zero decode state; ``cache_len`` is the *encoder-frame*
        capacity (audio frames // time_reduction)."""
        dtype = jnp.float32 if dtype is None else dtype
        return {
            "enc": jnp.zeros((batch_size, cache_len, r.dnn_dim), dtype),
            "t": jnp.zeros((batch_size,), jnp.int32),
            "t_len": jnp.zeros((batch_size,), jnp.int32),
            "g": jnp.zeros((batch_size, r.pred_hidden), dtype),
            "h": jnp.zeros((batch_size, r.pred_hidden), dtype),
            "syms": jnp.zeros((batch_size,), jnp.int32),
            "max_syms": jnp.full((batch_size,), max_symbols, jnp.int32),
        }

    return ModelBundle(
        cfg=cfg,
        init_params=lambda key: rnnt_mod.init_params(cfg, key),
        per_example_loss=per_example_loss,
        loss_fn=loss_fn,
        final_hidden=hidden,
        head_weight=head_weight,
        prefill=rnnt_prefill,
        decode=rnnt_decode,
        init_cache=rnnt_init_cache,
        input_specs=input_specs,
        make_batch=make_batch,
    )


# ===========================================================================

def build_model(cfg: ModelConfig) -> ModelBundle:
    if cfg.family == "rnnt":
        return _build_rnnt(cfg)
    if cfg.family == "encdec":
        return _build_encdec(cfg)
    return _build_lm(cfg)
