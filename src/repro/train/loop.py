"""End-to-end training loop implementing paper Algorithm 1 around any
ModelBundle: warm-start on full data, re-selection every R epochs
(PGM or a baseline), weighted mini-batch SGD on the subset, newbob lr
annealing on validation loss, checkpoint/resume, and cost accounting
(the basis of the paper's speedup numbers).

Two execution engines share the selection/annealing/checkpoint logic:

  * ``engine="scan"`` (default) — the device-resident scanned epoch
    engine (train/engine.py): units live on device, each epoch is one
    donated jit(lax.scan) over a precomputed batch plan, validation is
    one vmapped call;
  * ``engine="host"`` — the legacy per-batch host loop, kept as the
    parity oracle (tests/test_train_engine.py proves the two produce
    the same losses and selections).

With ``resident_selection=True`` (and ``method="pgm"``) the selection
rounds also stay on device: stage A runs as one jitted batch-scanned
pass over the resident units via ``core/pgm.ResidentSelector`` instead
of the sequential host-dispatched ``pgm_select`` path (docs/DESIGN.md
§1).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.core import baselines as bl
from repro.core.lastlayer import make_proj_for, units_gradients
from repro.core.metrics import overlap_index
from repro.core.pgm import ResidentSelector, Selection, pgm_select
from repro.data.pipeline import (
    full_iterator,
    subset_iterator,
    unit_durations,
)
from repro.train import checkpoint as ckpt_mod
from repro.train.engine import EpochEngine, make_step_core
from repro.train.optim import NewbobState, make_update_for


@dataclasses.dataclass
class History:
    train_loss: List[float] = dataclasses.field(default_factory=list)
    val_loss: List[float] = dataclasses.field(default_factory=list)
    lr: List[float] = dataclasses.field(default_factory=list)
    selections: List[Dict] = dataclasses.field(default_factory=list)
    cost_units: float = 0.0        # full-epoch-equivalent compute units
    wall_time: float = 0.0
    final_params: Any = None


def make_train_step(bundle, cfg: TrainConfig):
    return jax.jit(make_step_core(bundle, cfg))


def make_eval(bundle):
    @jax.jit
    def ev(params, batch):
        return bundle.per_example_loss(params, batch).mean()
    return ev


def _select(method, bundle, params, units, tc: TrainConfig, key, proj,
            val_units, durations, mesh=None, data_axis: str = "data",
            resident: Optional[ResidentSelector] = None):
    pc = tc.pgm
    n_units = jax.tree.leaves(units)[0].shape[0]
    budget = max(int(pc.subset_fraction * n_units), 1)
    if method == "pgm":
        if resident is not None:
            return resident(params, units, val_units=val_units)
        return pgm_select(bundle, params, units, pc, proj,
                          val_units=val_units, mesh=mesh, data_axis=data_axis)
    if method == "random":
        return bl.random_subset(key, n_units, budget)
    if method == "large_only":
        return bl.large_only(jnp.asarray(durations), budget)
    if method == "large_small":
        return bl.large_small(jnp.asarray(durations), budget)
    if method == "gradmatch_pb":
        g = units_gradients(bundle, params, units, proj,
                            exact=not pc.use_sketch)
        g_val = None
        if pc.val_matching:
            gv = units_gradients(bundle, params, val_units, proj,
                                 exact=not pc.use_sketch)
            g_val = gv.mean(axis=0) * float(n_units)
        return bl.gradmatch_pb(g, budget, pc.lam, pc.eps, pc.nonneg_weights,
                               g_val=g_val)
    raise ValueError(method)


def train_with_selection(
    bundle,
    units: Dict[str, np.ndarray],
    tc: TrainConfig,
    *,
    method: str = "pgm",            # pgm|random|large_only|large_small|
                                    # gradmatch_pb|full
    val_units=None,
    key=None,
    batch_units: int = 1,
    ckpt_dir: Optional[str] = None,
    resume: bool = False,
    engine: str = "scan",           # scan (device-resident) | host (legacy)
    resident_selection: bool = False,   # PGM stage A on the resident units
    mesh=None,                      # route PGM stage B via shard_map
    data_axis: str = "data",
    log_fn: Callable[[str], None] = lambda s: None,
) -> History:
    if engine not in ("scan", "host"):
        raise ValueError(f"unknown engine {engine!r}")
    key = jax.random.PRNGKey(tc.seed) if key is None else key
    params = bundle.init_params(key)
    opt_init, _ = make_update_for(tc)
    opt_state = opt_init(params)
    scan_engine: Optional[EpochEngine] = None
    if engine == "scan":
        scan_engine = EpochEngine(bundle, tc, units, val_units=val_units,
                                  batch_units=batch_units)
        units_dev = scan_engine.units
        val_dev = scan_engine.val_units
        step_fn = eval_fn = None
    else:
        step_fn = make_train_step(bundle, tc)
        eval_fn = make_eval(bundle)
        units_dev = {k: jnp.asarray(v) for k, v in units.items()}
        val_dev = (None if val_units is None
                   else {k: jnp.asarray(v) for k, v in val_units.items()})
    durations = unit_durations(units)
    proj = make_proj_for(bundle, jax.random.fold_in(key, 17),
                         tc.pgm.sketch_dim_h, tc.pgm.sketch_dim_v)
    # resident rounds: stage A is one jitted batch-scanned pass over the
    # device-resident units; the selector caches its executable (and the
    # projections, closed over the jit) across rounds
    resident = (ResidentSelector(bundle, tc.pgm, proj, mesh=mesh,
                                 data_axis=data_axis)
                if resident_selection and method == "pgm" else None)

    hist = History()
    newbob = NewbobState(tc.lr)
    selection: Optional[Selection] = None
    start_epoch = 0
    if resume and ckpt_dir and ckpt_mod.latest_step(ckpt_dir) is not None:
        tmpl = {"params": params, "opt": opt_state}
        loaded, manifest = ckpt_mod.restore(ckpt_dir, template=tmpl)
        params, opt_state = loaded["params"], loaded["opt"]
        start_epoch = manifest["extra"]["epoch"] + 1
        newbob = NewbobState(manifest["extra"]["lr"],
                             manifest["extra"]["prev_loss"])
        if manifest["extra"].get("sel_indices") is not None:
            sel_idx = manifest["extra"]["sel_indices"]
            selection = Selection(
                jnp.asarray(sel_idx, jnp.int32),
                jnp.asarray(manifest["extra"]["sel_weights"], jnp.float32),
                jnp.asarray(sum(1 for i in sel_idx if i >= 0)),
                jnp.zeros((1,)))
        log_fn(f"resumed at epoch {start_epoch}")

    t0 = time.time()
    n_units = jax.tree.leaves(units_dev)[0].shape[0]
    for epoch in range(start_epoch, tc.epochs):
        use_full = method == "full" or epoch < tc.pgm.warm_start_epochs
        # --- selection round ---
        if not use_full and (
                selection is None
                or (epoch - tc.pgm.warm_start_epochs) % tc.pgm.select_every == 0):
            sel_key = jax.random.fold_in(key, 1000 + epoch)
            new_sel = _select(method, bundle, params, units_dev, tc, sel_key,
                              proj, val_dev, durations, mesh=mesh,
                              data_axis=data_axis, resident=resident)
            oi = (overlap_index(np.asarray(selection.indices),
                                np.asarray(new_sel.indices))
                  if selection is not None else float("nan"))
            selection = new_sel
            # selection cost: one grad-rep pass over all units ~ 1/3 epoch
            sel_cost = (1.0 / 3.0 if method in ("pgm", "gradmatch_pb")
                        else 0.0)
            hist.cost_units += sel_cost
            hist.selections.append({
                "epoch": epoch,
                "indices": np.asarray(selection.indices).tolist(),
                "weights": np.asarray(selection.weights).tolist(),
                "overlap_index": oi,
            })
            log_fn(f"epoch {epoch}: selected {int(selection.n_selected)} "
                   f"units (OI={oi:.3f})")

        # --- epoch of SGD ---
        if scan_engine is not None:
            plan = (scan_engine.full_plan(epoch) if use_full else
                    scan_engine.subset_plan(selection.indices,
                                            selection.weights, epoch))
            # charge what the padded scan actually executes (bucketed step
            # count — padding rows run a full step before being gated), so
            # cost_units stays an honest compute measure
            hist.cost_units += (plan[0].shape[0]
                                / scan_engine.steps_per_epoch_max)
        elif use_full:
            hist.cost_units += 1.0
        else:
            hist.cost_units += float(int(selection.n_selected)) / n_units
        if scan_engine is not None:
            params, opt_state, step_losses = scan_engine.run_epoch(
                params, opt_state, newbob.lr, plan)
            # subset plans are padded to a fixed shape for retrace-freedom;
            # weight-0 padding steps must not contribute to the epoch mean
            live = scan_engine.plan_live_steps(plan)
            losses = np.asarray(step_losses, np.float64)[live]
            train_loss = float(losses.mean()) if losses.size else float("nan")
        else:
            it = (full_iterator(units, tc.seed, epoch, batch_units)
                  if use_full else
                  subset_iterator(units, np.asarray(selection.indices),
                                  np.asarray(selection.weights),
                                  tc.seed, epoch, batch_units))
            losses = []
            for batch in it:
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                params, opt_state, metrics = step_fn(params, opt_state, batch,
                                                     newbob.lr)
                losses.append(float(metrics["loss"]))
            train_loss = float(np.mean(losses)) if losses else float("nan")

        # --- validation + newbob ---
        if val_dev is not None:
            if scan_engine is not None:
                vl = scan_engine.validate(params)
            else:
                vl = float(np.mean([
                    float(eval_fn(params,
                                  {k: v[i] for k, v in val_dev.items()}))
                    for i in range(jax.tree.leaves(val_dev)[0].shape[0])]))
            newbob = newbob.update(vl, tc.anneal_factor,
                                   tc.improvement_threshold)
        else:
            vl = float("nan")
        hist.train_loss.append(train_loss)
        hist.val_loss.append(vl)
        hist.lr.append(newbob.lr)
        log_fn(f"epoch {epoch}: train {train_loss:.4f} val {vl:.4f} "
               f"lr {newbob.lr:.4f}")

        if ckpt_dir:
            extra = {"epoch": epoch, "lr": newbob.lr,
                     "prev_loss": newbob.prev_loss,
                     "sel_indices": (np.asarray(selection.indices).tolist()
                                     if selection is not None else None),
                     "sel_weights": (np.asarray(selection.weights).tolist()
                                     if selection is not None else None)}
            ckpt_mod.save(ckpt_dir, epoch,
                          {"params": params, "opt": opt_state}, extra)

    hist.wall_time = time.time() - t0
    hist.final_params = params
    return hist
