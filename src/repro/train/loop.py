"""End-to-end training loop implementing paper Algorithm 1 around any
ModelBundle: warm-start on full data, re-selection every R epochs
(PGM or a baseline), weighted mini-batch SGD on the subset, newbob lr
annealing on validation loss, checkpoint/resume, and cost accounting
(the basis of the paper's speedup numbers).

Execution is delegated to one engine interface
(``train/engine.py:make_engine``) with selection/annealing/checkpoint
logic shared above it:

  * ``engine="scan"`` (default) — the device-resident scanned epoch
    engine: units live on device, each epoch is one donated
    jit(lax.scan) over a precomputed batch plan, validation is one
    vmapped call.  With ``mesh`` the same executable compiles
    mesh-natively (FSDP/TP carry, data-sharded batches/units,
    DESIGN.md §5).
  * ``engine="host"`` — the legacy per-batch host loop, kept as the
    parity oracle (tests/test_train_engine.py proves the two produce
    the same losses and selections).

``epoch_chunk > 1`` folds up to that many consecutive epochs into one
``run_epochs`` dispatch (scan engine only): validation and the newbob
update run on device inside the chunk and metrics are fetched once per
chunk, so selection rounds (and checkpoint writes, once per chunk) are
the only host sync points.  ``plan_prefetch`` (default on for the scan
engine) builds the next plans on a host worker thread
(``data/plan_prefetch.py``) while the current dispatch runs.

With ``resident_selection=True`` (and ``method="pgm"``) the selection
rounds also stay on device: stage A runs as one jitted batch-scanned
pass over the engine's resident units — sharded over ``data`` when the
engine placed them on a mesh — via ``core/pgm.ResidentSelector``
instead of the sequential host-dispatched ``pgm_select`` path
(docs/DESIGN.md §1).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.core import baselines as bl
from repro.core.lastlayer import make_proj_for, units_gradients
from repro.core.metrics import overlap_index
from repro.core.pgm import ResidentSelector, Selection, pgm_select
from repro.data.pipeline import unit_durations
from repro.data.plan_prefetch import PlanPrefetcher
from repro.train import checkpoint as ckpt_mod
from repro.train import faults as faults_mod
from repro.train.engine import EpochEngine, make_engine, make_step_core
from repro.train.optim import NewbobState, make_update_for


@dataclasses.dataclass
class History:
    train_loss: List[float] = dataclasses.field(default_factory=list)
    val_loss: List[float] = dataclasses.field(default_factory=list)
    lr: List[float] = dataclasses.field(default_factory=list)
    selections: List[Dict] = dataclasses.field(default_factory=list)
    cost_units: float = 0.0        # full-epoch-equivalent compute units
    wall_time: float = 0.0
    final_params: Any = None
    skipped_steps: int = 0         # non-finite steps gated off on device
    rollbacks: int = 0             # divergence-watchdog restores
    preempted: bool = False        # exited early on SIGTERM/SIGINT


def _max_consecutive(mask: np.ndarray) -> int:
    best = cur = 0
    for v in mask:
        cur = cur + 1 if v else 0
        best = max(best, cur)
    return best


def make_train_step(bundle, cfg: TrainConfig):
    return jax.jit(make_step_core(bundle, cfg))


def make_eval(bundle):
    @jax.jit
    def ev(params, batch):
        return bundle.per_example_loss(params, batch).mean()
    return ev


def _select(method, bundle, params, units, tc: TrainConfig, key, proj,
            val_units, durations, mesh=None, data_axis: str = "data",
            resident: Optional[ResidentSelector] = None):
    pc = tc.pgm
    n_units = jax.tree.leaves(units)[0].shape[0]
    budget = max(int(pc.subset_fraction * n_units), 1)
    if method == "pgm":
        if resident is not None:
            return resident(params, units, val_units=val_units)
        return pgm_select(bundle, params, units, pc, proj,
                          val_units=val_units, mesh=mesh, data_axis=data_axis)
    if method == "random":
        return bl.random_subset(key, n_units, budget)
    if method == "large_only":
        return bl.large_only(jnp.asarray(durations), budget)
    if method == "large_small":
        return bl.large_small(jnp.asarray(durations), budget)
    if method == "gradmatch_pb":
        g = units_gradients(bundle, params, units, proj,
                            exact=not pc.use_sketch)
        g_val = None
        if pc.val_matching:
            gv = units_gradients(bundle, params, val_units, proj,
                                 exact=not pc.use_sketch)
            g_val = gv.mean(axis=0) * float(n_units)
        return bl.gradmatch_pb(g, budget, pc.lam, pc.eps, pc.nonneg_weights,
                               g_val=g_val)
    raise ValueError(method)


def train_with_selection(
    bundle,
    units: Dict[str, np.ndarray],
    tc: TrainConfig,
    *,
    method: str = "pgm",            # pgm|random|large_only|large_small|
                                    # gradmatch_pb|full
    val_units=None,
    key=None,
    batch_units: int = 1,
    ckpt_dir: Optional[str] = None,
    resume: bool = False,
    engine: str = "scan",           # scan (device-resident) | host (legacy)
    resident_selection: bool = False,   # PGM stage A on the resident units
    mesh=None,                      # shard training + selection on a mesh
    data_axis: str = "data",
    spec_mode: str = "tp",          # SpecBuilder param-sharding policy
    epoch_chunk: int = 1,           # epochs folded into one scan dispatch
    plan_prefetch: bool = True,     # build next plans on a host thread
    fault_plan: Optional["faults_mod.FaultPlan"] = None,  # chaos harness
    log_fn: Callable[[str], None] = lambda s: None,
) -> History:
    eng = make_engine(engine, bundle, tc, units, val_units=val_units,
                      batch_units=batch_units, mesh=mesh,
                      data_axis=data_axis, spec_mode=spec_mode)
    # the engine may rebuild the bundle at construction (RNN-T
    # loss_vocab_chunk auto-tune); train and select on the tuned one
    bundle = getattr(eng, "bundle", bundle)
    is_scan = isinstance(eng, EpochEngine)
    key = jax.random.PRNGKey(tc.seed) if key is None else key
    params = bundle.init_params(key)
    opt_init, _ = make_update_for(tc)
    opt_state = opt_init(params)
    # bring the donated carry onto the mesh (identity without one)
    params, opt_state = eng.shard_state(params, opt_state)
    units_dev = eng.units
    val_dev = eng.val_units
    durations = unit_durations({k: np.asarray(v) for k, v in units.items()})
    proj = make_proj_for(bundle, jax.random.fold_in(key, 17),
                         tc.pgm.sketch_dim_h, tc.pgm.sketch_dim_v)
    # resident rounds: stage A is one jitted batch-scanned pass over the
    # device-resident units (data-sharded with a mesh); the selector
    # caches its executable (and the projections, closed over the jit)
    # across rounds
    resident = (ResidentSelector(bundle, tc.pgm, proj, mesh=mesh,
                                 data_axis=data_axis, log_fn=log_fn)
                if resident_selection and method == "pgm" else None)

    hist = History()
    newbob = NewbobState(tc.lr)
    selection: Optional[Selection] = None
    start_epoch = 0
    mesh_shape = (dict(zip(mesh.axis_names, mesh.devices.shape))
                  if mesh is not None else None)
    # pod-axis compression: per-pod top-k error-feedback residuals ride
    # the checkpoint tree (key "err") so a resumed run continues from the
    # exact residuals, not fresh zeros (DESIGN.md §5)
    uses_err = getattr(eng, "uses_error_feedback", False)
    # pod-mode engines record their compressor in every manifest (also
    # for the stateless none/bf16 modes), so a resume under a different
    # mode is flagged and a same-mode resume stays silent
    pod_mode = getattr(eng, "pod_axis", None) is not None
    guard_on = bool(getattr(tc, "nonfinite_guard", False))

    def _ckpt_template_fn(manifest):
        # a checkpoint written without error-feedback state (different
        # compress_mode) must restore gracefully with fresh zero
        # residuals, not KeyError on a template leaf the archive never
        # had; shapes/dtypes only — restore replaces every leaf from the
        # archive, so don't allocate a device-resident zero tree
        tmpl = {"params": params, "opt": opt_state}
        if uses_err and any("'err'" in k for k in manifest["arrays"]):
            tmpl["err"] = jax.eval_shape(eng.init_compress_state, params)
        return tmpl

    def _restore_newest():
        """State from the newest checkpoint that passes checksum
        verification — a corrupt latest falls back to the previous
        intact step (DESIGN.md §10).  Returns
        ``(params, opt_state, newbob, selection, next_epoch)``."""
        loaded, manifest = ckpt_mod.restore_latest_intact(
            ckpt_dir, template_fn=_ckpt_template_fn,
            sharding_fn=eng.restore_sharding, log_fn=log_fn)
        p, o = loaded["params"], loaded["opt"]
        if uses_err:
            if "err" in loaded:
                eng.compress_state = loaded["err"]
            else:
                eng.compress_state = None
                log_fn("warning: no error-feedback state in checkpoint; "
                       "top-k residuals restart from zero")
        saved_cm = manifest.get("compress_mode")
        if (saved_cm or "none") != tc.compress_mode:
            log_fn(f"warning: checkpoint was written with compress_mode="
                   f"{saved_cm or 'none'!r}, resuming with "
                   f"{tc.compress_mode!r}")
        nb = NewbobState(manifest["extra"]["lr"],
                         manifest["extra"]["prev_loss"])
        sel = None
        if manifest["extra"].get("sel_indices") is not None:
            sel_idx = manifest["extra"]["sel_indices"]
            sel = Selection(
                jnp.asarray(sel_idx, jnp.int32),
                jnp.asarray(manifest["extra"]["sel_weights"], jnp.float32),
                jnp.asarray(sum(1 for i in sel_idx if i >= 0)),
                jnp.zeros((1,)))
        saved_mesh = manifest.get("mesh_shape")
        if saved_mesh != mesh_shape:
            log_fn(f"resharded checkpoint (saved mesh {saved_mesh} -> "
                   f"current {mesh_shape})")
        return p, o, nb, sel, manifest["extra"]["epoch"] + 1

    if resume and ckpt_dir and ckpt_mod.latest_step(ckpt_dir) is not None:
        params, opt_state, newbob, selection, start_epoch = _restore_newest()
        log_fn(f"resumed at epoch {start_epoch}")

    warm = tc.pgm.warm_start_epochs
    R = tc.pgm.select_every
    prefetcher = (PlanPrefetcher(max_pending=max(2, epoch_chunk))
                  if plan_prefetch and is_scan else None)
    sel_round = 0          # prefetch key component: one per selection
    writer = ckpt_mod.AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    preempt = faults_mod.PreemptionHandler(log_fn=log_fn).install()

    def _use_full(e: int) -> bool:
        return method == "full" or e < warm

    def _is_sel_epoch(e: int) -> bool:
        return not _use_full(e) and (e - warm) % R == 0

    def _plan_builder(e: int, sel: Optional[Selection]):
        if _use_full(e):
            base = lambda: eng.full_plan(e)
        else:
            idx, w = sel.indices, sel.weights
            base = lambda: eng.subset_plan(idx, w, e)
        if fault_plan is None:
            return base

        def build():
            fault_plan.maybe_fail_prefetch(e)
            return fault_plan.poison_plan(e, base())
        return build

    def _plan_key(e: int, rnd: int):
        # the watchdog re-keys plans by bumping the engine's plan_salt;
        # keys must carry it so stale pending plans never resolve
        salt = getattr(eng, "plan_salt", 0)
        return (("full", salt, e) if _use_full(e)
                else ("subset", salt, rnd, e))

    def _get_plan(e: int):
        build = _plan_builder(e, selection)
        if prefetcher is None:
            return build()
        return prefetcher.get(_plan_key(e, sel_round), build)

    t0 = time.time()
    try:
        epoch = start_epoch
        while epoch < tc.epochs:
            use_full = _use_full(epoch)
            # --- selection round (the host sync point) ---
            if not use_full and (selection is None or _is_sel_epoch(epoch)):
                sel_key = jax.random.fold_in(key, 1000 + epoch)
                new_sel = _select(method, bundle, params, units_dev, tc,
                                  sel_key, proj, val_dev, durations,
                                  mesh=mesh, data_axis=data_axis,
                                  resident=resident)
                oi = (overlap_index(np.asarray(selection.indices),
                                    np.asarray(new_sel.indices))
                      if selection is not None else float("nan"))
                selection = new_sel
                sel_round += 1
                if prefetcher is not None:
                    # keys change with the selection round: drop any
                    # pending plans so they can't pin buffer slots
                    prefetcher.invalidate()
                # selection cost: one grad-rep pass over all units ~ 1/3
                # epoch
                sel_cost = (1.0 / 3.0 if method in ("pgm", "gradmatch_pb")
                            else 0.0)
                hist.cost_units += sel_cost
                hist.selections.append({
                    "epoch": epoch,
                    "indices": np.asarray(selection.indices).tolist(),
                    "weights": np.asarray(selection.weights).tolist(),
                    "overlap_index": oi,
                })
                log_fn(f"epoch {epoch}: selected "
                       f"{int(selection.n_selected)} units (OI={oi:.3f})")

            # --- chunk of SGD epochs sharing this selection context ---
            if method == "full":
                boundary = tc.epochs
            elif epoch < warm:
                boundary = warm
            else:
                boundary = warm + ((epoch - warm) // R + 1) * R
            boundary = min(boundary, tc.epochs)
            chunk = (max(1, min(epoch_chunk, boundary - epoch))
                     if is_scan else 1)
            chunk_epochs = list(range(epoch, epoch + chunk))
            plans = [_get_plan(e) for e in chunk_epochs]
            # overlap the next dispatch: every later epoch whose selection
            # context is already decided (same selection, or a full plan)
            # can be built on the prefetch thread right now
            if prefetcher is not None:
                e_next = epoch + chunk
                while e_next < tc.epochs and not _is_sel_epoch(e_next):
                    if not prefetcher.schedule(
                            _plan_key(e_next, sel_round),
                            _plan_builder(e_next, selection)):
                        break
                    e_next += 1

            n_sel = (int(selection.n_selected)
                     if selection is not None else None)
            for p in plans:
                hist.cost_units += eng.epoch_cost(p, use_full=use_full,
                                                  n_selected=n_sel)
            if epoch_chunk == 1 or not is_scan:
                # per-epoch dispatch: validate + newbob on host (legacy
                # numerics — the parity-oracle path).  Keyed off the
                # *requested* chunk size, not this chunk's length, so a
                # chunked run uses one newbob implementation (the fp32
                # device one) everywhere — the anneal schedule stays a
                # pure function of the config even when boundaries leave
                # size-1 chunks
                params, opt_state, step_losses = eng.run_epoch(
                    params, opt_state, newbob.lr, plans[0])
                live = eng.plan_live_steps(plans[0])
                losses = np.asarray(step_losses, np.float64)[live]
                train_losses = [float(losses.mean()) if losses.size
                                else float("nan")]
                has_live = [losses.size > 0]
                if val_dev is not None:
                    vl = eng.validate(params)
                    newbob = newbob.update(vl, tc.anneal_factor,
                                           tc.improvement_threshold)
                else:
                    vl = float("nan")
                val_losses, lrs = [vl], [newbob.lr]
            else:
                # chunked dispatch: epochs, validations and newbob updates
                # all on device; one host fetch for the whole chunk
                (params, opt_state, step_losses, vls, lrs_dev, lr_out,
                 prev_out) = eng.run_epochs(params, opt_state, newbob.lr,
                                            newbob.prev_loss, plans)
                step_losses = np.asarray(step_losses, np.float64)
                train_losses = []
                has_live = []
                for i, p in enumerate(plans):
                    live = eng.plan_live_steps(p)
                    l = step_losses[i][live]
                    train_losses.append(float(l.mean()) if l.size
                                        else float("nan"))
                    has_live.append(l.size > 0)
                val_losses = [float(v) for v in np.asarray(vls)]
                lrs = [float(v) for v in np.asarray(lrs_dev)]
                newbob = NewbobState(float(lr_out), float(prev_out))

            # --- divergence watchdog (DESIGN.md §10) ---
            if guard_on:
                skm = (np.asarray(eng.last_skipped).reshape(-1) > 0.5
                       if eng.last_skipped is not None
                       else np.zeros(0, bool))
                n_sk = int(skm.sum())
                hist.skipped_steps += n_sk
                if n_sk:
                    log_fn(f"guard: skipped {n_sk} non-finite step(s) in "
                           f"epochs {chunk_epochs[0]}..{chunk_epochs[-1]}")
                bad_train = any(not np.isfinite(tl) for tl, h
                                in zip(train_losses, has_live) if h)
                bad_val = (val_dev is not None
                           and any(not np.isfinite(v) for v in val_losses))
                K = int(getattr(tc, "max_skipped_steps", 0) or 0)
                consec = _max_consecutive(skm)
                if (K > 0 and consec >= K) or bad_train or bad_val:
                    hist.rollbacks += 1
                    if hist.rollbacks > 3:
                        raise RuntimeError(
                            "divergence watchdog: giving up after 3 "
                            "rollbacks")
                    reason = (f"{consec} consecutive skipped steps"
                              if K > 0 and consec >= K
                              else "non-finite loss")
                    log_fn(f"watchdog: {reason} in epochs "
                           f"{chunk_epochs[0]}..{chunk_epochs[-1]}; "
                           f"rolling back with a re-keyed batch plan")
                    if writer is not None:
                        try:
                            writer.wait()
                        except BaseException as e:
                            log_fn(f"warning: async checkpoint write "
                                   f"failed: {e}")
                    eng.plan_salt = getattr(eng, "plan_salt", 0) + 1
                    sel_round += 1
                    if prefetcher is not None:
                        prefetcher.invalidate()
                    if (ckpt_dir
                            and ckpt_mod.latest_step(ckpt_dir) is not None):
                        (params, opt_state, newbob, selection,
                         epoch) = _restore_newest()
                        log_fn(f"watchdog: rolled back to epoch {epoch}")
                    else:
                        key = jax.random.fold_in(key,
                                                 7919 + hist.rollbacks)
                        params = bundle.init_params(key)
                        opt_state = opt_init(params)
                        params, opt_state = eng.shard_state(params,
                                                            opt_state)
                        if uses_err:
                            eng.compress_state = None
                        newbob = NewbobState(tc.lr)
                        selection = None
                        epoch = 0
                        log_fn("watchdog: no checkpoint; restarting from "
                               "re-initialised state")
                    continue

            for e, tl, vl, lr in zip(chunk_epochs, train_losses,
                                     val_losses, lrs):
                hist.train_loss.append(tl)
                hist.val_loss.append(vl)
                hist.lr.append(lr)
                log_fn(f"epoch {e}: train {tl:.4f} val {vl:.4f} "
                       f"lr {lr:.4f}")

            if fault_plan is not None:
                fault_plan.maybe_preempt(chunk_epochs[-1])
            preempted = preempt.triggered
            if ckpt_dir:
                extra = {"epoch": chunk_epochs[-1], "lr": newbob.lr,
                         "prev_loss": newbob.prev_loss,
                         "sel_indices": (np.asarray(
                             selection.indices).tolist()
                             if selection is not None else None),
                         "sel_weights": (np.asarray(
                             selection.weights).tolist()
                             if selection is not None else None)}
                if preempted:
                    extra["preempted"] = True
                tree = {"params": params, "opt": opt_state}
                if uses_err:
                    tree["err"] = (eng.compress_state
                                   if eng.compress_state is not None
                                   else eng.init_compress_state(params))
                writer.submit(chunk_epochs[-1], tree, extra,
                              mesh_shape=mesh_shape,
                              compress_mode=(tc.compress_mode if pod_mode
                                             else None))
            if preempted:
                if writer is not None:
                    writer.wait()
                hist.preempted = True
                log_fn(f"preemption: emergency checkpoint at epoch "
                       f"{chunk_epochs[-1]}; exiting resumably")
                break
            epoch += chunk
        if writer is not None:
            writer.wait()    # surface deferred write errors before returning
    finally:
        preempt.uninstall()
        if prefetcher is not None:
            prefetcher.close()
        if writer is not None:
            try:
                writer.close()
            except BaseException as e:
                log_fn(f"warning: checkpoint writer failed on close: {e}")

    hist.wall_time = time.time() - t0
    hist.final_params = params
    return hist
