"""Device-resident scanned epoch engine for Algorithm 1's SGD phase.

The legacy host loop assembles every batch in numpy, copies it to device
and dispatches one jit call per step, then validates one example per
Python iteration.  Here the whole corpus of selection units lives on
device once; an epoch is a single jitted ``lax.scan`` over a precomputed
(seed, epoch)-keyed batch plan (``data/pipeline.epoch_plan`` /
``subset_epoch_plan``), with ``(params, opt_state)`` donated so the
update runs in-place instead of round-tripping buffers.  Weighted-subset
epochs are expressed as index+weight arrays gathered inside jit — no
regenerated host batches — and validation is one vmapped call over the
validation units.

One compiled executable is reused for every epoch with the same step
count (full epochs share one; subset epochs share another as long as the
selection budget is stable), so steady-state epochs pay zero tracing or
host-device transfer beyond the tiny plan arrays.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.data.pipeline import epoch_plan, subset_epoch_plan
from repro.train.optim import clip_by_global_norm, make_update_for


def make_step_core(bundle, cfg: TrainConfig):
    """The un-jitted per-batch SGD update shared by the legacy host loop
    (which jits it per call) and the scanned engine (which embeds it in
    the scan body)."""
    _, opt_update = make_update_for(cfg)

    def step(params, opt_state, batch, lr):
        def loss(p):
            total, metrics = bundle.loss_fn(p, batch)
            return total, metrics

        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        params, opt_state = opt_update(params, grads, opt_state, lr)
        metrics = dict(metrics, grad_norm=gnorm)
        return params, opt_state, metrics

    return step


class EpochEngine:
    """Scanned-epoch executor around a ModelBundle.

    ``units`` (and optional ``val_units``) are moved to device once at
    construction.  ``run_epoch`` consumes a batch plan and returns the
    updated ``(params, opt_state)`` plus per-step losses; ``validate``
    returns the mean per-unit validation loss.  Inputs to ``run_epoch``
    are donated: the caller must treat the passed-in ``params`` /
    ``opt_state`` as consumed and continue with the returned values.
    """

    def __init__(self, bundle, cfg: TrainConfig,
                 units: Dict[str, Any],
                 val_units: Optional[Dict[str, Any]] = None,
                 batch_units: int = 1):
        self.bundle = bundle
        self.cfg = cfg
        self.batch_units = int(batch_units)
        self.units = {k: jnp.asarray(v) for k, v in units.items()}
        self.val_units = (None if val_units is None else
                          {k: jnp.asarray(v) for k, v in val_units.items()})
        self.n_units = int(jax.tree.leaves(self.units)[0].shape[0])
        self.unit_size = int(jax.tree.leaves(self.units)[0].shape[1])
        step_core = make_step_core(bundle, cfg)
        unit_size = self.unit_size

        def run(params, opt_state, units_dev, batch_idx, batch_w, lr):
            def body(carry, xs):
                p, s = carry
                idx, w = xs
                batch = {
                    k: v[idx].reshape((-1,) + v.shape[2:])
                    for k, v in units_dev.items()
                }
                if "weights" in batch:
                    batch = dict(batch, weights=batch["weights"]
                                 * jnp.repeat(w, unit_size))
                p, s, metrics = step_core(p, s, batch, lr)
                return (p, s), metrics["loss"]

            (params, opt_state), losses = jax.lax.scan(
                body, (params, opt_state), (batch_idx, batch_w))
            return params, opt_state, losses

        # donate (params, opt_state): the scan carry re-uses their buffers
        self._run = jax.jit(run, donate_argnums=(0, 1))

        def validate(params, val_dev):
            per_unit = jax.vmap(
                lambda u: bundle.per_example_loss(params, u).mean())(val_dev)
            return per_unit.mean()

        self._validate = jax.jit(validate)

    # ------------------------------------------------------------------
    def full_plan(self, epoch: int) -> Tuple[jax.Array, jax.Array]:
        """(seed, epoch)-keyed full-data plan; unit weights are 1."""
        idx = epoch_plan(self.n_units, self.cfg.seed, epoch, self.batch_units)
        return jnp.asarray(idx), jnp.ones(idx.shape, jnp.float32)

    def subset_plan(self, indices, weights,
                    epoch: int) -> Tuple[jax.Array, jax.Array]:
        idx, w = subset_epoch_plan(np.asarray(indices), np.asarray(weights),
                                   self.cfg.seed, epoch, self.batch_units)
        return jnp.asarray(idx), jnp.asarray(w)

    def run_epoch(self, params, opt_state, lr,
                  plan: Tuple[jax.Array, jax.Array]):
        """One scanned epoch.  Returns (params, opt_state, losses (n_steps,))
        — the passed params/opt_state buffers are donated."""
        batch_idx, batch_w = plan
        return self._run(params, opt_state, self.units, batch_idx, batch_w,
                         jnp.asarray(lr, jnp.float32))

    def validate(self, params) -> float:
        if self.val_units is None:
            return float("nan")
        return float(self._validate(params, self.val_units))
