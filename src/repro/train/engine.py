"""Device-resident scanned epoch engines for Algorithm 1's SGD phase.

Three execution paths live behind one engine interface (``make_engine``,
consumed by ``train/loop.py``):

  * ``HostEngine`` (``engine="host"``) — the legacy per-batch loop: one
    jit call per host-assembled batch, one eval call per validation
    unit.  Kept as the parity oracle.
  * ``EpochEngine`` (``engine="scan"``) — the whole corpus of selection
    units lives on device once; an epoch is a single jitted ``lax.scan``
    over a precomputed (seed, epoch)-keyed batch plan
    (``data/pipeline.epoch_plan`` / ``subset_epoch_plan``), with
    ``(params, opt_state)`` donated so the update runs in-place.
    Weighted-subset epochs are expressed as index+weight arrays gathered
    inside jit; validation is one vmapped call over the validation
    units.
  * ``EpochEngine`` with a ``mesh`` — the *same* scanned epoch compiled
    mesh-natively (DESIGN.md §5): the donated ``(params, opt_state)``
    carry is constrained to ``sharding/specs.py:SpecBuilder`` FSDP/TP
    partition specs, units/batches are sharded over the ``data`` axis,
    and GSPMD inserts the mean-psum of grads/metrics across ``data``
    that the per-shard loss terms require — one code path on 1 and N
    devices, parity-tested by ``tests/test_sharded_engine.py``.

Multi-epoch chunks: ``run_epochs`` folds several bucketed epochs into
one dispatch — an outer ``lax.scan`` over per-epoch plans whose body
runs the epoch, the vmapped validation, and the newbob lr update
entirely on device, so metrics come back to the host once per chunk and
selection rounds are the only host sync points.

Retrace-freedom (DESIGN.md §3): subset plans are padded with weight-0
padding rows (unit id ``-1``) up to a *bucketed* step count — the next
multiple of ``plan_granule`` (1/8 of the full-data step count) — so
selection rounds whose ``n_selected`` lands in the same bucket reuse one
compiled epoch executable, while a subset epoch still executes only
~``n_selected/n_units`` of the full-epoch steps (padding waste is
bounded by one granule, not by the subset fraction).  Padding rows are
bit-exact no-ops: the gather index is clamped, the step runs, and
``optim.gate_step`` selects the old ``(params, opt_state)`` leafwise, so
the padded scan's state matches the unpadded loop's exactly.
Retrace-freedom is asserted by ``tests/test_resident_selection.py`` /
``tests/test_sharded_engine.py`` through the shared compile-counter
contract (``repro.analysis.contracts.track_compiles``), which counts
actual XLA compilations rather than a per-function python side effect.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import TrainConfig
from repro.data.pipeline import epoch_plan, subset_epoch_plan
from repro.train.compress import compressed_psum, init_error_state
from repro.train.optim import (clip_by_global_norm, gate_step,
                               make_update_for)


class PodSpec(NamedTuple):
    """Static description of the two-level ``data x pod`` step
    (DESIGN.md §5): which mesh axis is the slow cross-pod dimension, how
    many pods it has, and which ``train/compress.py`` compressor runs on
    its gradient collective."""

    axis: str          # mesh axis name of the slow cross-pod dimension
    n_pods: int
    mode: str          # none | bf16 | topk (compressed_psum mode)
    k_frac: float      # top-k fraction per leaf (mode == "topk")
    data_axis: str     # fast intra-pod data axis (dense GSPMD psum)
    mesh: Any


def make_step_core(bundle, cfg: TrainConfig, shard=None, pod=None):
    """The un-jitted per-batch SGD update shared by the legacy host loop
    (which jits it per call) and the scanned engines (which embed it in
    the scan body).

    ``step_on`` (optional traced bool scalar) is the padding-batch gate:
    when False the optimizer update is a bit-exact no-op and every metric
    is zeroed (no state advance, no metric contribution); when ``None``
    (host loop — plans it consumes are never padded) no gating ops are
    emitted.

    The loss closure is whatever ``bundle.loss_fn`` resolves to from the
    model config — for RNN-T that is the fused custom_vjp transducer
    loss by default (``cfg.rnnt.loss_impl``, DESIGN.md §2), so the
    scanned epoch's ``value_and_grad`` runs the analytic alpha/beta
    backward with no ``(B, T, U+1, V)`` joint tensor and no per-scan-step
    autodiff residuals; ``loss_impl="dense"`` rebuilds every engine on
    the materialized-joint oracle for parity runs.

    ``shard`` (optional ``Sharder``) is forwarded into the loss for
    activation-sharding constraints; when ``None`` the emitted jaxpr is
    identical to the pre-sharder engine.

    ``pod`` (optional :class:`PodSpec`) switches the step to the
    two-level ``data x pod`` form (DESIGN.md §5): the batch's example
    axis is split into ``n_pods`` equal slices, each pod takes
    ``value_and_grad`` of its *local* weighted loss (rescaled so the pod
    mean of objectives equals the global weighted mean — the loss
    denominator is the weight sum, so per-pod means don't average to the
    global mean without the ``W_k / W`` factor), and the per-pod
    gradients meet in an explicit
    ``train/compress.py:compressed_psum`` over the pod axis — bound here
    by a ``vmap(axis_name=pod.axis, spmd_axis_name=pod.axis)``, which
    GSPMD lowers to a real cross-pod all-reduce while the intra-pod
    example reduction stays a dense GSPMD mean-psum over ``data``.  The
    pod step's signature gains the per-pod error-feedback state:
    ``step(params, opt_state, batch, lr, err, step_on) ->
    (params, opt_state, metrics, err)``; on gated-off padding steps the
    error state is returned bit-identically (``optim.gate_step``).

    Aux losses (e.g. the MoE router load-balance penalty) are computed
    per pod and pod-averaged — the standard data-parallel approximation
    (each replica balances its local sub-batch).  For aux-free families
    (dense LMs, RNN-T) this is exact and ``mode="none"`` stays bit-close
    to the one-level engines; for MoE the load-balance term is nonlinear
    in batch composition, so per-pod aux is a deliberate semantic choice,
    not a parity-preserving identity.

    Non-finite guard (``cfg.nonfinite_guard``, DESIGN.md §10): the step
    additionally checks loss and (clipped) gradients for NaN/Inf in-jit
    and folds the result into the ``step_on`` gate — a poisoned batch
    becomes a bit-exact no-op exactly like a weight-0 padding row (same
    ``gate_step`` select, composing with pod-mode error-feedback
    gating), its metrics are zeroed, and ``metrics["skipped"]`` reports
    whether a *live* step was suppressed.  The check is trace-static:
    guard on/off never retraces within a run, and a guarded run on
    all-finite data is bitwise identical to an unguarded one (the gate
    selects the new state everywhere).
    """
    _, opt_update = make_update_for(cfg)
    guard = bool(getattr(cfg, "nonfinite_guard", False))

    if pod is None:
        def step(params, opt_state, batch, lr, step_on=None):
            def loss(p):
                if shard is None:
                    total, metrics = bundle.loss_fn(p, batch)
                else:
                    total, metrics = bundle.loss_fn(p, batch, shard=shard)
                return total, metrics

            (l, metrics), grads = jax.value_and_grad(loss,
                                                     has_aux=True)(params)
            grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
            if guard:
                # the clip already paid for the global norm: any NaN/Inf
                # in the raw grads poisons the sum-of-squares, so one
                # scalar isfinite replaces a leafwise tree sweep (a
                # finite tree whose norm *overflows* is also gated off —
                # its clip scale would be 0, a degenerate step)
                finite = jnp.isfinite(l) & jnp.isfinite(gnorm)
                ok = finite if step_on is None else step_on & finite
            else:
                ok = step_on
            params, opt_state = opt_update(params, grads, opt_state, lr,
                                           step_on=ok)
            metrics = dict(metrics, grad_norm=gnorm)
            if ok is not None:
                metrics = {k: jnp.where(ok, v, jnp.zeros_like(v))
                           for k, v in metrics.items()}
            if guard:
                live = jnp.bool_(True) if step_on is None else step_on
                metrics["skipped"] = live & ~finite
            return params, opt_state, metrics

        return step

    data_size = pod.mesh.shape[pod.data_axis]

    def split_pods(v):
        """(E, ...) -> (n_pods, E/n_pods, ...) constrained P(pod, data)."""
        v = v.reshape((pod.n_pods, v.shape[0] // pod.n_pods) + v.shape[1:])
        ax = pod.data_axis if v.shape[1] % data_size == 0 else None
        return jax.lax.with_sharding_constraint(
            v, NamedSharding(pod.mesh,
                             P(pod.axis, ax, *([None] * (v.ndim - 2)))))

    def pod_step(params, opt_state, batch, lr, err, step_on=None):
        bp = {k: split_pods(v) for k, v in batch.items()}

        def per_pod(b_k, e_k):
            w = b_k.get("weights")
            W_k = (jnp.sum(w.astype(jnp.float32)) if w is not None
                   else jnp.float32(jax.tree.leaves(b_k)[0].shape[0]))
            # global weight sum / n_pods: the tiny scalar collective that
            # turns per-pod weighted means into the global weighted mean
            W = jax.lax.pmean(W_k, pod.axis)
            wr = W_k / jnp.maximum(W, 1e-9)

            def obj(p):
                if shard is None:
                    total, m = bundle.loss_fn(p, b_k)
                else:
                    total, m = bundle.loss_fn(p, b_k, shard=shard)
                return m["loss"] * wr + m.get("aux_loss", 0.0), m

            (_, m), grads = jax.value_and_grad(obj, has_aux=True)(params)
            grads, e_new = compressed_psum(grads, pod.axis, pod.mode,
                                           err=e_k, k_frac=pod.k_frac)
            metrics = {k: jax.lax.pmean(v, pod.axis) for k, v in m.items()}
            metrics["loss"] = jax.lax.pmean(m["loss"] * wr, pod.axis)
            if "total_loss" in m:
                metrics["total_loss"] = (metrics["loss"]
                                         + metrics.get("aux_loss", 0.0))
            return grads, e_new, metrics

        # the pmean over the *complete* pod axis leaves grads/metrics
        # unbatched (out_axes=None): only the error state stays per-pod
        grads, new_err, metrics = jax.vmap(
            per_pod, in_axes=(0, 0), out_axes=(None, 0, None),
            axis_name=pod.axis, spmd_axis_name=pod.axis)(bp, err)
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        if guard:
            # the check runs on the post-collective gradients: a NaN/Inf
            # in any pod poisons the psum, so every pod gates off the
            # same step (and rolls its error-feedback residuals back)
            finite = jnp.isfinite(metrics["loss"]) & jnp.isfinite(gnorm)
            ok = finite if step_on is None else step_on & finite
        else:
            ok = step_on
        params, opt_state = opt_update(params, grads, opt_state, lr,
                                       step_on=ok)
        metrics = dict(metrics, grad_norm=gnorm)
        if ok is not None:
            # padding/guarded batches advance nothing: the error-feedback
            # state is selected back bit-exactly, like params/opt_state
            new_err = gate_step(ok, new_err, err)
            metrics = {k: jnp.where(ok, v, jnp.zeros_like(v))
                       for k, v in metrics.items()}
        if guard:
            live = jnp.bool_(True) if step_on is None else step_on
            metrics["skipped"] = live & ~finite
        return params, opt_state, metrics, new_err

    return pod_step


def newbob_step(lr, prev_loss, val_loss, anneal_factor, threshold):
    """Device-side newbob update (the traced twin of
    ``optim.NewbobState.update``): anneal ``lr`` by ``anneal_factor``
    when the relative validation improvement over ``prev_loss`` drops
    below ``threshold``.  ``prev_loss = inf`` (first epoch) and a NaN
    ``val_loss`` (no validation set) both leave ``lr`` untouched, like
    the host version."""
    rel = (prev_loss - val_loss) / jnp.maximum(jnp.abs(prev_loss), 1e-9)
    anneal = (prev_loss != jnp.inf) & (rel < threshold)
    return jnp.where(anneal, lr * anneal_factor, lr), val_loss


def plan_live_steps(plan) -> np.ndarray:
    """Host-side mask of real (non-padding) steps in a plan — use it to
    exclude padding rows from per-step metric aggregates."""
    return np.asarray(plan[0])[:, 0] >= 0


def autotune_loss_vocab_chunk(bundle, units, batch_units: int):
    """Resolve ``RNNTConfig.loss_vocab_chunk == 0`` ("auto") into a
    concrete chunk width at engine build time and rebuild the bundle on
    it when that changes the layout.

    The fused transducer loss streams a ``(rows, chunk)`` slab per vocab
    chunk — the joint-head columns plus the per-chunk lattice block,
    ``rows ~= B * (U+1) + joint_dim`` for batch size
    ``B = batch_units * unit_size`` — so the width comes from the shared
    ``core/chunking.py:auto_vocab_chunk`` resolver (the same budget that
    tiles the grad-sketch kernel's vocab axis).  Small/smoke vocabs
    resolve to a single full-vocab chunk, i.e. exactly the historical
    ``0`` behaviour; an explicit negative value keeps forcing one chunk,
    and an explicit positive value is always respected.

    Returns ``(bundle, resolved_chunk)``; the bundle is rebuilt (same
    config surgery as ``models/api.py:build_model``) only when the tuned
    width is smaller than the vocab.
    """
    cfg_m = bundle.cfg
    r = getattr(cfg_m, "rnnt", None)
    if getattr(cfg_m, "family", None) != "rnnt" or r is None:
        return bundle, None
    if r.loss_vocab_chunk != 0:
        return bundle, r.loss_vocab_chunk
    leaf = jax.tree.leaves(units)[0]
    unit_size = int(leaf.shape[1])
    U = int(units["tokens"].shape[2])
    from repro.core.chunking import auto_vocab_chunk
    rows = int(batch_units) * unit_size * (U + 1) + int(r.joint_dim)
    tuned = auto_vocab_chunk(rows, int(r.vocab_size))
    if tuned >= int(r.vocab_size):
        return bundle, tuned
    import dataclasses

    from repro.models.api import build_model
    cfg_new = dataclasses.replace(
        cfg_m, rnnt=dataclasses.replace(r, loss_vocab_chunk=tuned))
    return build_model(cfg_new), tuned


class EpochEngine:
    """Scanned-epoch executor around a ModelBundle.

    Residency: ``units`` (and optional ``val_units``) are moved to device
    once at construction and never leave — SGD epochs gather batches from
    them inside jit, and PGM stage A can sketch them in place via
    ``core/pgm.ResidentSelector`` (no host round-trip per selection
    round).

    Mesh (DESIGN.md §5): with ``mesh`` the engine owns placement and
    compilation for N devices — units and validation units are
    ``device_put`` sharded over ``data_axis`` along their leading
    ``n_units`` dim (when divisible), the donated ``(params, opt_state)``
    carry is constrained to ``SpecBuilder`` FSDP/TP partition specs
    (``spec_mode`` selects the policy), gathered batches are constrained
    to shard their example axis over ``data``, and plan arrays shard
    their ``batch_units`` axis over ``data``.  GSPMD then partitions the
    step: per-shard loss/grad terms are combined with a mean-psum over
    ``data``, exactly the collective an explicit
    ``train/compress.py:compressed_psum`` emits on the slow ``pod`` axis
    of a multi-pod mesh.  Callers bring the carry onto the mesh with
    ``shard_state`` (fresh init) or ``restore_sharding`` (checkpoint
    restore).  Without a mesh the emitted jaxpr is identical to the
    single-device engine.

    Two-level ``data x pod`` mode (DESIGN.md §5): when the mesh carries
    ``cfg.pod_axis``, the scan body computes per-pod gradients (gathered
    batches place their example axis over ``(pod, data)`` jointly; units
    stay data-sharded/pod-replicated) and runs
    an explicit ``train/compress.py:compressed_psum`` —
    ``cfg.compress_mode`` ``none`` / ``bf16`` / ``topk`` — over the slow
    pod axis, while the intra-pod example reduction stays a dense GSPMD
    mean-psum over ``data``.  Params (and the mirrored optimizer state)
    keep FSDP specs over ``data`` only — replicated across pods, the
    standard multi-pod layout.  Top-k error-feedback residuals live in
    ``compress_state``: per-pod leaves ``(n_pods, *param_shape)`` sharded
    ``P(pod, *param_fsdp_spec)``, donated into every dispatch as part of
    the scan carry, advanced not-at-all on weight-0 padding steps
    (``optim.gate_step``), and checkpointed next to (params, opt_state)
    so resume is bit-exact (``train/loop.py``).

    Plans: ``full_plan`` / ``subset_plan`` return ``(batch_idx, batch_w)``
    index/weight arrays of shape ``(n_steps, batch_units)``.  Both are
    pure functions of ``(seed, epoch)`` (resume rebuilds them exactly —
    which also makes them safe to build ahead of time on a prefetch
    thread, see ``data/plan_prefetch.py``).  Full plans always have
    ``steps_per_epoch_max = n_units // batch_units`` steps; subset plans
    are padded with id ``-1`` / weight ``0`` rows up to
    ``bucket_steps(live)`` — the next multiple of ``plan_granule`` — so
    rounds with a stable selection budget reuse one epoch executable
    regardless of the exact ``n_selected``, at a padding overhead of at
    most one granule (1/8 epoch).

    Donation contract: inputs to ``run_epoch`` / ``run_epochs`` are
    donated — the caller must treat the passed-in ``params`` /
    ``opt_state`` buffers as consumed and continue with the returned
    values (the scan carry aliases them in place).
    """

    kind = "scan"

    def __init__(self, bundle, cfg: TrainConfig,
                 units: Dict[str, Any],
                 val_units: Optional[Dict[str, Any]] = None,
                 batch_units: int = 1,
                 mesh=None, data_axis: str = "data",
                 spec_mode: str = "tp"):
        bundle, self.loss_vocab_chunk = autotune_loss_vocab_chunk(
            bundle, units, batch_units)
        self.bundle = bundle
        self.cfg = cfg
        self.batch_units = int(batch_units)
        self.mesh = mesh
        self.data_axis = data_axis
        # two-level data x pod mode (DESIGN.md §5): active whenever the
        # mesh carries the configured pod axis — the step then computes
        # per-pod gradients and runs compressed_psum over that axis
        # inside the epoch scan
        pod_active = (mesh is not None
                      and cfg.pod_axis in getattr(mesh, "axis_names", ()))
        if cfg.compress_mode != "none" and not pod_active:
            raise ValueError(
                f"compress_mode={cfg.compress_mode!r} needs a mesh with a "
                f"{cfg.pod_axis!r} axis (e.g. --mesh 2x2 with axes "
                f"data x pod); got mesh="
                f"{None if mesh is None else tuple(mesh.axis_names)}")
        self.pod_axis = cfg.pod_axis if pod_active else None
        self.n_pods = int(mesh.shape[cfg.pod_axis]) if pod_active else 0
        self._pod = (PodSpec(cfg.pod_axis, self.n_pods, cfg.compress_mode,
                             cfg.compress_k_frac, data_axis, mesh)
                     if pod_active else None)
        #: per-pod top-k error-feedback residuals (None until the first
        #: topk epoch or a checkpoint restore; donated into every run)
        self.compress_state: Optional[Any] = None
        if mesh is not None:
            from repro.sharding.specs import SpecBuilder
            self.spec: Optional[Any] = SpecBuilder(
                mesh, mode=spec_mode, pod_axis=self.pod_axis,
                arch=getattr(bundle.cfg, "name", None))
        else:
            self.spec = None
        # RNN-T on a mesh: hand the loss a MeshSharder so the fused
        # transducer loss can pin its joint-factor boundary ("act_bsd")
        # — free GSPMD propagation through the CRDNN encoder produces
        # *wrong values* on XLA:CPU SPMD without the anchor (LM stacks
        # carry their own in-model annotations and stay sharder-free
        # here to keep their jaxprs unchanged).  Pod mode anchors every
        # family: the per-pod vmap prepends the pod axis to each act_bsd
        # spec (spmd_axis_name), and without the anchor the partitioner
        # falls back to full rematerialization of the layer-scan carry.
        # Expert mode anchors too: the (E, G, C, d) dispatch boundary
        # must pin its E dim to the expert axis for the all-to-all to
        # materialize instead of a full expert-bank gather
        if mesh is not None and (bundle.cfg.family == "rnnt"
                                 or pod_active or spec_mode == "expert"):
            from repro.sharding.specs import MeshSharder
            self.act_shard: Optional[Any] = MeshSharder(
                mesh, mode=spec_mode, pod_axis=self.pod_axis,
                arch=getattr(bundle.cfg, "name", None))
        else:
            self.act_shard = None
        self.units = self._place_units(units)
        self.val_units = (None if val_units is None
                          else self._place_units(val_units))
        self.n_units = int(jax.tree.leaves(self.units)[0].shape[0])
        self.unit_size = int(jax.tree.leaves(self.units)[0].shape[1])
        #: full-data step count (upper bound for every plan shape)
        self.steps_per_epoch_max = self.n_units // self.batch_units
        #: bucket granule for padded subset plans (1/8 of a full epoch)
        self.plan_granule = max(self.steps_per_epoch_max // 8, 1)
        #: non-finite step guard (DESIGN.md §10): trace-static, so the
        #: guarded engine compiles once like the unguarded one
        self.guard = bool(getattr(cfg, "nonfinite_guard", False))
        #: plan re-keying salt: the divergence watchdog bumps this on
        #: rollback so the replayed epochs draw a fresh batch order
        #: (plans stay pure functions of (seed, salt, epoch))
        self.plan_salt = 0
        #: per-step skip mask (device array) / total skip count of the
        #: last run_epoch/run_epochs dispatch; None when the guard is off
        self.last_skipped: Optional[jax.Array] = None
        self.last_n_skipped: Optional[jax.Array] = None
        if self._pod is not None and \
                (self.batch_units * self.unit_size) % self.n_pods:
            raise ValueError(
                f"batch ({self.batch_units} units x {self.unit_size} "
                f"examples) must divide into n_pods={self.n_pods} equal "
                f"per-pod slices")
        step_core = make_step_core(bundle, cfg, shard=self.act_shard,
                                   pod=self._pod)
        unit_size = self.unit_size
        pod = self._pod
        guard = self.guard

        def make_body(lr):
            def body(carry, xs):
                if guard:
                    *carry, nsk = carry
                if pod is None:
                    p, s = carry
                else:
                    p, s, err = carry
                idx, w = xs
                # plan rows are wholly real or wholly padding; padding
                # rows carry id -1 / weight 0 and must be bit-exact no-ops
                live = idx[0] >= 0
                gidx = jnp.maximum(idx, 0)
                batch = {
                    k: v[gidx].reshape((-1,) + v.shape[2:])
                    for k, v in self.units.items()
                }
                batch = self._constrain_batch(batch)
                if "weights" in batch:
                    batch = dict(batch, weights=batch["weights"]
                                 * jnp.repeat(w, unit_size))
                if pod is None:
                    p, s, metrics = step_core(p, s, batch, lr, step_on=live)
                    carry = (p, s)
                else:
                    p, s, metrics, err = step_core(p, s, batch, lr, err,
                                                   step_on=live)
                    carry = (p, s, err)
                if not guard:
                    return carry, metrics["loss"]
                # the skipped-step counter rides the donated carry; the
                # per-step mask joins the ys so the host watchdog can see
                # *consecutive* skips without an extra sync
                sk = metrics["skipped"]
                nsk = nsk + sk.astype(jnp.int32)
                return carry + (nsk,), (metrics["loss"],
                                        sk.astype(jnp.float32))

            return body

        def scan_epoch(carry, lr, xs):
            """One epoch scan; normalizes the guard-on/-off carry and ys
            shapes to ``(state_carry, losses, skipped, n_skipped)`` with
            ``skipped/n_skipped = None`` when the guard is off."""
            if not guard:
                carry, losses = jax.lax.scan(make_body(lr), carry, xs)
                return carry, losses, None, None
            (*carry, nsk), (losses, skipped) = jax.lax.scan(
                make_body(lr), tuple(carry) + (jnp.zeros((), jnp.int32),),
                xs)
            return tuple(carry), losses, skipped, nsk

        if pod is None:
            def run(params, opt_state, batch_idx, batch_w, lr):
                params, opt_state = self._constrain_state(params, opt_state)
                (params, opt_state), losses, skipped, nsk = scan_epoch(
                    (params, opt_state), lr, (batch_idx, batch_w))
                return params, opt_state, losses, skipped, nsk

            # donate (params, opt_state): the scan carry re-uses their
            # buffers
            self._run = jax.jit(run, donate_argnums=(0, 1))
        else:
            def run(params, opt_state, err, batch_idx, batch_w, lr):
                params, opt_state = self._constrain_state(params, opt_state)
                err = self._constrain_err(err)
                (params, opt_state, err), losses, skipped, nsk = scan_epoch(
                    (params, opt_state, err), lr, (batch_idx, batch_w))
                return params, opt_state, err, losses, skipped, nsk

            # the per-pod error-feedback residuals join the donated carry
            self._run = jax.jit(run, donate_argnums=(0, 1, 2))

        act_shard = self.act_shard

        def val_mean(params, val_dev):
            # validation gets the same activation anchor as the training
            # step (the fused RNN-T loss needs it on a mesh; identity
            # jaxpr when no sharder)
            def unit_loss(u):
                if act_shard is None:
                    return bundle.per_example_loss(params, u).mean()
                return bundle.per_example_loss(params, u,
                                               shard=act_shard).mean()

            return jax.vmap(unit_loss)(val_dev).mean()

        self._validate = jax.jit(val_mean)

        def chunk_epoch_body(state_carry, val_dev, lr_c, prev, xs):
            """Shared inner body of the chunked dispatch: one epoch scan
            + validation + newbob.  Returns the updated state carry, lr,
            prev, the epoch skip count, and this epoch's ys (losses
            [, skip mask], val loss, lr)."""
            state_carry, losses, skipped, nsk = scan_epoch(state_carry,
                                                           lr_c, xs)
            p = state_carry[0]
            if val_dev is not None:
                vl = val_mean(p, val_dev)
                lr_n, prev = newbob_step(
                    lr_c, prev, vl, cfg.anneal_factor,
                    cfg.improvement_threshold)
            else:
                vl = jnp.float32(jnp.nan)
                lr_n = lr_c
            ys = ((losses, vl, lr_n) if not guard
                  else (losses, skipped, vl, lr_n))
            return state_carry, lr_n, prev, nsk, ys

        if pod is None:
            def run_chunk(params, opt_state, val_dev, batch_idx, batch_w,
                          lr, prev_loss):
                """batch_idx/batch_w: (n_epochs, n_steps, batch_units).
                The whole chunk — epochs, validations, newbob updates —
                is one dispatch; metrics are accumulated in the scan ys
                and fetched once by the caller."""
                params, opt_state = self._constrain_state(params, opt_state)

                def epoch(carry, xs):
                    if guard:
                        p, s, lr_c, prev, nsk = carry
                    else:
                        p, s, lr_c, prev = carry
                    (p, s), lr_n, prev, nsk_e, ys = chunk_epoch_body(
                        (p, s), val_dev, lr_c, prev, xs)
                    if guard:
                        return (p, s, lr_n, prev, nsk + nsk_e), ys
                    return (p, s, lr_n, prev), ys

                carry0 = (params, opt_state, lr, prev_loss)
                if guard:
                    carry0 = carry0 + (jnp.zeros((), jnp.int32),)
                carry, ys = jax.lax.scan(epoch, carry0,
                                         (batch_idx, batch_w))
                if guard:
                    params, opt_state, lr, prev_loss, nsk = carry
                    losses, skipped, vls, lrs = ys
                else:
                    params, opt_state, lr, prev_loss = carry
                    (losses, vls, lrs), skipped, nsk = ys, None, None
                return (params, opt_state, losses, skipped, nsk, vls, lrs,
                        lr, prev_loss)

            self._run_chunk = jax.jit(run_chunk, donate_argnums=(0, 1))
        else:
            def run_chunk(params, opt_state, err, val_dev, batch_idx,
                          batch_w, lr, prev_loss):
                """Pod-mode chunk: identical dispatch shape, with the
                per-pod error-feedback residuals threaded through the
                outer epoch carry next to (params, opt_state)."""
                params, opt_state = self._constrain_state(params, opt_state)
                err = self._constrain_err(err)

                def epoch(carry, xs):
                    if guard:
                        p, s, e, lr_c, prev, nsk = carry
                    else:
                        p, s, e, lr_c, prev = carry
                    (p, s, e), lr_n, prev, nsk_e, ys = chunk_epoch_body(
                        (p, s, e), val_dev, lr_c, prev, xs)
                    if guard:
                        return (p, s, e, lr_n, prev, nsk + nsk_e), ys
                    return (p, s, e, lr_n, prev), ys

                carry0 = (params, opt_state, err, lr, prev_loss)
                if guard:
                    carry0 = carry0 + (jnp.zeros((), jnp.int32),)
                carry, ys = jax.lax.scan(epoch, carry0,
                                         (batch_idx, batch_w))
                if guard:
                    params, opt_state, err, lr, prev_loss, nsk = carry
                    losses, skipped, vls, lrs = ys
                else:
                    params, opt_state, err, lr, prev_loss = carry
                    (losses, vls, lrs), skipped, nsk = ys, None, None
                return (params, opt_state, err, losses, skipped, nsk, vls,
                        lrs, lr, prev_loss)

            self._run_chunk = jax.jit(run_chunk, donate_argnums=(0, 1, 2))

    # -- mesh placement helpers ----------------------------------------
    def _place_units(self, units):
        # units stay sharded over `data` only, replicated across pods —
        # combined (pod, data) placement makes the in-scan unit gather
        # (and the vmapped validation) fall into XLA:SPMD full-remat
        # fallbacks on the host backend; the per-pod compute split
        # happens on the *gathered batch* instead (_constrain_batch +
        # make_step_core.split_pods)
        place = _data_sharded_put(self.mesh, self.data_axis)
        return {k: place(jnp.asarray(v)) for k, v in units.items()}

    def _constrain_batch(self, batch):
        """Shard the gathered batch's example axis over ``data`` (when
        divisible) — the step's per-shard loss/grad terms then reduce
        with a GSPMD mean-psum across the axis.  In pod mode the example
        axis spans ``(pod, data)`` jointly; the pod step then splits it
        into per-pod slices (``make_step_core``) without moving data."""
        if self.mesh is None:
            return batch
        if self._pod is not None:
            axes_t: Tuple[str, ...] = (self.pod_axis, self.data_axis)
        else:
            axes_t = (self.data_axis,)
        size = int(np.prod([self.mesh.shape[a] for a in axes_t]))
        spec_ax = axes_t if len(axes_t) > 1 else axes_t[0]

        def con(v):
            ax = spec_ax if v.shape[0] % size == 0 else None
            return jax.lax.with_sharding_constraint(
                v, NamedSharding(self.mesh,
                                 P(ax, *([None] * (v.ndim - 1)))))

        return {k: con(v) for k, v in batch.items()}

    def _constrain_state(self, params, opt_state):
        """Pin the donated carry to the SpecBuilder FSDP/TP specs so the
        whole scan (and its outputs, via donation) keeps them."""
        if self.mesh is None:
            return params, opt_state
        con = lambda t: jax.lax.with_sharding_constraint(
            t, self.state_shardings(t))
        return con(params), con(opt_state)

    def state_shardings(self, tree):
        """NamedShardings for a params-shaped tree (optimizer states
        mirror the params tree, so the same key-path rules apply)."""
        return self.spec.to_shardings(self.spec.param_specs(tree))

    def err_shardings(self, tree):
        """NamedShardings for the per-pod error-feedback state: each leaf
        mirrors a param with a leading ``n_pods`` dim, so its spec is
        ``P(pod, *param_fsdp_spec)`` — pod-local residuals, FSDP-sliced
        like the param they track."""
        flat, tdef = jax.tree_util.tree_flatten_with_path(tree)
        shs = [NamedSharding(self.mesh, P(
            self.pod_axis,
            *self.spec.param_spec(jax.tree_util.keystr(p), l.shape[1:])))
            for p, l in flat]
        return jax.tree_util.tree_unflatten(tdef, shs)

    def _constrain_err(self, err):
        if err is None or self.mesh is None:
            return err
        return jax.tree.map(jax.lax.with_sharding_constraint, err,
                            self.err_shardings(err))

    # -- compression state ---------------------------------------------
    @property
    def uses_error_feedback(self) -> bool:
        """True when the engine carries per-pod top-k residuals that must
        be checkpointed next to (params, opt_state) for exact resume."""
        return self._pod is not None and self._pod.mode == "topk"

    def init_compress_state(self, params):
        """Fresh zero error-feedback state, pod-sharded on the mesh;
        None unless the engine compresses with error feedback."""
        if not self.uses_error_feedback:
            return None
        err = init_error_state(params, n_pods=self.n_pods)
        return jax.device_put(err, self.err_shardings(err))

    def _ensure_compress_state(self, params):
        if self.uses_error_feedback and self.compress_state is None:
            self.compress_state = self.init_compress_state(params)
        return self.compress_state

    def shard_state(self, params, opt_state):
        """Bring a freshly-initialized carry onto the mesh with the
        engine's FSDP/TP shardings (identity without a mesh)."""
        if self.mesh is None:
            return params, opt_state
        return (jax.device_put(params, self.state_shardings(params)),
                jax.device_put(opt_state, self.state_shardings(opt_state)))

    def restore_sharding(self, path: str, arr):
        """``checkpoint.restore(sharding_fn=...)`` hook: reshard a
        restored leaf onto this engine's mesh — elastic restore across
        mesh shapes (DESIGN.md §5).  Returns None without a mesh.
        Error-feedback leaves (checkpoint key ``err``) carry a leading
        pod dim and reshard to ``P(pod, *param_spec)``."""
        if self.mesh is None:
            return None
        if self._pod is not None and "['err']" in path:
            return NamedSharding(self.mesh, P(
                self.pod_axis,
                *self.spec.param_spec(path, tuple(np.shape(arr))[1:])))
        return NamedSharding(self.mesh,
                             self.spec.param_spec(path, np.shape(arr)))

    def _put_plan(self, idx, w):
        idx, w = jnp.asarray(idx), jnp.asarray(w)
        if self.mesh is not None and \
                idx.shape[-1] % self.mesh.shape[self.data_axis] == 0:
            spec = P(*([None] * (idx.ndim - 1)), self.data_axis)
            sh = NamedSharding(self.mesh, spec)
            idx, w = jax.device_put(idx, sh), jax.device_put(w, sh)
        return idx, w

    # ------------------------------------------------------------------
    def _plan_seed(self) -> int:
        """Plan seed including the watchdog's re-key salt: 0 rollbacks
        leave it exactly ``cfg.seed`` (bit-identical schedules); each
        rollback shifts every subsequent epoch's batch order so a replay
        doesn't march through the same poisoned sequence."""
        return self.cfg.seed + 1_000_003 * self.plan_salt

    def full_plan(self, epoch: int) -> Tuple[jax.Array, jax.Array]:
        """(seed, epoch)-keyed full-data plan; unit weights are 1.  Shape
        ``(steps_per_epoch_max, batch_units)`` — identical to padded
        subset plans, so full and subset epochs share one executable."""
        idx = epoch_plan(self.n_units, self._plan_seed(), epoch,
                         self.batch_units)
        return self._put_plan(idx, np.ones(idx.shape, np.float32))

    def bucket_steps(self, n_live_steps: int) -> int:
        """Round a live step count up to the next ``plan_granule``
        multiple (capped at ``steps_per_epoch_max``): the padded-plan
        shape that bounds both recompiles (≤8 distinct buckets ever; one
        in the common stable-budget case) and padding waste (≤1
        granule).  Never returns 0 — a selection with fewer live units
        than a batch still yields a one-granule all-padding plan, keeping
        the shape inside the bucket family instead of tracing a fresh
        zero-length executable."""
        g = self.plan_granule
        return min(max(-(-n_live_steps // g) * g, g),
                   self.steps_per_epoch_max)

    def subset_plan(self, indices, weights, epoch: int,
                    pad_to_steps: Optional[int] = None,
                    ) -> Tuple[jax.Array, jax.Array]:
        """(seed, epoch)-keyed weighted-subset plan.

        By default the plan is padded with weight-0 rows to
        ``bucket_steps(live)`` so changing ``n_selected`` between
        selection rounds reuses the compiled epoch executable while a
        subset epoch still runs only ~``n_selected`` steps' worth of
        compute (pass ``pad_to_steps=0`` for the legacy unpadded shape,
        or any explicit step count)."""
        if pad_to_steps is None:
            n_live = int((np.asarray(indices) >= 0).sum())
            pad_to_steps = self.bucket_steps(n_live // self.batch_units)
        idx, w = subset_epoch_plan(np.asarray(indices), np.asarray(weights),
                                   self._plan_seed(), epoch,
                                   self.batch_units,
                                   pad_to_steps=pad_to_steps or None)
        return self._put_plan(idx, w)

    plan_live_steps = staticmethod(plan_live_steps)

    def epoch_cost(self, plan, use_full: bool = False,
                   n_selected: Optional[int] = None) -> float:
        """Full-epoch-equivalent compute charged for executing ``plan``:
        the bucketed step count — padding rows run a full step before
        being gated — so reported savings include the granule slack
        honestly (DESIGN.md §3)."""
        return plan[0].shape[0] / self.steps_per_epoch_max

    def run_epoch(self, params, opt_state, lr,
                  plan: Tuple[jax.Array, jax.Array]):
        """One scanned epoch.  Returns ``(params, opt_state, losses)``
        with ``losses`` of shape ``(n_steps,)`` — padding steps report 0
        and must be masked out of aggregates with ``plan_live_steps``.
        The passed params/opt_state buffers are donated (see class
        docstring); in pod mode the engine-held ``compress_state`` is
        donated and replaced alongside them."""
        batch_idx, batch_w = plan
        if self._pod is None:
            params, opt_state, losses, skipped, nsk = self._run(
                params, opt_state, batch_idx, batch_w,
                jnp.asarray(lr, jnp.float32))
        else:
            err = self._ensure_compress_state(params)
            (params, opt_state, self.compress_state, losses, skipped,
             nsk) = self._run(params, opt_state, err, batch_idx, batch_w,
                              jnp.asarray(lr, jnp.float32))
        self.last_skipped, self.last_n_skipped = skipped, nsk
        return params, opt_state, losses

    def run_epochs(self, params, opt_state, lr, prev_loss,
                   plans: Sequence[Tuple[jax.Array, jax.Array]]):
        """A chunk of epochs as ONE dispatch (outer scan over per-epoch
        plans; inner scan over steps; validation + newbob on device).

        ``plans`` must share one shape (all full plans do; subset plans
        within one selection period land in one bucket).  Returns
        ``(params, opt_state, losses (E, n_steps), val_losses (E,),
        lrs (E,), lr_out, prev_loss_out)`` — ``lrs[i]`` is the
        post-update lr after epoch ``i`` (what the host
        ``NewbobState.update`` would have produced), ``val_losses`` is
        NaN-filled when the engine has no ``val_units``.  Metrics cross
        the host boundary once per chunk, when the caller fetches them.
        Inputs are donated like ``run_epoch``."""
        shapes = {tuple(p[0].shape) for p in plans}
        if len(shapes) != 1:
            raise ValueError(f"chunked plans must share one shape, got "
                             f"{sorted(shapes)}")
        # plans arrive already device_put (full_plan/subset_plan, often on
        # the prefetch thread) with their batch axis data-sharded; the
        # stack preserves placement, so no second transfer is needed
        batch_idx = jnp.stack([p[0] for p in plans])
        batch_w = jnp.stack([p[1] for p in plans])
        if self._pod is None:
            (params, opt_state, losses, skipped, nsk, vls, lrs, lr_out,
             prev_out) = self._run_chunk(params, opt_state, self.val_units,
                                         batch_idx, batch_w,
                                         jnp.asarray(lr, jnp.float32),
                                         jnp.asarray(prev_loss, jnp.float32))
        else:
            err = self._ensure_compress_state(params)
            (params, opt_state, self.compress_state, losses, skipped, nsk,
             vls, lrs, lr_out, prev_out) = self._run_chunk(
                params, opt_state, err, self.val_units, batch_idx, batch_w,
                jnp.asarray(lr, jnp.float32),
                jnp.asarray(prev_loss, jnp.float32))
        self.last_skipped, self.last_n_skipped = skipped, nsk
        return params, opt_state, losses, vls, lrs, lr_out, prev_out

    def validate(self, params) -> float:
        """Mean per-unit validation loss as one vmapped call (NaN when the
        engine was built without ``val_units``)."""
        if self.val_units is None:
            return float("nan")
        return float(self._validate(params, self.val_units))


class HostEngine:
    """The legacy per-batch host loop behind the same engine interface —
    the parity oracle (`tests/test_train_engine.py`): one jit call per
    host-assembled batch, one eval call per validation unit.  Plans are
    the unpadded ``(seed, epoch)``-keyed schedules, so batch order is
    byte-identical to the scanned engine's by construction (DESIGN.md
    §1).  With a mesh, only the *selection* units are sharded (the SGD
    step itself stays single-device — sharded training is the scan
    engine's job; pod-axis gradient compression is likewise scan-only)."""

    kind = "host"
    uses_error_feedback = False
    compress_state = None

    def __init__(self, bundle, cfg: TrainConfig,
                 units: Dict[str, Any],
                 val_units: Optional[Dict[str, Any]] = None,
                 batch_units: int = 1,
                 mesh=None, data_axis: str = "data",
                 spec_mode: str = "tp"):
        if cfg.compress_mode != "none":
            raise ValueError(
                f"compress_mode={cfg.compress_mode!r} is scan-engine-only "
                f"(the host loop trains dense on one device); use "
                f"engine='scan' with a data x {cfg.pod_axis} mesh")
        bundle, self.loss_vocab_chunk = autotune_loss_vocab_chunk(
            bundle, units, batch_units)
        self.bundle = bundle
        self.cfg = cfg
        self.batch_units = int(batch_units)
        self.mesh = mesh
        self.units_host = {k: np.asarray(v) for k, v in units.items()}
        place = _data_sharded_put(mesh, data_axis)
        self.units = {k: place(v) for k, v in self.units_host.items()}
        self.val_units = (None if val_units is None else
                          {k: place(np.asarray(v))
                           for k, v in val_units.items()})
        self.n_units = int(self.units_host[next(iter(units))].shape[0])
        self.unit_size = int(self.units_host[next(iter(units))].shape[1])
        self.steps_per_epoch_max = self.n_units // self.batch_units
        self.guard = bool(getattr(cfg, "nonfinite_guard", False))
        self.plan_salt = 0
        self.last_skipped = None
        self.last_n_skipped = None
        self._step = jax.jit(make_step_core(bundle, cfg))
        self._eval = jax.jit(
            lambda params, batch: bundle.per_example_loss(params,
                                                          batch).mean())

    # -- unified interface ---------------------------------------------
    def _plan_seed(self) -> int:
        return self.cfg.seed + 1_000_003 * self.plan_salt

    def full_plan(self, epoch: int):
        idx = epoch_plan(self.n_units, self._plan_seed(), epoch,
                         self.batch_units)
        return idx, np.ones(idx.shape, np.float32)

    def subset_plan(self, indices, weights, epoch: int):
        """Unpadded — the host loop executes exactly the live steps."""
        return subset_epoch_plan(np.asarray(indices), np.asarray(weights),
                                 self._plan_seed(), epoch, self.batch_units)

    plan_live_steps = staticmethod(plan_live_steps)

    def epoch_cost(self, plan, use_full: bool = False,
                   n_selected: Optional[int] = None) -> float:
        """Paper-style charge: the fraction of units trained on (the
        host loop executes exactly the live steps; the dropped
        remainder of a subset is still charged, matching the paper's
        `b_k / n` accounting)."""
        if use_full or n_selected is None:
            return 1.0
        return float(n_selected) / self.n_units

    def shard_state(self, params, opt_state):
        return params, opt_state

    def restore_sharding(self, path: str, arr):
        return None

    def run_epoch(self, params, opt_state, lr, plan):
        """Per-batch host loop over the plan rows — assembles every batch
        in numpy (the same view `full_iterator`/`subset_iterator` yield)
        and dispatches one jit call per step."""
        losses = []
        skipped = []
        for sel, w in zip(*plan):
            batch = {k: v[sel].reshape((-1,) + v.shape[2:])
                     for k, v in self.units_host.items()}
            if "weights" in batch:
                batch = dict(batch, weights=batch["weights"]
                             * np.repeat(w, self.unit_size))
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = self._step(params, opt_state,
                                                    batch, lr)
            losses.append(float(metrics["loss"]))        # repro: noqa[host-sync-loop] -- the host engine IS the per-step parity oracle (DESIGN §1); one sync per step is its definition
            if self.guard:
                skipped.append(float(metrics["skipped"]))  # repro: noqa[host-sync-loop] -- same deliberate per-step oracle sync as the loss fetch above
        if self.guard:
            self.last_skipped = np.asarray(skipped, np.float32)
            self.last_n_skipped = int(sum(skipped))
        return params, opt_state, np.asarray(losses, np.float64)

    def validate(self, params) -> float:
        if self.val_units is None:
            return float("nan")
        n_val = int(jax.tree.leaves(self.val_units)[0].shape[0])
        return float(np.mean([
            float(self._eval(params,
                             {k: v[i] for k, v in self.val_units.items()}))
            for i in range(n_val)]))


def _data_sharded_put(mesh, data_axis: str):
    """Leading-axis ``data`` placement for unit trees (replicated when
    the dim doesn't divide; plain device arrays without a mesh).  Pod
    engines deliberately keep units here too — pod-replicated — and
    split compute on the gathered batch instead (see
    ``EpochEngine._place_units``)."""
    if mesh is None:
        return jnp.asarray
    size = mesh.shape[data_axis]

    def put(v):
        ax = data_axis if v.shape[0] % size == 0 else None
        return jax.device_put(v, NamedSharding(
            mesh, P(ax, *([None] * (np.ndim(v) - 1)))))

    return put


def make_engine(name: str, bundle, cfg: TrainConfig, units,
                val_units=None, batch_units: int = 1, mesh=None,
                data_axis: str = "data", spec_mode: str = "tp"):
    """The one engine factory ``train/loop.py`` consumes: ``"host"`` |
    ``"scan"`` (mesh-native when ``mesh`` is given)."""
    if name == "scan":
        return EpochEngine(bundle, cfg, units, val_units=val_units,
                           batch_units=batch_units, mesh=mesh,
                           data_axis=data_axis, spec_mode=spec_mode)
    if name == "host":
        return HostEngine(bundle, cfg, units, val_units=val_units,
                          batch_units=batch_units, mesh=mesh,
                          data_axis=data_axis, spec_mode=spec_mode)
    raise ValueError(f"unknown engine {name!r}")
