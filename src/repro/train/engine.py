"""Device-resident scanned epoch engine for Algorithm 1's SGD phase.

The legacy host loop assembles every batch in numpy, copies it to device
and dispatches one jit call per step, then validates one example per
Python iteration.  Here the whole corpus of selection units lives on
device once; an epoch is a single jitted ``lax.scan`` over a precomputed
(seed, epoch)-keyed batch plan (``data/pipeline.epoch_plan`` /
``subset_epoch_plan``), with ``(params, opt_state)`` donated so the
update runs in-place instead of round-tripping buffers.  Weighted-subset
epochs are expressed as index+weight arrays gathered inside jit — no
regenerated host batches — and validation is one vmapped call over the
validation units.

Retrace-freedom (DESIGN.md §3): subset plans are padded with weight-0
padding rows (unit id ``-1``) up to a *bucketed* step count — the next
multiple of ``plan_granule`` (1/8 of the full-data step count) — so
selection rounds whose ``n_selected`` lands in the same bucket reuse one
compiled epoch executable, while a subset epoch still executes only
~``n_selected/n_units`` of the full-epoch steps (padding waste is
bounded by one granule, not by the subset fraction).  Padding rows are
bit-exact no-ops: the gather index is clamped, the step runs, and
``optim.gate_step`` selects the old ``(params, opt_state)`` leafwise, so
the padded scan's state matches the unpadded loop's exactly.
``n_epoch_traces`` counts compilations (it only advances while tracing)
and is asserted on by ``tests/test_resident_selection.py``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.data.pipeline import epoch_plan, subset_epoch_plan
from repro.train.optim import clip_by_global_norm, make_update_for


def make_step_core(bundle, cfg: TrainConfig):
    """The un-jitted per-batch SGD update shared by the legacy host loop
    (which jits it per call) and the scanned engine (which embeds it in
    the scan body).

    ``step_on`` (optional traced bool scalar) is the padding-batch gate:
    when False the optimizer update is a bit-exact no-op and every metric
    is zeroed (no state advance, no metric contribution); when ``None``
    (host loop — plans it consumes are never padded) no gating ops are
    emitted.
    """
    _, opt_update = make_update_for(cfg)

    def step(params, opt_state, batch, lr, step_on=None):
        def loss(p):
            total, metrics = bundle.loss_fn(p, batch)
            return total, metrics

        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        params, opt_state = opt_update(params, grads, opt_state, lr,
                                       step_on=step_on)
        metrics = dict(metrics, grad_norm=gnorm)
        if step_on is not None:
            metrics = {k: jnp.where(step_on, v, jnp.zeros_like(v))
                       for k, v in metrics.items()}
        return params, opt_state, metrics

    return step


class EpochEngine:
    """Scanned-epoch executor around a ModelBundle.

    Residency: ``units`` (and optional ``val_units``) are moved to device
    once at construction and never leave — SGD epochs gather batches from
    them inside jit, and PGM stage A can sketch them in place via
    ``core/pgm.ResidentSelector`` (no host round-trip per selection
    round).

    Plans: ``full_plan`` / ``subset_plan`` return ``(batch_idx, batch_w)``
    index/weight arrays of shape ``(n_steps, batch_units)``.  Both are
    pure functions of ``(seed, epoch)`` (resume rebuilds them exactly).
    Full plans always have ``steps_per_epoch_max = n_units //
    batch_units`` steps; subset plans are padded with id ``-1`` /
    weight ``0`` rows up to ``bucket_steps(live)`` — the next multiple
    of ``plan_granule`` — so rounds with a stable selection budget
    reuse one epoch executable regardless of the exact ``n_selected``,
    at a padding overhead of at most one granule (1/8 epoch).

    Donation contract: inputs to ``run_epoch`` are donated — the caller
    must treat the passed-in ``params`` / ``opt_state`` buffers as
    consumed and continue with the returned values (the scan carry
    aliases them in place).
    """

    def __init__(self, bundle, cfg: TrainConfig,
                 units: Dict[str, Any],
                 val_units: Optional[Dict[str, Any]] = None,
                 batch_units: int = 1):
        self.bundle = bundle
        self.cfg = cfg
        self.batch_units = int(batch_units)
        self.units = {k: jnp.asarray(v) for k, v in units.items()}
        self.val_units = (None if val_units is None else
                          {k: jnp.asarray(v) for k, v in val_units.items()})
        self.n_units = int(jax.tree.leaves(self.units)[0].shape[0])
        self.unit_size = int(jax.tree.leaves(self.units)[0].shape[1])
        #: full-data step count (upper bound for every plan shape)
        self.steps_per_epoch_max = self.n_units // self.batch_units
        #: bucket granule for padded subset plans (1/8 of a full epoch)
        self.plan_granule = max(self.steps_per_epoch_max // 8, 1)
        #: number of times the epoch executable has been traced/compiled
        self.n_epoch_traces = 0
        step_core = make_step_core(bundle, cfg)
        unit_size = self.unit_size

        def run(params, opt_state, units_dev, batch_idx, batch_w, lr):
            self.n_epoch_traces += 1  # python side effect: counts traces

            def body(carry, xs):
                p, s = carry
                idx, w = xs
                # plan rows are wholly real or wholly padding; padding rows
                # carry id -1 / weight 0 and must be bit-exact no-ops
                live = idx[0] >= 0
                gidx = jnp.maximum(idx, 0)
                batch = {
                    k: v[gidx].reshape((-1,) + v.shape[2:])
                    for k, v in units_dev.items()
                }
                if "weights" in batch:
                    batch = dict(batch, weights=batch["weights"]
                                 * jnp.repeat(w, unit_size))
                p, s, metrics = step_core(p, s, batch, lr, step_on=live)
                return (p, s), metrics["loss"]

            (params, opt_state), losses = jax.lax.scan(
                body, (params, opt_state), (batch_idx, batch_w))
            return params, opt_state, losses

        # donate (params, opt_state): the scan carry re-uses their buffers
        self._run = jax.jit(run, donate_argnums=(0, 1))

        def validate(params, val_dev):
            per_unit = jax.vmap(
                lambda u: bundle.per_example_loss(params, u).mean())(val_dev)
            return per_unit.mean()

        self._validate = jax.jit(validate)

    # ------------------------------------------------------------------
    def full_plan(self, epoch: int) -> Tuple[jax.Array, jax.Array]:
        """(seed, epoch)-keyed full-data plan; unit weights are 1.  Shape
        ``(steps_per_epoch_max, batch_units)`` — identical to padded
        subset plans, so full and subset epochs share one executable."""
        idx = epoch_plan(self.n_units, self.cfg.seed, epoch, self.batch_units)
        return jnp.asarray(idx), jnp.ones(idx.shape, jnp.float32)

    def bucket_steps(self, n_live_steps: int) -> int:
        """Round a live step count up to the next ``plan_granule``
        multiple (capped at ``steps_per_epoch_max``): the padded-plan
        shape that bounds both recompiles (≤8 distinct buckets ever; one
        in the common stable-budget case) and padding waste (≤1
        granule).  Never returns 0 — a selection with fewer live units
        than a batch still yields a one-granule all-padding plan, keeping
        the shape inside the bucket family instead of tracing a fresh
        zero-length executable."""
        g = self.plan_granule
        return min(max(-(-n_live_steps // g) * g, g),
                   self.steps_per_epoch_max)

    def subset_plan(self, indices, weights, epoch: int,
                    pad_to_steps: Optional[int] = None,
                    ) -> Tuple[jax.Array, jax.Array]:
        """(seed, epoch)-keyed weighted-subset plan.

        By default the plan is padded with weight-0 rows to
        ``bucket_steps(live)`` so changing ``n_selected`` between
        selection rounds reuses the compiled epoch executable while a
        subset epoch still runs only ~``n_selected`` steps' worth of
        compute (pass ``pad_to_steps=0`` for the legacy unpadded shape,
        or any explicit step count)."""
        if pad_to_steps is None:
            n_live = int((np.asarray(indices) >= 0).sum())
            pad_to_steps = self.bucket_steps(n_live // self.batch_units)
        idx, w = subset_epoch_plan(np.asarray(indices), np.asarray(weights),
                                   self.cfg.seed, epoch, self.batch_units,
                                   pad_to_steps=pad_to_steps or None)
        return jnp.asarray(idx), jnp.asarray(w)

    @staticmethod
    def plan_live_steps(plan: Tuple[jax.Array, jax.Array]) -> np.ndarray:
        """Host-side mask of real (non-padding) steps in a plan — use it
        to exclude padding rows from per-step metrics."""
        return np.asarray(plan[0])[:, 0] >= 0

    def run_epoch(self, params, opt_state, lr,
                  plan: Tuple[jax.Array, jax.Array]):
        """One scanned epoch.  Returns ``(params, opt_state, losses)``
        with ``losses`` of shape ``(n_steps,)`` — padding steps report 0
        and must be masked out of aggregates with ``plan_live_steps``.
        The passed params/opt_state buffers are donated (see class
        docstring)."""
        batch_idx, batch_w = plan
        return self._run(params, opt_state, self.units, batch_idx, batch_w,
                         jnp.asarray(lr, jnp.float32))

    def validate(self, params) -> float:
        """Mean per-unit validation loss as one vmapped call (NaN when the
        engine was built without ``val_units``)."""
        if self.val_units is None:
            return float("nan")
        return float(self._validate(params, self.val_units))
