"""Optimizers (from scratch — no optax offline): SGD(+momentum), AdamW,
gradient clipping, and the paper's "newbob" scheduler (anneal lr by a
factor when relative validation improvement drops below a threshold).
Optimizer states are pytrees mirroring the params, so they inherit the
params' sharding (ZeRO-3-style under FSDP specs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def tree_all_finite(tree) -> jax.Array:
    """Traced bool scalar: every leaf of ``tree`` is free of NaN/Inf.

    Reference checker for the non-finite step guard's semantics
    (DESIGN.md §10).  The jitted step itself doesn't pay for this
    leafwise sweep: gradient clipping already computes the global norm,
    and any NaN/Inf leaf poisons that sum of squares, so the in-scan
    guard checks ``isfinite(gnorm)`` — one scalar — and feeds it into
    the same ``gate_step`` select that implements weight-0 padding
    batches (a poisoned step advances nothing, bit-exactly, with no
    host sync)."""
    leaves = jax.tree.leaves(tree)
    ok = jnp.bool_(True)
    for l in leaves:
        ok = ok & jnp.all(jnp.isfinite(l))
    return ok


def clip_by_global_norm(grads, max_norm: float):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), n


# ---------------------------------------------------------------------------
# SGD (+ momentum) — the paper trains with plain SGD at lr 1-2
# ---------------------------------------------------------------------------

def sgd_init(params, momentum: float = 0.0):
    if momentum == 0.0:
        return {"step": jnp.zeros((), jnp.int32)}
    return {"step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(jnp.zeros_like, params)}


def sgd_update(params, grads, state, lr, momentum: float = 0.0,
               weight_decay: float = 0.0):
    step = state["step"] + 1
    if weight_decay:
        grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
    if momentum:
        mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
        upd = mu
        new_state = {"step": step, "mu": mu}
    else:
        upd = grads
        new_state = {"step": step}
    params = jax.tree.map(lambda p, u: (p - lr * u).astype(p.dtype),
                          params, upd)
    return params, new_state


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params):
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params)}


def adamw_update(params, grads, state, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay: float = 0.0):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                     state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_
                     + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                     state["v"], grads)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    params = jax.tree.map(upd, params, m, v)
    return params, {"step": step, "m": m, "v": v}


def make_optimizer(name: str):
    if name == "sgd":
        return sgd_init, sgd_update
    if name == "adamw":
        return adamw_init, adamw_update
    raise ValueError(name)


def gate_step(step_on, new_tree, old_tree):
    """Padding-aware step semantics: select ``new_tree`` where ``step_on``
    (a traced boolean scalar) and ``old_tree`` otherwise, leafwise.

    A weight-0 padding batch (see ``data/pipeline.subset_epoch_plan``'s
    ``pad_to_steps``) must advance *nothing*: no parameter update, no step
    counter tick, no Adam moment decay.  ``jnp.where`` on a scalar predicate
    lowers to a select, so a gated-off step returns the old buffers
    bit-identically — padded and unpadded epochs produce the same
    ``(params, opt_state)``.
    """
    return jax.tree.map(lambda a, b: jnp.where(step_on, a, b),
                        new_tree, old_tree)


def make_update_for(cfg):
    """Bind a TrainConfig's optimizer hyper-parameters once, so the host
    loop and the scanned epoch engine share one (init, update) pair:
    ``init(params) -> state``; ``update(params, grads, state, lr[, step_on])``.

    ``step_on`` (optional traced bool scalar) implements the weight-0
    padding-batch semantics of retrace-free subset plans: when False the
    update is a bit-exact no-op for both params and optimizer state
    (``gate_step``); when ``None`` (the host loop, real batches) no gating
    ops are emitted at all.
    """
    init, update = make_optimizer(cfg.optimizer)
    kw = {"momentum": cfg.momentum} if cfg.optimizer == "sgd" else {}

    def init_fn(params):
        return init(params, cfg.momentum) if cfg.optimizer == "sgd" \
            else init(params)

    def update_fn(params, grads, state, lr, step_on=None):
        new_p, new_s = update(params, grads, state, lr,
                              weight_decay=cfg.weight_decay, **kw)
        if step_on is None:
            return new_p, new_s
        return gate_step(step_on, new_p, params), \
            gate_step(step_on, new_s, state)

    return init_fn, update_fn


# ---------------------------------------------------------------------------
# newbob scheduler (paper: lr 2.0, anneal 0.8 on rel. improvement < 0.0025)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class NewbobState:
    lr: float
    prev_loss: float = float("inf")

    def update(self, val_loss: float, anneal_factor: float = 0.8,
               improvement_threshold: float = 0.0025) -> "NewbobState":
        if self.prev_loss != float("inf"):
            rel = (self.prev_loss - val_loss) / max(abs(self.prev_loss), 1e-9)
            if rel < improvement_threshold:
                return NewbobState(self.lr * anneal_factor, val_loss)
        return NewbobState(self.lr, val_loss)
