"""Cross-pod gradient compression (beyond-paper distributed optimization).

Over the slow DCN ``pod`` axis, all-reducing full fp32 gradients is the
dominant collective.  Two composable compressors:

  * bf16 cast (2x):   lossless enough for gradient averaging in practice;
  * top-k sparsification with **error feedback** (Stich et al. 2018):
    transmit the k largest-|g| entries per tensor, accumulate the residual
    locally and add it to the next step's gradient — provably convergent
    for SGD.

``compressed_psum`` wires a compressor into an explicit shard_map
all-reduce over a named axis (the pattern a multi-pod deployment uses for
the ``pod`` axis while leaving intra-pod reductions dense).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def bf16_compress(g):
    return jax.tree.map(lambda l: l.astype(jnp.bfloat16), g)


def topk_compress(g, err, k_frac: float = 0.05):
    """Returns (sparse_g, new_err).  sparse_g has the same dense shape
    (zeros off-support) — the collective still benefits when the runtime
    all-reduces bf16-sparse or when k_frac maps to gather-scatter; the
    error-feedback math is exact either way."""

    def one(l, e):
        l32 = l.astype(jnp.float32) + e
        flat = l32.reshape(-1)
        k = max(int(flat.size * k_frac), 1)
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = jnp.abs(flat) >= thresh
        sent = jnp.where(mask, flat, 0.0)
        return sent.reshape(l.shape), (flat - sent).reshape(l.shape)

    flat_g, tdef = jax.tree_util.tree_flatten(g)
    flat_e = jax.tree_util.tree_leaves(err)
    out = [one(l, e) for l, e in zip(flat_g, flat_e)]
    sent = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_err = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    return sent, new_err


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads, axis: str, mode: str = "bf16", err=None,
                    k_frac: float = 0.05):
    """All-reduce-mean grads over ``axis`` (inside shard_map) with the
    selected compressor.  Returns (mean grads fp32, new error state)."""
    if mode == "none":
        return jax.tree.map(
            lambda l: jax.lax.pmean(l.astype(jnp.float32), axis), grads), err
    if mode == "bf16":
        sent = bf16_compress(grads)
        red = jax.tree.map(
            lambda l: jax.lax.pmean(l.astype(jnp.float32), axis), sent)
        return red, err
    if mode == "topk":
        sent, new_err = topk_compress(grads, err, k_frac)
        red = jax.tree.map(lambda l: jax.lax.pmean(l, axis), sent)
        return red, new_err
    raise ValueError(mode)
