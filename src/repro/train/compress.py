"""Cross-pod gradient compression (beyond-paper distributed optimization).

Over the slow DCN ``pod`` axis, all-reducing full fp32 gradients is the
dominant collective.  Two composable compressors:

  * bf16 cast (2x): the collective itself runs at bf16 width — the cast
    happens *before* the reduce and the fp32 upcast after, so the wire
    moves half the bytes (asserted on the lowered HLO by
    ``tests/test_compress.py``);
  * top-k sparsification with **error feedback** (Stich et al. 2018):
    transmit exactly the k largest-|g| entries per tensor, accumulate the
    residual locally and add it to the next step's gradient — provably
    convergent for SGD.

``compressed_psum`` wires a compressor into an all-reduce-mean over a
named axis.  The axis may be bound by an explicit ``shard_map`` (the
standalone multi-pod plumbing pattern, ``tests/test_sharding.py``) or by
the scanned engine's per-pod ``vmap`` (``train/engine.py`` runs it on
the ``pod`` mesh axis *inside* the jitted epoch scan, DESIGN.md §5) —
``jax.lax.pmean`` is context-agnostic, so the same function serves both.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def bf16_compress(g):
    return jax.tree.map(lambda l: l.astype(jnp.bfloat16), g)


def topk_compress(g, err, k_frac: float = 0.05):
    """Returns (sparse_g, new_err).  sparse_g has the same dense shape
    (zeros off-support) — the collective still benefits when the runtime
    all-reduces bf16-sparse or when k_frac maps to gather-scatter; the
    error-feedback math is exact either way.

    Exactly ``k = max(int(size * k_frac), 1)`` entries are selected per
    leaf via ``top_k`` indices + scatter — never more.  (A threshold
    mask ``|g| >= kth`` would over-select on ties, and when the k-th
    largest |g| is 0 — common for sparse/embedding-style gradients — it
    would silently select the *entire* tensor, degrading the collective
    back to dense; ``tests/test_compress.py`` holds the regression.)
    """

    def one(l, e):
        flat = (l.astype(jnp.float32) + e).reshape(-1)
        k = max(int(flat.size * k_frac), 1)
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        sent = jnp.zeros_like(flat).at[idx].set(flat[idx])
        # residual is exact: flat - sent is 0 on the support, flat off it,
        # so sent + new_err == g + old_err bit-for-bit
        return sent.reshape(l.shape), (flat - sent).reshape(l.shape)

    flat_g, tdef = jax.tree_util.tree_flatten(g)
    flat_e = jax.tree_util.tree_leaves(err)
    out = [one(l, e) for l, e in zip(flat_g, flat_e)]
    sent = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_err = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    return sent, new_err


def init_error_state(params, n_pods: Optional[int] = None):
    """Zero error-feedback state mirroring ``params``.  With ``n_pods``
    every leaf gains a leading pod dimension — the per-pod residuals the
    scanned engine carries (sharded ``P(pod, *param_fsdp_spec)``) and
    checkpoints next to the optimizer state."""
    lead = () if n_pods is None else (int(n_pods),)
    return jax.tree.map(lambda p: jnp.zeros(lead + tuple(p.shape),
                                            jnp.float32), params)


def compressed_psum(grads, axis: str, mode: str = "bf16", err=None,
                    k_frac: float = 0.05):
    """All-reduce-mean grads over the named ``axis`` (bound by shard_map
    or a per-pod vmap) with the selected compressor.  Returns
    (mean grads fp32, new error state)."""
    if mode == "none":
        return jax.tree.map(
            lambda l: jax.lax.pmean(l.astype(jnp.float32), axis), grads), err
    if mode == "bf16":
        # cast BEFORE the pmean so the collective itself moves bf16 —
        # reducing an fp32 upcast would keep the wire at full width and
        # make the documented 2x reduction false
        return jax.tree.map(
            lambda l: jax.lax.pmean(l.astype(jnp.bfloat16), axis)
            .astype(jnp.float32), grads), err
    if mode == "topk":
        sent, new_err = topk_compress(grads, err, k_frac)
        red = jax.tree.map(lambda l: jax.lax.pmean(l, axis), sent)
        return red, new_err
    raise ValueError(mode)
