"""Fault-tolerant checkpointing (from scratch — no orbax offline).

Layout:  <dir>/step_<n>/
            manifest.json   {step, config, mesh_shape, tree structure,
                             per-array sha256, wallclock}
            arrays.npz      flat {path: np.ndarray}
Writes go to ``<dir>/.tmp_<n>`` then ``os.replace`` -> atomic: a crash
mid-write never corrupts the latest checkpoint.  ``AsyncCheckpointer``
runs the serialization+write on a background thread (device_get happens
synchronously to snapshot a consistent state, file IO overlaps training).

Restore is *elastic*: arrays are loaded host-side and ``jax.device_put``
with whatever sharding the (possibly different) new mesh prescribes —
restart on a different pod/slice count just works (DESIGN.md §5).
"""
from __future__ import annotations

import hashlib
import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}, treedef


def _prune_tmp_dirs(ckpt_dir: str):
    """Remove ``.tmp_*`` staging dirs left behind by a crash mid-``save``.

    The atomic rename protocol means a tmp dir is garbage the moment the
    process that created it is gone; pruning on the next ``save``/
    ``restore`` keeps a crash loop from accumulating partial writes."""
    if not os.path.isdir(ckpt_dir):
        return
    for d in os.listdir(ckpt_dir):
        if d.startswith(".tmp_"):
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def save(ckpt_dir: str, step: int, tree, extra: Optional[Dict] = None,
         mesh_shape: Optional[Dict[str, int]] = None,
         compress_mode: Optional[str] = None):
    """Blocking atomic save.  ``mesh_shape`` (``{axis: size}`` or None
    for single-device) is recorded in the manifest so a restore can
    report/reshard across mesh-topology changes (DESIGN.md §5); arrays
    are always stored as full host arrays, so restore onto any mesh is
    a plain ``device_put`` with the new shardings.  ``compress_mode``
    records the pod-axis gradient compressor next to ``mesh_shape`` when
    the tree carries per-pod error-feedback state (key ``err``), so a
    resume under a different compressor can be flagged instead of
    silently mixing residual semantics."""
    os.makedirs(ckpt_dir, exist_ok=True)
    _prune_tmp_dirs(ckpt_dir)
    tmp = os.path.join(ckpt_dir, f".tmp_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp)

    host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)
    flat, _ = _flatten(host_tree)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k: v for k, v in flat.items()})
    manifest = {
        "step": int(step),
        "time": time.time(),
        "mesh_shape": mesh_shape,
        "compress_mode": compress_mode,
        "arrays": {k: {"shape": list(np.shape(v)),
                       "dtype": str(np.asarray(v).dtype),
                       "sha256": hashlib.sha256(
                           np.ascontiguousarray(v).tobytes()).hexdigest()}
                   for k, v in flat.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _update_latest(ckpt_dir, step)


def _update_latest(ckpt_dir: str, step: int):
    tmp = os.path.join(ckpt_dir, ".latest_tmp")
    with open(tmp, "w") as f:
        f.write(str(step))
    os.replace(tmp, os.path.join(ckpt_dir, "LATEST"))


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                 if d.startswith("step_")] if os.path.isdir(ckpt_dir) else []
        return max(steps) if steps else None
    return int(open(p).read().strip())


def read_manifest(ckpt_dir: str, step: Optional[int] = None) -> Dict:
    """Manifest of a checkpoint without loading its arrays — lets a
    caller inspect what was saved (e.g. whether error-feedback state
    exists, which ``compress_mode`` wrote it) before building a restore
    template."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    with open(os.path.join(ckpt_dir, f"step_{step}",
                           "manifest.json")) as f:
        return json.load(f)


def restore(ckpt_dir: str, step: Optional[int] = None, template=None,
            sharding_fn=None, verify: bool = True):
    """Load a checkpoint.  ``template``: pytree prototype (for structure);
    ``sharding_fn(path, array) -> Sharding|None`` enables elastic
    resharding onto a new mesh.  Returns (tree, manifest)."""
    _prune_tmp_dirs(ckpt_dir)
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    arrays = {k: data[k] for k in data.files}
    if verify:
        bad = [k for k, meta in manifest["arrays"].items()
               if k not in arrays
               or hashlib.sha256(np.ascontiguousarray(arrays[k])
                                 .tobytes()).hexdigest() != meta["sha256"]]
        if bad:
            raise IOError(
                f"checkpoint corruption detected in {len(bad)} array(s): "
                + ", ".join(sorted(bad)))
    if template is None:
        return arrays, manifest
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, proto in flat_t:
        k = jax.tree_util.keystr(path)
        a = arrays[k].astype(proto.dtype) if hasattr(proto, "dtype") \
            else arrays[k]
        if sharding_fn is not None:
            sh = sharding_fn(k, a)
            a = jax.device_put(a, sh) if sh is not None else jax.numpy.asarray(a)
        else:
            a = jax.numpy.asarray(a)
        leaves.append(a)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves), manifest


def restore_latest_intact(ckpt_dir: str, template=None, sharding_fn=None,
                          verify: bool = True, template_fn=None,
                          log_fn=None):
    """Restore the newest checkpoint that passes checksum verification.

    Walks ``step_<n>`` dirs newest-first; a corrupted (or unreadable)
    checkpoint is logged and skipped instead of killing the run — the
    fault-model contract (DESIGN.md §10) is that a bad latest checkpoint
    degrades resume to the previous intact one.  ``template_fn(manifest)``
    lets the caller build the restore template per-checkpoint (e.g. the
    ``err`` error-feedback leaf only exists in pod-mode saves); it takes
    precedence over ``template``.  Returns ``(tree, manifest)``; raises
    ``FileNotFoundError`` if no checkpoints exist and ``IOError`` if none
    is intact."""
    _prune_tmp_dirs(ckpt_dir)
    steps = sorted((int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                    if d.startswith("step_")), reverse=True) \
        if os.path.isdir(ckpt_dir) else []
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    last_err: Optional[BaseException] = None
    for step in steps:
        try:
            tpl = template
            if template_fn is not None:
                tpl = template_fn(read_manifest(ckpt_dir, step))
            return restore(ckpt_dir, step, template=tpl,
                           sharding_fn=sharding_fn, verify=verify)
        except Exception as e:   # corruption surfaces as IOError (sha256
            last_err = e         # mismatch), BadZipFile/zlib.error (zip
            # decode) or KeyError (missing array) depending on where the
            # damage landed — all mean "this step is unusable, try older"
            if log_fn is not None:
                log_fn(f"[ckpt] step_{step} unusable ({e}); "
                       f"falling back to previous checkpoint")
    raise IOError(f"no intact checkpoint in {ckpt_dir} "
                  f"(tried steps {steps})") from last_err


class AsyncCheckpointer:
    """Background-thread writer: training only blocks for device_get.
    A bounded queue (depth 1) applies back-pressure instead of piling up
    snapshots; ``wait()`` drains before exit."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree, extra, mesh_shape, compress_mode = item
            try:
                save(self.ckpt_dir, step, host_tree, extra,
                     mesh_shape=mesh_shape, compress_mode=compress_mode)
            except BaseException as e:          # surfaced on next submit/wait
                self._err = e
            finally:
                self._q.task_done()

    def submit(self, step: int, tree, extra: Optional[Dict] = None,
               mesh_shape: Optional[Dict[str, int]] = None,
               compress_mode: Optional[str] = None):
        if self._err:
            raise self._err
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)),
                                 tree)
        self._q.put((step, host_tree, extra, mesh_shape, compress_mode))

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join()
