"""Deterministic fault injection for the chaos harness (DESIGN.md §10).

Every injector here is *deterministic* — faults fire at a configured
epoch/step, not at random — so each chaos test (tests/test_chaos.py)
asserts an exact documented recovery path:

  * ``FaultPlan.poison_plan``      -> in-scan non-finite guard gates the
                                      step off bit-exactly (engine.py)
  * ``FaultPlan.maybe_fail_prefetch`` -> PlanPrefetcher retries with
                                      capped backoff (plan_prefetch.py)
  * ``FaultPlan.maybe_preempt``    -> PreemptionHandler finishes the
                                      chunk, writes an emergency
                                      checkpoint, exits resumably
  * ``corrupt_checkpoint`` / ``tamper_arrays`` -> restore refuses the
                                      step, ``restore_latest_intact``
                                      falls back to the previous one
  * ``failing_selection_kernels``  -> ResidentSelector falls back
                                      pallas -> xla -> soft-random

Injectors fire *once* per ``FaultPlan`` instance: after a watchdog
rollback the replayed epochs run clean, which is exactly the transient
fault model the recovery semantics are written for.
"""
from __future__ import annotations

import contextlib
import os
import signal
import threading
from typing import Optional, Tuple

import numpy as np


class FaultPlan:
    """A schedule of deterministic, fire-once faults threaded through
    ``train_with_selection(fault_plan=...)``.

    ``nan_step``/``inf_step`` are ``(epoch, step)`` pairs poisoning one
    plan-weight row (the weights multiply into the per-example loss, so
    the poison propagates into loss and gradients on device);
    ``nan_epoch`` poisons every step of one epoch — enough consecutive
    skips to trip the divergence watchdog.  ``drop_step`` turns one plan
    row into padding (ids -1, weight 0) instead — not a fault but the
    *reference* for the guard's documented semantics: a guarded-off
    non-finite step must be bit-identical to the run that trained the
    same schedule with that batch as a padding row (the ``step_on``
    gate).  ``prefetch_fail_epochs``
    raises from inside the plan builder the first time each listed
    epoch's plan is built.  ``preempt_after_epoch`` raises SIGTERM in
    the loop's own thread once that epoch's chunk completes.
    """

    def __init__(self, *, nan_step: Optional[Tuple[int, int]] = None,
                 inf_step: Optional[Tuple[int, int]] = None,
                 nan_epoch: Optional[int] = None,
                 drop_step: Optional[Tuple[int, int]] = None,
                 prefetch_fail_epochs: Tuple[int, ...] = (),
                 preempt_after_epoch: Optional[int] = None):
        self.nan_step = nan_step
        self.inf_step = inf_step
        self.nan_epoch = nan_epoch
        self.drop_step = drop_step
        self.prefetch_fail_epochs = tuple(prefetch_fail_epochs)
        self.preempt_after_epoch = preempt_after_epoch
        self._fired = set()

    def _once(self, tag) -> bool:
        if tag in self._fired:
            return False
        self._fired.add(tag)
        return True

    # -- plan poisoning (caught by the in-scan non-finite guard) --------
    def poison_plan(self, epoch: int, plan):
        idx, w = plan
        w = np.array(w, np.float32, copy=True)
        if (self.nan_step is not None and self.nan_step[0] == epoch
                and self._once(("nan_step", epoch))):
            w[self.nan_step[1] % w.shape[0]] = np.nan
        if (self.inf_step is not None and self.inf_step[0] == epoch
                and self._once(("inf_step", epoch))):
            w[self.inf_step[1] % w.shape[0]] = np.inf
        if self.nan_epoch == epoch and self._once(("nan_epoch", epoch)):
            w[:] = np.nan
        if (self.drop_step is not None and self.drop_step[0] == epoch
                and self._once(("drop_step", epoch))):
            idx = np.array(idx, np.int32, copy=True)
            row = self.drop_step[1] % w.shape[0]
            idx[row] = -1
            w[row] = 0.0
        return idx, w

    # -- prefetch worker crash (caught by PlanPrefetcher retries) -------
    def maybe_fail_prefetch(self, epoch: int):
        if (epoch in self.prefetch_fail_epochs
                and self._once(("prefetch", epoch))):
            raise RuntimeError(f"injected prefetch failure at epoch "
                               f"{epoch}")

    # -- preemption (caught by PreemptionHandler) -----------------------
    def maybe_preempt(self, epoch: int):
        if (self.preempt_after_epoch is not None
                and epoch >= self.preempt_after_epoch
                and self._once("preempt")):
            signal.raise_signal(signal.SIGTERM)


class PreemptionHandler:
    """SIGTERM/SIGINT -> set a flag; the training loop finishes the
    in-flight chunk, writes an emergency checkpoint through the async
    writer and returns with ``History.preempted`` and a resumable
    manifest (DESIGN.md §10).  Installing from a non-main thread is a
    no-op (``signal.signal`` only works on the main thread) — the chunk
    dispatch still runs, preemption handling is simply owned by
    whichever loop lives on the main thread."""

    SIGNALS = (signal.SIGTERM, signal.SIGINT)

    def __init__(self, log_fn=None):
        self._log = log_fn or (lambda s: None)
        self.triggered = False
        self._prev = {}

    def _handle(self, signum, frame):
        self.triggered = True
        self._log(f"received signal {signum}; checkpointing and exiting "
                  f"after the in-flight chunk")

    def install(self) -> "PreemptionHandler":
        if threading.current_thread() is not threading.main_thread():
            return self
        try:
            for s in self.SIGNALS:
                self._prev[s] = signal.signal(s, self._handle)
        except ValueError:      # embedded interpreters without signal API
            self._prev.clear()
        return self

    def uninstall(self):
        for s, prev in self._prev.items():
            try:
                signal.signal(s, prev)
            except ValueError:
                pass
        self._prev.clear()


# ---------------------------------------------------------------------------
# checkpoint corruption
# ---------------------------------------------------------------------------

def corrupt_checkpoint(ckpt_dir: str, step: Optional[int] = None,
                       n_bytes: int = 64) -> str:
    """Flip bytes in the middle of a checkpoint's ``arrays.npz`` — a
    deterministic stand-in for disk/transfer corruption.  The damaged
    archive fails at decode (zip CRC) or at the manifest's per-array
    sha256, and ``restore_latest_intact`` must fall back to the previous
    intact step.  Returns the damaged file's path."""
    from repro.train import checkpoint as ckpt_mod
    step = ckpt_mod.latest_step(ckpt_dir) if step is None else step
    path = os.path.join(ckpt_dir, f"step_{step}", "arrays.npz")
    with open(path, "r+b") as f:
        f.seek(0, os.SEEK_END)
        size = f.tell()
        pos = size // 2
        f.seek(pos)
        chunk = f.read(min(n_bytes, max(size - pos, 1)))
        f.seek(pos)
        f.write(bytes(b ^ 0xFF for b in chunk))
    return path


def tamper_arrays(ckpt_dir: str, step: Optional[int] = None, keys=None):
    """Rewrite ``arrays.npz`` with perturbed values for ``keys`` (default
    all) while leaving the manifest untouched: a *valid* archive whose
    contents no longer match their recorded sha256.  This exercises the
    checksum verification proper — ``corrupt_checkpoint`` byte-flips the
    zip container, which fails earlier at decode — and lets a test
    assert that ``restore`` names *every* corrupted array.  Returns the
    list of tampered keys."""
    from repro.train import checkpoint as ckpt_mod
    step = ckpt_mod.latest_step(ckpt_dir) if step is None else step
    path = os.path.join(ckpt_dir, f"step_{step}", "arrays.npz")
    data = np.load(path)
    arrays = {k: np.array(data[k]) for k in data.files}
    data.close()
    targets = list(keys) if keys is not None else list(arrays)
    for k in targets:
        arrays[k] = arrays[k] + np.ones((), arrays[k].dtype)
    np.savez(path, **arrays)
    return targets


# ---------------------------------------------------------------------------
# selection-kernel failure
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def failing_selection_kernels(impls=("pallas",)):
    """Patch ``repro.core.pgm.units_gradients_batched`` so stage A raises
    for the listed kernel backends.  ``ResidentSelector`` resolves the
    module global at trace time and re-jits on fallback, so a selector
    retrying on the XLA path sees the unpatched function for
    ``kernel_impl="xla"``.  Pass ``("all",)`` (or list every backend) to
    simulate total scorer failure and exercise the soft-random
    degradation."""
    from repro.core import pgm as pgm_mod
    orig = pgm_mod.units_gradients_batched

    def wrapper(*args, **kwargs):
        impl = kwargs.get("kernel_impl")
        if "all" in impls or impl in impls:
            raise RuntimeError(f"injected kernel failure ({impl!r})")
        return orig(*args, **kwargs)

    pgm_mod.units_gradients_batched = wrapper
    try:
        yield
    finally:
        pgm_mod.units_gradients_batched = orig
