"""Pure-jnp oracle: masked dense sliding-window attention."""
import jax
import jax.numpy as jnp


def swa_attn_ref(q, k, v, *, window: int):
    B, H, S, hd = q.shape
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = (qp - kp >= 0) & (qp - kp < window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
