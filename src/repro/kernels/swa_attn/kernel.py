"""Pallas TPU kernel: sliding-window flash attention (forward).

Used by the local layers of mixtral / starcoder2 / gemma3 /
recurrentgemma.  Grid: (batch*heads, q tiles, band tiles); the band for q
tile i covers kv tiles [i - W/TQ, i] (W must be a multiple of the q tile).
Online softmax state (m, l, acc) lives in VMEM scratch across the
sequential band axis.  Out-of-range band tiles are index-clamped for the
load and fully masked in-kernel (``tile_idx >= 0`` guard prevents the
clamped duplicate from double counting).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _swa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                *, window, tq, n_band, scale):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)               # (TQ, hd)
    k = k_ref[...].astype(jnp.float32)               # (TQ, hd)  (band tile)
    v = v_ref[...].astype(jnp.float32)

    tile_idx = i - (n_band - 1) + j                  # absolute kv tile id
    q_pos = i * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tq), 0)
    kv_pos = tile_idx * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tq), 1)
    dpos = q_pos - kv_pos
    mask = (dpos >= 0) & (dpos < window) & (tile_idx >= 0)

    s = jnp.where(mask, (q @ k.T) * scale, NEG)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _():
        o_ref[...] = (acc_ref[...]
                      / jnp.maximum(l_ref[...], 1e-30)[:, None]
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "tq", "interpret"))
def swa_attn(q, k, v, *, window: int, tq: int = 256, interpret: bool = True):
    """q,k,v: (B, H, S, hd), causal sliding-window of ``window`` positions
    (q attends to kv in (q-window, q]).  Returns (B, H, S, hd)."""
    B, H, S, hd = q.shape
    tq = min(tq, S)
    assert S % tq == 0, (S, tq)
    assert window % tq == 0 or window <= tq, (window, tq)
    n_band = max(window // tq, 1) + 1
    scale = 1.0 / (hd ** 0.5)

    qf = q.reshape(B * H, S, hd)
    kf = k.reshape(B * H, S, hd)
    vf = v.reshape(B * H, S, hd)
    grid = (B * H, S // tq, n_band)

    def kv_index(b, i, j):
        return (b, jnp.maximum(i - (n_band - 1) + j, 0), 0)

    out = pl.pallas_call(
        functools.partial(_swa_kernel, window=window, tq=tq, n_band=n_band,
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, tq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, tq, hd), kv_index),
            pl.BlockSpec((None, tq, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((None, tq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq,), jnp.float32),
            pltpu.VMEM((tq,), jnp.float32),
            pltpu.VMEM((tq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd)
