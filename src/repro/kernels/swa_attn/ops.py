"""jit'd wrapper with backend fallback (jnp band attention off-TPU)."""
from __future__ import annotations

import jax

from repro.kernels.swa_attn.kernel import swa_attn as _pallas_swa
from repro.kernels.swa_attn.ref import swa_attn_ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def swa_attn_op(q, k, v, *, window: int, use_pallas: bool = None,
                interpret: bool = None):
    use_pallas = on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        interpret = (not on_tpu()) if interpret is None else interpret
        return _pallas_swa(q, k, v, window=window, interpret=interpret)
    return swa_attn_ref(q, k, v, window=window)
