"""Pure-jnp oracle for the grad_sketch kernel: materializes the softmax
error matrix directly (O(N*V) memory — test sizes only)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def grad_sketch_ref(h, w, r_h, r_v, targets, scale):
    h32 = h.astype(jnp.float32)
    logits = h32 @ w.astype(jnp.float32)
    p = jax.nn.softmax(logits, axis=-1)
    e = p - jax.nn.one_hot(targets, w.shape[1], dtype=jnp.float32)
    e = e * scale.astype(jnp.float32)[:, None]
    return (h32 @ r_h.astype(jnp.float32)).T @ (e @ r_v.astype(jnp.float32))


def grad_sketch_units_ref(h, w, r_h, r_v, targets, scale):
    """(U, n, d) / (U, n) inputs -> (U, k1, k2) per-unit sketches."""
    return jax.vmap(
        lambda hu, tu, su: grad_sketch_ref(hu, w, r_h, r_v, tu, su)
    )(h, targets, scale)
