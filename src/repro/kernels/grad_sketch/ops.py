"""jit'd public wrapper for the fused gradient-sketch.

Backend selection: Pallas kernel on TPU (or interpret=True for CPU
validation); the vocab-chunked pure-jnp path (core.lastlayer.streamed_er2)
elsewhere — same memory behaviour, XLA-fused.  Callers holding a
``PGMConfig.kernel_impl`` string pass it as ``impl`` and both flags are
resolved by ``kernels/backend.py``; the legacy ``use_pallas``/``interpret``
kwargs keep working for direct callers and tests.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.lastlayer import streamed_er2
from repro.kernels.backend import on_tpu, pallas_flags
from repro.kernels.grad_sketch.kernel import grad_sketch as _pallas_sketch
from repro.kernels.grad_sketch.kernel import (
    grad_sketch_units as _pallas_sketch_units,
)


def grad_sketch_op(h, w, r_h, r_v, targets, scale, *,
                   use_pallas: bool = None, interpret: bool = None,
                   vocab_chunk: int = 8192, impl: Optional[str] = None):
    """h (N,d); w (d,V); r_h (d,k1); r_v (V,k2); targets (N,); scale (N,)
    -> (k1, k2) fp32 sketch of the last-layer gradient."""
    if impl is not None:
        use_pallas, interpret = pallas_flags(impl)
    use_pallas = on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        interpret = (not on_tpu()) if interpret is None else interpret
        return _pallas_sketch(h, w, r_h, r_v, targets, scale,
                              interpret=interpret)
    er2 = streamed_er2(h.astype(jnp.float32), w, targets,
                       scale.astype(jnp.float32), r_v, vocab_chunk)
    hr = h.astype(jnp.float32) @ r_h.astype(jnp.float32)
    return hr.T @ er2


def grad_sketch_units_op(h, w, r_h, r_v, targets, scale, *,
                         use_pallas: bool = None, interpret: bool = None,
                         vocab_chunk: int = 8192,
                         impl: Optional[str] = None):
    """Per-unit fused sketch: h (U,n,d); targets/scale (U,n) -> (U,k1,k2).

    The stage-A entry point for the batched LM path
    (``core/lastlayer.py:units_gradients_batched``).  The XLA fallback
    flattens the unit axis and reuses ``streamed_er2`` + a segment einsum
    — bit-identical to the historical batched-path math.
    """
    if impl is not None:
        use_pallas, interpret = pallas_flags(impl)
    use_pallas = on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        interpret = (not on_tpu()) if interpret is None else interpret
        return _pallas_sketch_units(h, w, r_h, r_v, targets, scale,
                                    interpret=interpret)
    U, n, d = h.shape
    k1 = r_h.shape[1]
    k2 = r_v.shape[1]
    hf = h.reshape(-1, d).astype(jnp.float32)
    er2 = streamed_er2(hf, w, targets.reshape(-1).astype(jnp.int32),
                       scale.reshape(-1).astype(jnp.float32), r_v,
                       vocab_chunk)
    hr = hf @ r_h.astype(jnp.float32)
    return jnp.einsum("unk,unl->ukl", hr.reshape(U, n, k1),
                      er2.reshape(U, n, k2))
