"""jit'd public wrapper for the fused gradient-sketch.

Backend selection: Pallas kernel on TPU (or interpret=True for CPU
validation); the vocab-chunked pure-jnp path (core.lastlayer.streamed_er2)
elsewhere — same memory behaviour, XLA-fused."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lastlayer import streamed_er2
from repro.kernels.grad_sketch.kernel import grad_sketch as _pallas_sketch


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def grad_sketch_op(h, w, r_h, r_v, targets, scale, *,
                   use_pallas: bool = None, interpret: bool = None,
                   vocab_chunk: int = 8192):
    """h (N,d); w (d,V); r_h (d,k1); r_v (V,k2); targets (N,); scale (N,)
    -> (k1, k2) fp32 sketch of the last-layer gradient."""
    use_pallas = on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        interpret = (not on_tpu()) if interpret is None else interpret
        return _pallas_sketch(h, w, r_h, r_v, targets, scale,
                              interpret=interpret)
    er2 = streamed_er2(h.astype(jnp.float32), w, targets,
                       scale.astype(jnp.float32), r_v, vocab_chunk)
    hr = h.astype(jnp.float32) @ r_h.astype(jnp.float32)
    return hr.T @ er2
