"""Pallas TPU kernel: fused last-layer gradient sketch (DESIGN.md §2/§9).

Computes, per selection unit,  sketch = (H R1)^T @ (E R2)  where
  E = diag(scale) * (softmax(H W) - onehot(targets))
without materializing the (N, V) error/probability matrix, the (N, k2)
``E R2`` intermediate, or the (d, V) gradient.  Vocab is streamed
tile-by-tile from HBM into VMEM with an online-softmax (flash-style)
normalization over the vocab axis — the TPU-native reformulation of the
paper's gradient-memory problem.

The grid is unit-blocked: ``(U, row tiles, vocab tiles)`` with the vocab
axis minor (the TPU grid is sequential over minor axes, so VMEM scratch
carries running state across vocab tiles and the (1, k1, k2) output
block of unit ``u`` accumulates across its row tiles).  ``grad_sketch``
(one sketch over all rows) is the ``U = 1`` special case of
``grad_sketch_units`` — one kernel body serves both the per-unit op and
the resident selector's batched stage A.

Two sequential-grid kernels:
  1. ``_lse_kernel``     — running logsumexp of H W over vocab tiles;
  2. ``_sketch_kernel``  — accumulates  P_tile @ R2_tile  into an er2
     scratch, finalizes  er2 = (er2 - R2[targets]) * scale  at the last
     vocab tile, and accumulates  (H R1)_tile^T @ er2  into the unit's
     output block.

The vocab tile ``tv`` defaults to the shared VMEM-budget resolver
(``core/chunking.py:auto_vocab_chunk`` with ``tn + d`` live rows — the
(tn, tv) logits tile plus the (d, tv) head slab), the same resolver the
engine uses to auto-tune the fused RNN-T loss's ``loss_vocab_chunk``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.chunking import auto_vocab_chunk

NEG = -1e30


def _lse_kernel(h_ref, w_ref, logz_ref, m_ref, s_ref, *, v_total, tv):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        s_ref[...] = jnp.zeros_like(s_ref)

    h = h_ref[0].astype(jnp.float32)                # (TN, d)
    w = w_ref[...].astype(jnp.float32)              # (d, TV)
    logits = h @ w                                  # MXU
    col = j * tv + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(col < v_total, logits, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=1))
    s_ref[...] = (s_ref[...] * jnp.exp(m_prev - m_new)
                  + jnp.exp(logits - m_new[:, None]).sum(axis=1))
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _():
        logz = m_ref[...] + jnp.log(jnp.maximum(s_ref[...], 1e-30))
        logz_ref[...] = logz[None]


def _sketch_kernel(h_ref, w_ref, rv_ref, logz_ref, rvt_ref, scale_ref,
                   hr_ref, out_ref, er2_ref, *, v_total, tv):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(jnp.logical_and(i == 0, j == 0))
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(j == 0)
    def _():
        er2_ref[...] = jnp.zeros_like(er2_ref)

    h = h_ref[0].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    logits = h @ w                                  # (TN, TV)
    col = j * tv + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    p = jnp.where(col < v_total,
                  jnp.exp(logits - logz_ref[0][:, None]), 0.0)
    er2_ref[...] += p @ rv_ref[...].astype(jnp.float32)

    @pl.when(j == pl.num_programs(2) - 1)
    def _():
        er2 = (er2_ref[...] - rvt_ref[0].astype(jnp.float32))
        er2 = er2 * scale_ref[0][:, None]
        out_ref[...] += (hr_ref[0].astype(jnp.float32).T @ er2)[None]


@functools.partial(jax.jit, static_argnames=("tn", "tv", "interpret"))
def grad_sketch_units(h, w, r_h, r_v, targets, scale, *, tn: int = 256,
                      tv: int = 0, interpret: bool = True):
    """h (U,n,d); w (d,V); r_h (d,k1); r_v (V,k2); targets (U,n);
    scale (U,n) -> per-unit sketches (U, k1, k2) fp32.

    Padded rows (n not a tile multiple) ride through with scale 0, so
    they contribute nothing to the finalized er2 or the output block.
    """
    U, n, d = h.shape
    V = w.shape[1]
    k1, k2 = r_h.shape[1], r_v.shape[1]
    tn = min(tn, max(n, 8))
    tv = auto_vocab_chunk(tn + d, V) if tv <= 0 else min(tv, V)

    n_pad = (-n) % tn
    v_pad = (-V) % tv
    hp = jnp.pad(h, ((0, 0), (0, n_pad), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, v_pad)))
    rvp = jnp.pad(r_v, ((0, v_pad), (0, 0)))
    tp = jnp.pad(targets, ((0, 0), (0, n_pad)))
    sp = jnp.pad(scale, ((0, 0), (0, n_pad)))
    np_, Vp = n + n_pad, V + v_pad
    gn, gv = np_ // tn, Vp // tv

    # small host-side precomputations (negligible FLOPs; see module doc)
    hr = hp.astype(jnp.float32) @ r_h.astype(jnp.float32)      # (U,np_,k1)
    rvt = r_v.astype(jnp.float32)[jnp.clip(tp, 0, V - 1)]      # (U,np_,k2)

    logz = pl.pallas_call(
        functools.partial(_lse_kernel, v_total=V, tv=tv),
        grid=(U, gn, gv),
        in_specs=[
            pl.BlockSpec((1, tn, d), lambda u, i, j: (u, i, 0)),
            pl.BlockSpec((d, tv), lambda u, i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, tn), lambda u, i, j: (u, i)),
        out_shape=jax.ShapeDtypeStruct((U, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tn,), jnp.float32),
                        pltpu.VMEM((tn,), jnp.float32)],
        interpret=interpret,
    )(hp, wp)

    sketch = pl.pallas_call(
        functools.partial(_sketch_kernel, v_total=V, tv=tv),
        grid=(U, gn, gv),
        in_specs=[
            pl.BlockSpec((1, tn, d), lambda u, i, j: (u, i, 0)),
            pl.BlockSpec((d, tv), lambda u, i, j: (0, j)),
            pl.BlockSpec((tv, k2), lambda u, i, j: (j, 0)),
            pl.BlockSpec((1, tn), lambda u, i, j: (u, i)),
            pl.BlockSpec((1, tn, k2), lambda u, i, j: (u, i, 0)),
            pl.BlockSpec((1, tn), lambda u, i, j: (u, i)),
            pl.BlockSpec((1, tn, k1), lambda u, i, j: (u, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, k1, k2), lambda u, i, j: (u, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((U, k1, k2), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tn, k2), jnp.float32)],
        interpret=interpret,
    )(hp, wp, rvp, logz, rvt, sp, hr)
    return sketch


@functools.partial(jax.jit, static_argnames=("tn", "tv", "interpret"))
def grad_sketch(h, w, r_h, r_v, targets, scale, *, tn: int = 256,
                tv: int = 0, interpret: bool = True):
    """h (N,d); w (d,V); r_h (d,k1); r_v (V,k2); targets (N,); scale (N,)
    -> sketch (k1, k2) fp32.  The U = 1 case of ``grad_sketch_units``."""
    return grad_sketch_units(h[None], w, r_h, r_v, targets[None],
                             scale[None], tn=tn, tv=tv,
                             interpret=interpret)[0]
