"""Pallas TPU kernel: fused last-layer gradient sketch (DESIGN.md §2).

Computes  sketch = (H R1)^T @ (E R2)  where
  E = diag(scale) * (softmax(H W) - onehot(targets))
without materializing the (N, V) error/probability matrix or the (d, V)
gradient.  Vocab is streamed tile-by-tile from HBM into VMEM with an
online-softmax (flash-style) normalization over the vocab axis — the
TPU-native reformulation of the paper's gradient-memory problem.

Two sequential-grid kernels (the TPU grid is sequential over the minor
axis, so VMEM scratch carries running state across vocab tiles):
  1. ``_lse_kernel``     — running logsumexp of H W over vocab tiles;
  2. ``_sketch_kernel``  — accumulates  P_tile @ R2_tile  into an er2
     scratch, finalizes  er2 = (er2 - R2[targets]) * scale  at the last
     vocab tile, and accumulates  (H R1)_tile^T @ er2  into the output.

VMEM budget per step (defaults TN=256, TV=512, d<=5376 fp32):
  h tile 5.2 MB + w tile 10.5 MB + small operands < 16 MB v5e VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _lse_kernel(h_ref, w_ref, logz_ref, m_ref, s_ref, *, v_total, tv):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        s_ref[...] = jnp.zeros_like(s_ref)

    h = h_ref[...].astype(jnp.float32)              # (TN, d)
    w = w_ref[...].astype(jnp.float32)              # (d, TV)
    logits = h @ w                                  # MXU
    col = j * tv + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(col < v_total, logits, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=1))
    s_ref[...] = (s_ref[...] * jnp.exp(m_prev - m_new)
                  + jnp.exp(logits - m_new[:, None]).sum(axis=1))
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        logz_ref[...] = m_ref[...] + jnp.log(jnp.maximum(s_ref[...], 1e-30))


def _sketch_kernel(h_ref, w_ref, rv_ref, logz_ref, rvt_ref, scale_ref,
                   hr_ref, out_ref, er2_ref, *, v_total, tv):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(jnp.logical_and(i == 0, j == 0))
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(j == 0)
    def _():
        er2_ref[...] = jnp.zeros_like(er2_ref)

    h = h_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    logits = h @ w                                  # (TN, TV)
    col = j * tv + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    p = jnp.where(col < v_total,
                  jnp.exp(logits - logz_ref[...][:, None]), 0.0)
    er2_ref[...] += p @ rv_ref[...].astype(jnp.float32)

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        er2 = (er2_ref[...] - rvt_ref[...].astype(jnp.float32))
        er2 = er2 * scale_ref[...][:, None]
        out_ref[...] += hr_ref[...].astype(jnp.float32).T @ er2


@functools.partial(jax.jit, static_argnames=("tn", "tv", "interpret"))
def grad_sketch(h, w, r_h, r_v, targets, scale, *, tn: int = 256,
                tv: int = 512, interpret: bool = True):
    """h (N,d); w (d,V); r_h (d,k1); r_v (V,k2); targets (N,); scale (N,)
    -> sketch (k1, k2) fp32."""
    N, d = h.shape
    V = w.shape[1]
    k1, k2 = r_h.shape[1], r_v.shape[1]
    tn = min(tn, max(N, 8))
    tv = min(tv, V)

    n_pad = (-N) % tn
    v_pad = (-V) % tv
    hp = jnp.pad(h, ((0, n_pad), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, v_pad)))
    rvp = jnp.pad(r_v, ((0, v_pad), (0, 0)))
    tp = jnp.pad(targets, (0, n_pad))
    sp = jnp.pad(scale, (0, n_pad))
    Np, Vp = N + n_pad, V + v_pad
    gn, gv = Np // tn, Vp // tv

    # small host-side precomputations (negligible FLOPs; see module doc)
    hr = hp.astype(jnp.float32) @ r_h.astype(jnp.float32)      # (Np, k1)
    rvt = r_v.astype(jnp.float32)[jnp.clip(tp, 0, V - 1)]      # (Np, k2)

    logz = pl.pallas_call(
        functools.partial(_lse_kernel, v_total=V, tv=tv),
        grid=(gn, gv),
        in_specs=[
            pl.BlockSpec((tn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, tv), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tn,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Np,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tn,), jnp.float32),
                        pltpu.VMEM((tn,), jnp.float32)],
        interpret=interpret,
    )(hp, wp)

    sketch = pl.pallas_call(
        functools.partial(_sketch_kernel, v_total=V, tv=tv),
        grid=(gn, gv),
        in_specs=[
            pl.BlockSpec((tn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, tv), lambda i, j: (0, j)),
            pl.BlockSpec((tv, k2), lambda i, j: (j, 0)),
            pl.BlockSpec((tn,), lambda i, j: (i,)),
            pl.BlockSpec((tn, k2), lambda i, j: (i, 0)),
            pl.BlockSpec((tn,), lambda i, j: (i,)),
            pl.BlockSpec((tn, k1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((k1, k2), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k1, k2), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tn, k2), jnp.float32)],
        interpret=interpret,
    )(hp, wp, rvp, logz, rvt, sp, hr)
    return sketch
