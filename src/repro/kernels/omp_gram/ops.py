"""jit'd wrapper: Pallas on TPU / interpret for validation, XLA elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.omp_gram.kernel import omp_gram as _pallas_gram
from repro.kernels.omp_gram.ref import omp_gram_ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def omp_gram_op(g, *, use_pallas: bool = None, interpret: bool = None):
    use_pallas = on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        interpret = (not on_tpu()) if interpret is None else interpret
        return _pallas_gram(g, interpret=interpret)
    return omp_gram_ref(g)
