"""jit'd wrapper: Pallas on TPU / interpret for validation, XLA elsewhere.

Backend choice is explicit when the caller passes ``impl`` (an
``auto``/``pallas``/``xla`` string from ``PGMConfig.kernel_impl``, see
``kernels/backend.py``); the legacy ``use_pallas``/``interpret`` kwargs
keep working for direct callers and tests.
"""
from __future__ import annotations

from typing import Optional

from repro.kernels.backend import on_tpu, pallas_flags
from repro.kernels.omp_gram.kernel import omp_gram as _pallas_gram
from repro.kernels.omp_gram.kernel import omp_gram_batched as _pallas_batched
from repro.kernels.omp_gram.ref import omp_gram_batched_ref, omp_gram_ref


def omp_gram_op(g, *, use_pallas: bool = None, interpret: bool = None,
                impl: Optional[str] = None):
    """(n, D) -> (n, n) fp32 Gram matrix."""
    if impl is not None:
        use_pallas, interpret = pallas_flags(impl)
    use_pallas = on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        interpret = (not on_tpu()) if interpret is None else interpret
        return _pallas_gram(g, interpret=interpret)
    return omp_gram_ref(g)


def omp_gram_batched_op(g, *, use_pallas: bool = None,
                        interpret: bool = None,
                        impl: Optional[str] = None):
    """(P, n, D) -> (P, n, n) fp32 per-partition Gram matrices — the
    stage-B entry point (``core/pgm.py:partitioned_gm``)."""
    if impl is not None:
        use_pallas, interpret = pallas_flags(impl)
    use_pallas = on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        interpret = (not on_tpu()) if interpret is None else interpret
        return _pallas_batched(g, interpret=interpret)
    return omp_gram_batched_ref(g)
