"""Pure-jnp oracle for omp_gram."""
import jax.numpy as jnp


def omp_gram_ref(g):
    g32 = g.astype(jnp.float32)
    return g32 @ g32.T
