"""Pure-jnp oracle for omp_gram."""
import jax.numpy as jnp


def omp_gram_ref(g):
    g32 = g.astype(jnp.float32)
    return g32 @ g32.T


def omp_gram_batched_ref(g):
    """(P, n, D) -> (P, n, n): per-partition Grams, batched contraction."""
    g32 = g.astype(jnp.float32)
    return jnp.einsum("pnd,pmd->pnm", g32, g32)
