"""Pallas TPU kernel: tiled Gram matrix  K = G G^T  with fp32 accumulation.

This is the one O(n^2 D) operation in Gram-space OMP (core/gm.py); inputs
are bf16/fp32 unit-gradient sketches (n, D).  Tiling: (ti, tj) output
tiles, sequential accumulation over D tiles in VMEM scratch; MXU-aligned
defaults ti=tj=256, td=512.

The grid carries a leading partition axis so stage B's per-partition
Grams (``core/pgm.py:partitioned_gm`` needs (P, per, per) from
(P, per, D)) come out of one kernel call: ``omp_gram_batched`` runs the
same body on a ``(P, i, j, k)`` grid with per-partition (1, ti, td)
blocks; ``omp_gram`` is its P = 1 special case.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gram_kernel(gi_ref, gj_ref, out_ref, acc_ref):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = gi_ref[0].astype(jnp.float32)
    b = gj_ref[0].astype(jnp.float32)
    acc_ref[...] += a @ b.T

    @pl.when(k == pl.num_programs(3) - 1)
    def _():
        out_ref[...] = acc_ref[...][None]


@functools.partial(jax.jit, static_argnames=("ti", "tj", "td", "interpret"))
def omp_gram_batched(g, *, ti: int = 256, tj: int = 256, td: int = 512,
                     interpret: bool = True) -> jax.Array:
    """g: (P, n, D) -> (P, n, n) fp32 per-partition Gram matrices."""
    P, n, D = g.shape
    ti = min(ti, n)
    tj = min(tj, n)
    td = min(td, D)
    n_pad = (-n) % max(ti, tj)
    d_pad = (-D) % td
    gp = jnp.pad(g, ((0, 0), (0, n_pad), (0, d_pad)))
    Np, Dp = gp.shape[1:]
    grid = (P, Np // ti, Np // tj, Dp // td)

    out = pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ti, td), lambda p, i, j, k: (p, i, k)),
            pl.BlockSpec((1, tj, td), lambda p, i, j, k: (p, j, k)),
        ],
        out_specs=pl.BlockSpec((1, ti, tj), lambda p, i, j, k: (p, i, j)),
        out_shape=jax.ShapeDtypeStruct((P, Np, Np), jnp.float32),
        scratch_shapes=[pltpu.VMEM((ti, tj), jnp.float32)],
        interpret=interpret,
    )(gp, gp)
    return out[:, :n, :n]


@functools.partial(jax.jit, static_argnames=("ti", "tj", "td", "interpret"))
def omp_gram(g, *, ti: int = 256, tj: int = 256, td: int = 512,
             interpret: bool = True) -> jax.Array:
    """g: (n, D) -> (n, n) fp32 Gram matrix (the P = 1 batched case)."""
    return omp_gram_batched(g[None], ti=ti, tj=tj, td=td,
                            interpret=interpret)[0]
