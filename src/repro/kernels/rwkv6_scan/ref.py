"""Oracle: the sequential WKV scan from models/rwkv6.py."""
import jax.numpy as jnp

from repro.models.rwkv6 import wkv_scan


def rwkv6_wkv_ref(r, k, v, w, u):
    B, S, H, N = r.shape
    s0 = jnp.zeros((B, H, N, N), jnp.float32)
    return wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                    v.astype(jnp.float32), w.astype(jnp.float32), u, s0)
