"""Pallas TPU kernel: chunk-parallel RWKV6 WKV recurrence.

Per (batch*head) lane, chunks are processed sequentially over the minor
grid axis with the (N, N) state carried in VMEM scratch; within a chunk
the pairwise-decay attention matrix is dense MXU work:

  cum_i  = sum_{j<=i} log w_j                  (per channel)
  y      = (r * e^{cum_prev}) @ S
         + [(r_i . k_j e^{cum_{i-1}-cum_j})]_{j<i} @ V  + diag bonus
  S'     = diag(e^{cum_C}) S + (k e^{cum_C - cum})^T V

All exponents are <= 0 (see models/rwkv6.py docstring) — no overflow.
Chunk C=64 and head dim N=64 keep every operand MXU-shaped; VMEM per step
~ (C*C*N + C*N*4 + N*N) * 4B ~= 1.2 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, sout_ref, s_ref,
                *, chunk):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[...].astype(jnp.float32)              # (C, N)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    lw = lw_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)              # (1, N)

    cum = jnp.cumsum(lw, axis=0)                    # (C, N)
    cum_prev = cum - lw
    S = s_ref[...]

    # inter-chunk contribution
    y = (r * jnp.exp(cum_prev)) @ S                 # (C, N)

    # intra-chunk strict-lower pairwise decays
    dif = cum_prev[:, None, :] - cum[None, :, :]    # (C, C, N), <=0 for j<i
    C = chunk
    li = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    tri = (li > lj)[:, :, None]
    e = jnp.where(tri, jnp.exp(jnp.minimum(dif, 0.0)), 0.0)
    A = jnp.einsum("in,jn,ijn->ij", r, k, e)
    y = y + A @ v
    # diagonal bonus
    diag = jnp.sum(r * (u * k), axis=1)             # (C,)
    y = y + diag[:, None] * v
    y_ref[...] = y.astype(y_ref.dtype)

    # state update
    tot = cum[-1:, :]                               # (1, N)
    k_dec = k * jnp.exp(tot - cum)
    s_ref[...] = jnp.exp(tot[0])[:, None] * S + k_dec.T @ v

    @pl.when(c == pl.num_programs(1) - 1)
    def _():
        sout_ref[...] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_wkv(r, k, v, w, u, *, chunk: int = 64, interpret: bool = True):
    """r,k,v,w: (B, S, H, N); u: (H, N).  w = decays in (0,1).
    Returns (y (B,S,H,N) fp32, final state (B,H,N,N))."""
    B, S, H, N = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nC = S // chunk

    def lane(t):
        return t.transpose(0, 2, 1, 3).reshape(B * H, S, N)

    rf, kf, vf = lane(r), lane(k), lane(v)
    lwf = lane(jnp.log(jnp.clip(w.astype(jnp.float32), 1e-8, 1.0)))
    uf = jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, 1, N)

    grid = (B * H, nC)
    y, s_out = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, 1, N), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, N, N), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, N), jnp.float32),
            jax.ShapeDtypeStruct((B * H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, lwf, uf)
    y = y.reshape(B, H, S, N).transpose(0, 2, 1, 3)
    return y, s_out.reshape(B, H, N, N)
