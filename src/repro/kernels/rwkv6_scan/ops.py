"""jit'd wrapper: Pallas chunked WKV on TPU, jnp chunked path elsewhere."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_scan.kernel import rwkv6_wkv as _pallas_wkv
from repro.models.rwkv6 import wkv_chunked


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def rwkv6_wkv_op(r, k, v, w, u, *, use_pallas: bool = None,
                 interpret: bool = None, chunk: int = 64):
    use_pallas = on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        interpret = (not on_tpu()) if interpret is None else interpret
        return _pallas_wkv(r, k, v, w, u, chunk=chunk, interpret=interpret)
    B, S, H, N = r.shape
    s0 = jnp.zeros((B, H, N, N), jnp.float32)
    return wkv_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                       v.astype(jnp.float32), w.astype(jnp.float32), u, s0,
                       chunk=chunk)
