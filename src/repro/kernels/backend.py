"""Explicit kernel-backend selection for the selection-round kernels.

Before PR 8 every ``ops.py`` wrapper decided its backend implicitly
(``on_tpu()`` at call time).  That stays the default, but the choice is
now a first-class, loggable knob: ``PGMConfig.kernel_impl`` /
``--selection-kernels`` take one of

* ``"auto"``   — Pallas on TPU, the XLA reference path elsewhere (the
  old implicit behaviour);
* ``"pallas"`` — force the Pallas kernels; off-TPU they run in
  interpret mode (bit-faithful CPU emulation — this is what the parity
  suite in ``tests/test_selection_kernels.py`` forces on);
* ``"xla"``    — force the pure-jnp reference path everywhere.

``resolve_kernel_impl`` collapses ``auto`` against the live backend so
the resolved choice can be logged once per selector build and threaded
as a jit-static string from there on.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

KERNEL_IMPLS = ("auto", "pallas", "xla")


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_kernel_impl(impl: Optional[str] = "auto") -> str:
    """Collapse an ``auto``/``pallas``/``xla`` request against the live
    backend -> ``"pallas"`` or ``"xla"``."""
    impl = "auto" if impl is None else impl
    if impl not in KERNEL_IMPLS:
        raise ValueError(
            f"kernel_impl must be one of {KERNEL_IMPLS}, got {impl!r}")
    if impl == "auto":
        return "pallas" if on_tpu() else "xla"
    return impl


def pallas_flags(impl: Optional[str]) -> Tuple[bool, bool]:
    """``(use_pallas, interpret)`` for the kernel ``ops.py`` wrappers:
    compiled Pallas on TPU, interpret-mode Pallas off-TPU when forced."""
    resolved = resolve_kernel_impl(impl)
    return resolved == "pallas", not on_tpu()
