"""Pallas TPU kernel: RNN-T lattice wavefront scan (DESIGN.md §2).

Computes the whole (T, U+1) lattice recurrence
  rows[t] = row_update(logaddexp(rows[t-1] + mult[t], add[t]), emit[t])
in one ``pallas_call``: the TPU grid is sequential over T, a VMEM
scratch carries the previous row across grid steps, and the within-row
first-order log-semiring recurrence
  a[u] = logaddexp(base[u], a[u-1] + emit[u])
is solved with a Hillis–Steele doubling scan — ``ceil(log2(U1))``
vectorized (B, U1) steps instead of U1 sequential ones, the in-kernel
twin of the ``lax.associative_scan`` row update in
``core/rnnt_loss.py`` (its oracle; see ``ref.py``).

Combine rule for the pair (c, b) = (emit prefix, partial row):
  (c1, b1) . (c2, b2) = (c1 + c2, logaddexp(b1 + c2, b2))
with identity (0, NEG) shifted in at the row head.

VMEM budget per step: 4 row tiles of (B, U1) fp32 plus the carry —
kilobytes at any realistic (B, U) — so the kernel is HBM-bandwidth
bound on the three (T, B, U1) streams, with no (B, T, U, V) traffic at
all (the vocab never enters the lattice).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _lattice_kernel(mult_ref, add_ref, emit_ref, out_ref, carry_ref, *,
                    n_u: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _():
        carry_ref[...] = jnp.full_like(carry_ref, NEG)

    base = jnp.logaddexp(carry_ref[...] + mult_ref[0], add_ref[0])
    c = emit_ref[0]                                    # (B, U1)
    b = base
    d = 1
    while d < n_u:                                     # Hillis–Steele
        B = b.shape[0]
        c_shift = jnp.concatenate(
            [jnp.zeros((B, d), b.dtype), c[:, :-d]], axis=1)
        b_shift = jnp.concatenate(
            [jnp.full((B, d), NEG, b.dtype), b[:, :-d]], axis=1)
        b = jnp.logaddexp(b_shift + c, b)
        c = c_shift + c
        d *= 2
    carry_ref[...] = b
    out_ref[0] = b


@functools.partial(jax.jit, static_argnames=("interpret",))
def rnnt_lattice(mult, add, emit, *, interpret: bool = True):
    """mult, add, emit: (T, B, U1) fp32 -> lattice rows (T, B, U1) fp32.

    ``emit[t, :, 0]`` must be NEG (position 0 has no within-row
    predecessor); ``add[0]`` seeds the first row (the virtual row -1 is
    NEG).
    """
    T, B, U1 = mult.shape
    f32 = jnp.float32
    row_spec = pl.BlockSpec((1, B, U1), lambda t: (t, 0, 0))
    return pl.pallas_call(
        functools.partial(_lattice_kernel, n_u=U1),
        grid=(T,),
        in_specs=[row_spec, row_spec, row_spec],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((T, B, U1), f32),
        scratch_shapes=[pltpu.VMEM((B, U1), f32)],
        interpret=interpret,
    )(mult.astype(f32), add.astype(f32), emit.astype(f32))
