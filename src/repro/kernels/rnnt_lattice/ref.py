"""XLA reference for the RNN-T lattice scan.

The oracle lives in ``core/rnnt_loss.py:lattice_scan_ref`` (an outer
``lax.scan`` over rows, ``lax.associative_scan`` within a row) — this
module re-exports it under the kernels namespace so every kernel package
keeps the ``{kernel, ops, ref}`` layout, and ``tests/test_kernels.py``
can sweep the Pallas kernel against it.

The recurrence (log semiring, per batch row):
  rows[t] = row_update(logaddexp(rows[t-1] + mult[t], add[t]), emit[t])
  row_update: a[u] = logaddexp(base[u], a[u-1] + emit[u]), emit[0] = NEG
with ``rows[-1] = NEG`` so ``add[0]`` seeds the first row.  The alpha
forward uses it directly; the beta backward uses it on (t, u)-flipped
rows with the terminal blank injected through ``add``.
"""
from __future__ import annotations

from repro.core.rnnt_loss import NEG, lattice_scan_ref


def rnnt_lattice_ref(mult, add, emit):
    """(T, B, U1) x3 -> stacked lattice rows (T, B, U1), fp32."""
    return lattice_scan_ref(mult, add, emit)


__all__ = ["NEG", "rnnt_lattice_ref", "lattice_scan_ref"]
