"""jit'd wrapper: Pallas on TPU / interpret for validation, XLA elsewhere.

Consumed by ``core/rnnt_loss.py:_lattice`` (the fused loss's pluggable
lattice backend); same dispatch convention as ``grad_sketch``/
``omp_gram``."""
from __future__ import annotations

import jax

from repro.kernels.rnnt_lattice.kernel import rnnt_lattice as _pallas_lattice
from repro.kernels.rnnt_lattice.ref import rnnt_lattice_ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def rnnt_lattice_op(mult, add, emit, *, use_pallas: bool = None,
                    interpret: bool = None):
    """(T, B, U1) x3 -> lattice rows (T, B, U1) fp32."""
    use_pallas = on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        interpret = (not on_tpu()) if interpret is None else interpret
        return _pallas_lattice(mult, add, emit, interpret=interpret)
    return rnnt_lattice_ref(mult, add, emit)
