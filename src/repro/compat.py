"""Version-robust wrappers over JAX APIs that moved between releases.

``shard_map`` has lived in three places/signatures across the JAX
versions this repo must run on:

  * ``jax.shard_map``                      (new API, ``check_vma=`` kwarg)
  * ``jax.experimental.shard_map.shard_map`` (older API, ``check_rep=``)

All in-repo code imports :func:`shard_map` from here; the wrapper
translates the ``check_vma``/``check_rep`` spelling to whatever the
installed JAX understands (the two kwargs mean the same thing — skip the
replication/varying-manual-axes check for bodies that create fresh
carries inside the mapped region).
"""
from __future__ import annotations

import inspect
from typing import Any

try:  # JAX >= 0.6: top-level jax.shard_map
    from jax import shard_map as _shard_map_impl  # type: ignore[attr-defined]
except ImportError:  # older JAX: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_IMPL_PARAMS = frozenset(inspect.signature(_shard_map_impl).parameters)


def shard_map(f, *, mesh, in_specs, out_specs,
              check_vma: Any = None, **kwargs):
    """Dispatch to the installed JAX's shard_map, translating the
    vma/rep-check kwarg.  ``check_vma=None`` means "library default"."""
    if check_vma is not None:
        if "check_vma" in _IMPL_PARAMS:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in _IMPL_PARAMS:
            kwargs["check_rep"] = check_vma
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kwargs)
