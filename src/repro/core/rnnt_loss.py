"""RNN-Transducer loss (Graves 2012) in pure JAX — dense and fused paths.

Forward algorithm over the (T, U+1) lattice in log space.  The row
recursion  alpha[t,u] = logaddexp(alpha[t-1,u] + blank[t-1,u],
                                  alpha[t,u-1] + emit[t,u-1])
is evaluated with an outer ``lax.scan`` over T rows; the within-row
dependency is a first-order linear recurrence in the log semiring and is
computed with ``lax.associative_scan``:
  elements (c, b) combine as (c1+c2, logaddexp(b1+c2, b2)).
Complexity O(T*U), compile size O(1) in T and U.

Two implementations share that lattice (DESIGN.md §2):

* ``rnnt_loss`` / ``rnnt_loss_from_logits`` — the **dense oracle**: takes
  the fully materialized ``(B, T, U+1, V)`` log-softmaxed joint and
  differentiates the scan with plain autodiff.  Simple, but the joint
  tensor (and its autodiff residuals) dominate training memory — the
  exact footprint problem the source paper attributes to RNN-T
  gradients.
* ``rnnt_loss_fused`` — the production path: a ``jax.custom_vjp`` over
  the joint *factors* ``(ze, zp, w_out)``.  The forward streams the
  joint row-by-row over T (and over vocab chunks), fusing
  ``tanh(ze+zp) @ w_out``, the logsumexp denominator and the blank/label
  gathers inside the row scan, so live memory is ``O(B·U·V_chunk)`` per
  step and only ``O(B·T·U)`` lattice scalars persist.  The backward runs
  the beta lattice and emits ``d loss/d logits`` in closed form —
  occupancy ``exp(alpha + beta - log p)`` decomposed into blank/emit arc
  posteriors, minus the softmax correction — contracted on the fly into
  ``(dze, dzp, dw_out)`` without ever materializing the joint or its
  gradient.  XLA stores no per-scan-step autodiff residuals.

The lattice row update itself is pluggable: the XLA associative-scan
path below (``lattice_scan_ref``) or the Pallas wavefront kernel in
``kernels/rnnt_lattice/`` (TPU; interpret-validated on CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

NEG = -1e30


def _log_semiring_combine(e1, e2):
    c1, b1 = e1
    c2, b2 = e2
    return c1 + c2, jnp.logaddexp(b1 + c2, b2)


def _row_update(base, emit_prev):
    """Solve a[u] = logaddexp(base[u], a[u-1] + emit_prev[u-1]) for all u.

    base, emit_prev: (..., U1).  emit_prev[..., u] is the emission score
    consumed when moving u-1 -> u (i.e. aligned so position u uses
    emit_prev[..., u]); emit_prev[..., 0] must be NEG (no predecessor).
    """
    c = emit_prev
    b = base
    _, a = jax.lax.associative_scan(_log_semiring_combine, (c, b), axis=-1)
    return a


# ---------------------------------------------------------------------------
# Generic lattice scan (shared by the alpha forward and — on flipped
# inputs — the beta backward; the Pallas ``rnnt_lattice`` kernel computes
# the same recurrence, see kernels/rnnt_lattice/ref.py)
# ---------------------------------------------------------------------------

def lattice_scan_ref(mult, add, emit):
    """rows[t] = row_update(logaddexp(rows[t-1] + mult[t], add[t]), emit[t]).

    mult, add, emit: (T, B, U1).  ``rows[-1]`` is taken as NEG (log 0),
    so ``add[0]`` seeds the first row.  ``emit[t, :, 0]`` must be NEG.
    Returns the stacked rows (T, B, U1).
    """

    def step(carry, xs):
        m, a, e = xs
        row = _row_update(jnp.logaddexp(carry + m, a), e)
        return row, row

    init = jnp.full(mult.shape[1:], NEG, mult.dtype)
    _, rows = jax.lax.scan(step, init, (mult, add, emit))
    return rows


def _lattice(mult, add, emit, impl: str):
    """Backend dispatch for the lattice scan: ``ref`` (XLA associative
    scan), ``pallas``/``interpret`` (the ``kernels/rnnt_lattice`` kernel,
    compiled / interpret-mode), or ``auto`` (Pallas on TPU, ref
    elsewhere)."""
    if impl == "ref":
        return lattice_scan_ref(mult, add, emit)
    if impl not in ("auto", "pallas", "interpret"):
        raise ValueError(f"lattice_impl must be 'auto', 'ref', 'pallas' "
                         f"or 'interpret', got {impl!r}")
    from repro.kernels.rnnt_lattice.ops import rnnt_lattice_op
    if impl == "auto":
        return rnnt_lattice_op(mult, add, emit)
    return rnnt_lattice_op(mult, add, emit, use_pallas=True,
                           interpret=(impl == "interpret"))


# ---------------------------------------------------------------------------
# Dense oracle
# ---------------------------------------------------------------------------

def rnnt_loss(
    log_probs: jax.Array,     # (B, T, U1, V) log-softmaxed joint outputs
    labels: jax.Array,        # (B, U) int32
    t_lens: jax.Array,        # (B,) frames per example
    u_lens: jax.Array,        # (B,) labels per example
    blank: int = 0,
) -> jax.Array:
    """Per-example negative log-likelihood, shape (B,)."""
    B, T, U1, V = log_probs.shape
    U = U1 - 1
    lp = log_probs.astype(jnp.float32)

    lp_blank = lp[..., blank]                                   # (B,T,U1)
    lab = jnp.pad(labels, ((0, 0), (0, 1)))                     # (B,U1)
    lp_emit = jnp.take_along_axis(
        lp, lab[:, None, :, None].astype(jnp.int32), axis=-1)[..., 0]
    # invalidate emissions at/after u_lens (cannot emit past the last label)
    u_ids = jnp.arange(U1)
    emit_valid = u_ids[None, :] < u_lens[:, None]               # (B,U1)
    lp_emit = jnp.where(emit_valid[:, None, :], lp_emit, NEG)

    # alpha[0] row: alpha[0,0]=0; alpha[0,u] = sum_{j<u} emit[0,j]
    init_base = jnp.full((B, U1), NEG).at[:, 0].set(0.0)
    emit_shift0 = jnp.pad(lp_emit[:, 0, :-1], ((0, 0), (1, 0)),
                          constant_values=NEG)
    alpha0 = _row_update(init_base, emit_shift0)

    def row_step(alpha_prev, inputs):
        lpb_prev, lpe_t = inputs                                # (B,U1) each
        base = alpha_prev + lpb_prev                            # blank move
        emit_shift = jnp.pad(lpe_t[:, :-1], ((0, 0), (1, 0)),
                             constant_values=NEG)
        alpha_t = _row_update(base, emit_shift)
        return alpha_t, alpha_t

    xs = (jnp.moveaxis(lp_blank, 1, 0)[:-1],                    # rows 0..T-2
          jnp.moveaxis(lp_emit, 1, 0)[1:])                      # rows 1..T-1
    _, alphas_rest = jax.lax.scan(row_step, alpha0, xs)
    alphas = jnp.concatenate([alpha0[None], alphas_rest], axis=0)  # (T,B,U1)

    # NLL = -(alpha[T-1, U] + blank[T-1, U]) gathered at true lengths
    t_idx = jnp.clip(t_lens - 1, 0, T - 1)
    a_final = alphas[t_idx, jnp.arange(B)]                      # (B,U1)
    a_at_u = jnp.take_along_axis(a_final, u_lens[:, None], axis=1)[:, 0]
    b_final = jnp.take_along_axis(
        lp_blank[jnp.arange(B), t_idx], u_lens[:, None], axis=1)[:, 0]
    return -(a_at_u + b_final)


def rnnt_loss_from_logits(logits, labels, t_lens, u_lens, blank: int = 0):
    return rnnt_loss(jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1),
                     labels, t_lens, u_lens, blank)


# ---------------------------------------------------------------------------
# Fused loss: custom_vjp over the joint factors, vocab-streamed
# ---------------------------------------------------------------------------

def _vocab_chunks(w_out, vocab_chunk: int):
    """Pad/reshape the head to (n_chunks, J, C) plus a column-validity
    mask (n_chunks, C) — the streaming layout of the row scans, shared
    with ``core/lastlayer.py:streamed_er2`` via ``core/chunking.py`` so
    the padding/mask convention cannot drift."""
    from repro.core.chunking import resolve_vocab_chunk, vocab_chunks
    V = w_out.shape[1]
    return vocab_chunks(w_out, resolve_vocab_chunk(V, vocab_chunk), axis=1)


def _row_scores(z, wp, valid, w_blank, w_lab, emit_valid, logz_only=False):
    """One joint row: z (B,U1,J) -> (lpb, lpe, logz), each (B,U1).

    The logsumexp denominator streams over vocab chunks with an online
    (flash-style) max/sum; the blank/label scores are direct gathered
    contractions against single head columns, so the full (B,U1,V)
    logits row only ever exists one V_chunk at a time.
    """
    B, U1, _ = z.shape

    def chunk_step(carry, xs):
        m, s = carry
        wc, vc = xs
        lg = jnp.where(vc[None, None, :], jnp.einsum("buj,jc->buc", z, wc),
                       NEG)
        m_new = jnp.maximum(m, lg.max(axis=-1))
        s = s * jnp.exp(m - m_new) + jnp.exp(lg - m_new[..., None]).sum(-1)
        return (m_new, s), None

    m0 = jnp.full((B, U1), NEG, jnp.float32)
    s0 = jnp.zeros((B, U1), jnp.float32)
    (m, s), _ = jax.lax.scan(chunk_step, (m0, s0), (wp, valid))
    logz = m + jnp.log(jnp.maximum(s, 1e-37))
    lpb = jnp.einsum("buj,j->bu", z, w_blank) - logz
    lpe = jnp.where(emit_valid,
                    jnp.einsum("buj,buj->bu", z, w_lab) - logz, NEG)
    return lpb, lpe, logz


def _alpha_inputs(lpb, lpe):
    """Assemble (mult, add, emit) rows for the alpha lattice scan."""
    T, B, U1 = lpb.shape
    neg_row = jnp.full((1, B, U1), NEG, lpb.dtype)
    mult = jnp.concatenate([neg_row, lpb[:-1]], axis=0)
    init_base = jnp.full((B, U1), NEG).at[:, 0].set(0.0)
    add = jnp.concatenate(
        [init_base[None], jnp.full((T - 1, B, U1), NEG)], axis=0)
    emit = jnp.pad(lpe[:, :, :-1], ((0, 0), (0, 0), (1, 0)),
                   constant_values=NEG)
    return mult, add, emit


def _fused_forward(blank, vocab_chunk, impl, ze, zp, w_out,
                   labels, t_lens, u_lens):
    """Stream the joint over T rows -> (nll, lpb, lpe, logz, alphas)."""
    B, T, J = ze.shape
    U1 = zp.shape[1]
    wp, valid = _vocab_chunks(w_out, vocab_chunk)
    w_blank = w_out[:, blank]
    lab = jnp.pad(labels, ((0, 0), (0, 1))).astype(jnp.int32)   # (B,U1)
    w_lab = w_out.T[lab]                                        # (B,U1,J)
    emit_valid = jnp.arange(U1)[None, :] < u_lens[:, None]

    def row(_, ze_t):
        z = jnp.tanh(ze_t[:, None, :] + zp)                     # (B,U1,J)
        return None, _row_scores(z, wp, valid, w_blank, w_lab, emit_valid)

    _, (lpb, lpe, logz) = jax.lax.scan(row, None, jnp.moveaxis(ze, 1, 0))

    alphas = _lattice(*_alpha_inputs(lpb, lpe), impl)           # (T,B,U1)
    t_idx = jnp.clip(t_lens - 1, 0, T - 1)
    bidx = jnp.arange(B)
    a_final = alphas[t_idx, bidx]                               # (B,U1)
    a_at_u = jnp.take_along_axis(a_final, u_lens[:, None], axis=1)[:, 0]
    b_final = jnp.take_along_axis(lpb[t_idx, bidx], u_lens[:, None],
                                  axis=1)[:, 0]
    nll = -(a_at_u + b_final)
    return nll, (lpb, lpe, logz, alphas)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _rnnt_fused(blank, vocab_chunk, impl, ze, zp, w_out,
                labels, t_lens, u_lens):
    nll, _ = _fused_forward(blank, vocab_chunk, impl, ze, zp, w_out,
                            labels, t_lens, u_lens)
    return nll


def _rnnt_fused_fwd(blank, vocab_chunk, impl, ze, zp, w_out,
                    labels, t_lens, u_lens):
    nll, (lpb, lpe, logz, alphas) = _fused_forward(
        blank, vocab_chunk, impl, ze, zp, w_out, labels, t_lens, u_lens)
    return nll, (ze, zp, w_out, labels, t_lens, u_lens,
                 lpb, lpe, logz, alphas, nll)


def _rnnt_fused_bwd(blank, vocab_chunk, impl, res, g):
    """Beta lattice + closed-form occupancy gradient, streamed over T rows
    and vocab chunks into (dze, dzp, dw_out) — the (B,T,U1,V) logits
    gradient is never materialized."""
    (ze, zp, w_out, labels, t_lens, u_lens,
     lpb, lpe, logz, alphas, nll) = res
    B, T, J = ze.shape
    U1 = zp.shape[1]
    V = w_out.shape[1]

    # --- beta lattice: same recurrence on (t, u)-flipped rows, with the
    # terminal blank injected through the additive term ---------------------
    t_ids = jnp.arange(T)[:, None, None]
    u_ids = jnp.arange(U1)[None, None, :]
    terminal = ((t_ids == (t_lens - 1)[None, :, None])
                & (u_ids == u_lens[None, :, None]))             # (T,B,U1)
    term = jnp.where(terminal, lpb, NEG)
    flip = lambda x: x[::-1, :, ::-1]
    betas = flip(_lattice(flip(lpb), flip(term), flip(lpe), impl))

    # --- arc posteriors ----------------------------------------------------
    logp = -nll                                                 # (B,)
    neg_row = jnp.full((1, B, U1), NEG)
    beta_next_t = jnp.concatenate([betas[1:], neg_row], axis=0)
    beta_dest = jnp.logaddexp(beta_next_t, jnp.where(terminal, 0.0, NEG))
    occ_b = jnp.exp(alphas + lpb + beta_dest - logp[None, :, None])
    beta_next_u = jnp.pad(betas[:, :, 1:], ((0, 0), (0, 0), (0, 1)),
                          constant_values=NEG)
    occ_e = jnp.exp(alphas + lpe + beta_next_u - logp[None, :, None])
    gamma = occ_b + occ_e                                       # (T,B,U1)

    # --- stream d logits = p*gamma - occ_b*1_blank - occ_e*1_label into the
    # factor gradients, row by row -----------------------------------------
    wp, valid = _vocab_chunks(w_out, vocab_chunk)
    nc, _, chunk = wp.shape
    w_blank = w_out[:, blank]
    lab = jnp.pad(labels, ((0, 0), (0, 1))).astype(jnp.int32)
    w_lab = w_out.T[lab]                                        # (B,U1,J)
    gB = g.astype(jnp.float32)                                  # (B,)

    def row(carry, xs):
        dzp_acc, dwo, dwlab = carry
        ze_t, gamma_t, occb_t, occe_t, logz_t = xs
        z = jnp.tanh(ze_t[:, None, :] + zp)                     # (B,U1,J)
        coef = gamma_t * gB[:, None]                            # (B,U1)

        def chunk_step(dz, xs2):
            wc, vc = xs2
            lg = jnp.einsum("buj,jc->buc", z, wc)
            p = jnp.where(vc[None, None, :],
                          jnp.exp(lg - logz_t[..., None]), 0.0)
            pc = p * coef[..., None]                            # (B,U1,C)
            dwo_c = jnp.einsum("buj,buc->jc", z, pc)
            dz = dz + jnp.einsum("buc,jc->buj", pc, wc)
            return dz, dwo_c

        dz, dwo_chunks = jax.lax.scan(
            chunk_step, jnp.zeros((B, U1, J), jnp.float32), (wp, valid))
        dwo = dwo + jnp.moveaxis(dwo_chunks, 0, 1).reshape(
            J, nc * chunk)[:, :V]
        cb = occb_t * gB[:, None]
        ce = occe_t * gB[:, None]
        dz = dz - cb[..., None] * w_blank - ce[..., None] * w_lab
        dwo = dwo.at[:, blank].add(-jnp.einsum("bu,buj->j", cb, z))
        dwlab = dwlab + ce[..., None] * z
        dpre = dz * (1.0 - z * z)                               # tanh'
        dzp_acc = dzp_acc + dpre
        return (dzp_acc, dwo, dwlab), dpre.sum(axis=1)

    carry0 = (jnp.zeros_like(zp, jnp.float32),
              jnp.zeros((J, V), jnp.float32),
              jnp.zeros((B, U1, J), jnp.float32))
    (dzp, dwo, dwlab), dze_rows = jax.lax.scan(
        row, carry0,
        (jnp.moveaxis(ze, 1, 0), gamma, occ_b, occ_e, logz))
    # scatter the accumulated -occ_e * z contributions at label columns
    scatter = jnp.zeros((V, J), jnp.float32).at[lab.reshape(-1)].add(
        dwlab.reshape(-1, J))
    dwo = dwo - scatter.T
    dze = jnp.moveaxis(dze_rows, 0, 1)                          # (B,T,J)

    f0 = lambda x: np.zeros(x.shape, jax.dtypes.float0)
    return (dze.astype(ze.dtype), dzp.astype(zp.dtype),
            dwo.astype(w_out.dtype), f0(labels), f0(t_lens), f0(u_lens))


_rnnt_fused.defvjp(_rnnt_fused_fwd, _rnnt_fused_bwd)


def rnnt_loss_fused(
    ze: jax.Array,            # (B, T, J) encoder-side joint projection
    zp: jax.Array,            # (B, U+1, J) prediction-side joint projection
    w_out: jax.Array,         # (J, V) joint output head
    labels: jax.Array,        # (B, U) int32
    t_lens: jax.Array,        # (B,)
    u_lens: jax.Array,        # (B,)
    blank: int = 0,
    vocab_chunk: int = 0,
    lattice_impl: str = "auto",
) -> jax.Array:
    """Per-example RNN-T NLL from the joint *factors* — the fused,
    memory-lean equivalent of
    ``rnnt_loss_from_logits(tanh(ze[:,:,None]+zp[:,None]) @ w_out, ...)``.

    The ``(B, T, U+1, V)`` joint is never materialized, forward or
    backward: ``vocab_chunk`` bounds the live logits row at
    ``O(B·U·vocab_chunk)`` (``<= 0`` means one chunk of the full vocab),
    and gradients are analytic (``jax.custom_vjp``) so the row scan
    leaves no autodiff residuals.  ``lattice_impl`` selects the lattice
    backend (``auto`` | ``ref`` | ``pallas`` | ``interpret``).
    """
    return _rnnt_fused(int(blank), int(vocab_chunk), str(lattice_impl),
                       ze.astype(jnp.float32), zp.astype(jnp.float32),
                       w_out.astype(jnp.float32), labels,
                       t_lens.astype(jnp.int32), u_lens.astype(jnp.int32))
