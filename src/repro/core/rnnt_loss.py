"""RNN-Transducer loss (Graves 2012) in pure JAX.

Forward algorithm over the (T, U+1) lattice in log space.  The row
recursion  alpha[t,u] = logaddexp(alpha[t-1,u] + blank[t-1,u],
                                  alpha[t,u-1] + emit[t,u-1])
is evaluated with an outer ``lax.scan`` over T rows; the within-row
dependency is a first-order linear recurrence in the log semiring and is
computed with ``lax.associative_scan``:
  elements (c, b) combine as (c1+c2, logaddexp(b1+c2, b2)).
Complexity O(T*U), compile size O(1) in T and U.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def _log_semiring_combine(e1, e2):
    c1, b1 = e1
    c2, b2 = e2
    return c1 + c2, jnp.logaddexp(b1 + c2, b2)


def _row_update(base, emit_prev):
    """Solve a[u] = logaddexp(base[u], a[u-1] + emit_prev[u-1]) for all u.

    base, emit_prev: (..., U1).  emit_prev[..., u] is the emission score
    consumed when moving u-1 -> u (i.e. aligned so position u uses
    emit_prev[..., u]); emit_prev[..., 0] must be NEG (no predecessor).
    """
    c = emit_prev
    b = base
    _, a = jax.lax.associative_scan(_log_semiring_combine, (c, b), axis=-1)
    return a


def rnnt_loss(
    log_probs: jax.Array,     # (B, T, U1, V) log-softmaxed joint outputs
    labels: jax.Array,        # (B, U) int32
    t_lens: jax.Array,        # (B,) frames per example
    u_lens: jax.Array,        # (B,) labels per example
    blank: int = 0,
) -> jax.Array:
    """Per-example negative log-likelihood, shape (B,)."""
    B, T, U1, V = log_probs.shape
    U = U1 - 1
    lp = log_probs.astype(jnp.float32)

    lp_blank = lp[..., blank]                                   # (B,T,U1)
    lab = jnp.pad(labels, ((0, 0), (0, 1)))                     # (B,U1)
    lp_emit = jnp.take_along_axis(
        lp, lab[:, None, :, None].astype(jnp.int32), axis=-1)[..., 0]
    # invalidate emissions at/after u_lens (cannot emit past the last label)
    u_ids = jnp.arange(U1)
    emit_valid = u_ids[None, :] < u_lens[:, None]               # (B,U1)
    lp_emit = jnp.where(emit_valid[:, None, :], lp_emit, NEG)

    # alpha[0] row: alpha[0,0]=0; alpha[0,u] = sum_{j<u} emit[0,j]
    init_base = jnp.full((B, U1), NEG).at[:, 0].set(0.0)
    emit_shift0 = jnp.pad(lp_emit[:, 0, :-1], ((0, 0), (1, 0)),
                          constant_values=NEG)
    alpha0 = _row_update(init_base, emit_shift0)

    def row_step(alpha_prev, inputs):
        lpb_prev, lpe_t = inputs                                # (B,U1) each
        base = alpha_prev + lpb_prev                            # blank move
        emit_shift = jnp.pad(lpe_t[:, :-1], ((0, 0), (1, 0)),
                             constant_values=NEG)
        alpha_t = _row_update(base, emit_shift)
        return alpha_t, alpha_t

    xs = (jnp.moveaxis(lp_blank, 1, 0)[:-1],                    # rows 0..T-2
          jnp.moveaxis(lp_emit, 1, 0)[1:])                      # rows 1..T-1
    _, alphas_rest = jax.lax.scan(row_step, alpha0, xs)
    alphas = jnp.concatenate([alpha0[None], alphas_rest], axis=0)  # (T,B,U1)

    # NLL = -(alpha[T-1, U] + blank[T-1, U]) gathered at true lengths
    t_idx = jnp.clip(t_lens - 1, 0, T - 1)
    a_final = alphas[t_idx, jnp.arange(B)]                      # (B,U1)
    a_at_u = jnp.take_along_axis(a_final, u_lens[:, None], axis=1)[:, 0]
    b_final = jnp.take_along_axis(
        lp_blank[jnp.arange(B), t_idx], u_lens[:, None], axis=1)[:, 0]
    return -(a_at_u + b_final)


def rnnt_loss_from_logits(logits, labels, t_lens, u_lens, blank: int = 0):
    return rnnt_loss(jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1),
                     labels, t_lens, u_lens, blank)
