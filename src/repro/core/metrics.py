"""Paper evaluation metrics: Overlap Index, Noise Overlap Index (§5.2
Table 4), relative test error, speedup/energy accounting."""
from __future__ import annotations

import numpy as np


def overlap_index(prev_indices, cur_indices) -> float:
    """Fraction of common units between consecutive selection rounds,
    normalized by subset size (paper's OI)."""
    a = set(int(i) for i in np.asarray(prev_indices) if i >= 0)
    b = set(int(i) for i in np.asarray(cur_indices) if i >= 0)
    denom = max(len(b), 1)
    return len(a & b) / denom


def noise_overlap_index(sel_indices, noise_flags) -> float:
    """(# selected noisy units) / (# noisy units) (paper's NOI)."""
    flags = np.asarray(noise_flags)
    sel = [int(i) for i in np.asarray(sel_indices) if i >= 0]
    n_noisy = max(int(flags.sum()), 1)
    return float(flags[sel].sum()) / n_noisy


def relative_test_error(err: float, err_full: float) -> float:
    """Paper's Rel. Test Error (%): (err - err_full) / err_full * 100."""
    return (err - err_full) / max(err_full, 1e-12) * 100.0


def speedup(full_cost: float, subset_cost: float) -> float:
    return full_cost / max(subset_cost, 1e-12)


def training_cost_units(n_epochs: int, warm_epochs: int, subset_frac: float,
                        select_rounds: int = 0, select_cost_frac: float = 0.0
                        ) -> float:
    """Cost in full-epoch units: warm-start epochs at 1.0 + remaining epochs
    at subset_frac + selection overhead (fraction of an epoch per round:
    one forward + last-layer grad pass over candidates ~ 1/3 train epoch)."""
    return (warm_epochs
            + (n_epochs - warm_epochs) * subset_frac
            + select_rounds * select_cost_frac)
