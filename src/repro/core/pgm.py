"""PGM — Partitioned Gradient Matching (paper Algorithm 1).

Every ``R`` epochs:
  stage A  compute per-unit last-layer gradient representations for all
           candidate units (sketched by default; exact = paper-faithful);
  stage B  split units into D partitions; per partition, run gradient
           matching (Algorithm 2 / gm.py) against either the partition's
           own mean gradient (Val=False) or the validation gradient
           (Val=True, robust mode), each with budget b_k/D;
  stage C  concatenate the partial subsets and their weights.

Distribution (docs/DESIGN.md §5): stage A is a plain GSPMD jit (units
sharded over the ``data`` mesh axis, model params over ``model``); stage
B is embarrassingly parallel across partitions and is dispatched with
``shard_map`` over ``data`` in ``pgm_select_sharded`` — the jax-native
equivalent of the paper's "one GM per GPU".

Residency (docs/DESIGN.md §1): ``ResidentSelector`` runs stage A as one
jitted batch-scanned pass over the epoch engine's device-resident unit
buffers — the very same buffers the engine trains from, including their
``data``-axis sharding when the engine was built on a mesh — with the
sketch projections closed over the jit so both the executable and the
projection constants are reused across selection rounds: no per-round
host round-trip, and no second copy of the corpus.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import gm
from repro.core.lastlayer import units_gradients, units_gradients_batched
from repro.core.sketch import Projections
from repro.kernels.backend import resolve_kernel_impl
from repro.kernels.omp_gram.ops import omp_gram_batched_op


class Selection(NamedTuple):
    indices: jax.Array     # (b_k,) global unit ids, -1 padded
    weights: jax.Array     # (b_k,) fp32
    n_selected: jax.Array  # scalar
    errors: jax.Array      # (D,) per-partition final E_lambda


# ---------------------------------------------------------------------------
# Stage B: partitioned OMP over precomputed gradient representations
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_partitions", "budget_per_part",
                                   "nonneg", "val_matching", "kernel_impl",
                                   "solver"))
def partitioned_gm(
    g_units: jax.Array,            # (n, D) unit-gradient vectors
    n_partitions: int,
    budget_per_part: int,
    lam: float = 0.5,
    eps: float = 1e-10,
    nonneg: bool = True,
    val_matching: bool = False,
    g_val: Optional[jax.Array] = None,   # (D,) required when val_matching
    kernel_impl: Optional[str] = None,   # PGMConfig.kernel_impl string
    solver: str = "chol",
) -> Selection:
    n, D_sk = g_units.shape
    P = n_partitions
    assert n % P == 0, f"n units {n} must divide into {P} partitions"
    per = n // P
    gp = g_units.reshape(P, per, D_sk).astype(jnp.float32)

    if val_matching:
        target = jnp.broadcast_to(g_val.astype(jnp.float32), (P, D_sk))
    else:
        # match the partition's own summed gradient: note sum (not mean) so
        # that sum_i w_i g_i can reach it with O(1) weights per unit
        target = gp.sum(axis=1)

    # all P Grams from one batched kernel call (Pallas on TPU / per
    # kernel_impl); c and ||t||^2 are cheap rank-1 contractions
    K = omp_gram_batched_op(gp, impl=kernel_impl)
    c = jnp.einsum("pnd,pd->pn", gp, target)
    tsq = jnp.einsum("pd,pd->p", target, target)

    def one_partition(K_p, c_p, tsq_p):
        return gm.gram_omp(K_p, c_p, tsq_p, budget_per_part, lam, eps,
                           nonneg, solver)

    res = jax.vmap(one_partition)(K, c, tsq)
    offsets = (jnp.arange(P, dtype=jnp.int32) * per)[:, None]
    glob = jnp.where(res.indices >= 0, res.indices + offsets, -1)
    return Selection(
        indices=glob.reshape(-1),
        weights=res.weights.reshape(-1),
        n_selected=res.n_selected.sum(),
        errors=res.error,
    )


# ---------------------------------------------------------------------------
# Full Algorithm 1 selection round (stages A + B)
# ---------------------------------------------------------------------------

def _stage_b(g_units, pgm_cfg, g_val=None, mesh=None,
             data_axis: str = "data") -> Selection:
    """Dispatch stage B (partitioned OMP) over precomputed stage-A
    gradient representations — shard_map over ``data_axis`` when a mesh
    divides the partitions, single-device jit otherwise."""
    n_units = g_units.shape[0]
    budget_total = max(int(pgm_cfg.subset_fraction * n_units), 1)
    D = min(pgm_cfg.n_partitions, n_units)
    budget_per = max(budget_total // D, 1)
    if mesh is not None and _mesh_divides(mesh, data_axis, D, n_units):
        # same code path on 1 and N devices: partitions are distributed
        # over the data axis, each shard runs its OMPs locally
        cfg = pgm_cfg if pgm_cfg.n_partitions == D else \
            dataclasses.replace(pgm_cfg, n_partitions=D)
        return pgm_select_sharded(mesh, data_axis, g_units, cfg, g_val=g_val)
    return partitioned_gm(
        g_units, D, budget_per, pgm_cfg.lam, pgm_cfg.eps,
        pgm_cfg.nonneg_weights, pgm_cfg.val_matching, g_val,
        kernel_impl=_impl_of(pgm_cfg))


def _val_target(gv, n_units: int, pgm_cfg) -> jax.Array:
    """Validation target: mean gradient scaled to the partition mass so
    budgets/weights stay comparable with train matching."""
    D = min(pgm_cfg.n_partitions, n_units)
    return gv.mean(axis=0) * (n_units / D)


def pgm_select(
    bundle,
    params,
    units,                        # batch pytree with leading (n_units,) axis
    pgm_cfg,
    proj: Optional[Projections] = None,
    val_units=None,               # validation units when val_matching
    mesh=None,                    # stage B via shard_map when provided
    data_axis: str = "data",
) -> Selection:
    n_units = jax.tree.leaves(units)[0].shape[0]
    exact = not pgm_cfg.use_sketch
    rt = _router_term_for(bundle, pgm_cfg)
    impl = _impl_of(pgm_cfg)

    g = units_gradients(bundle, params, units, proj, exact=exact,
                        router_term=rt, kernel_impl=impl)
    g_val = None
    if pgm_cfg.val_matching:
        gv = units_gradients(bundle, params, val_units, proj, exact=exact,
                             router_term=rt, kernel_impl=impl)
        g_val = _val_target(gv, n_units, pgm_cfg)
    return _stage_b(g, pgm_cfg, g_val=g_val, mesh=mesh, data_axis=data_axis)


def _router_term_for(bundle, pgm_cfg) -> bool:
    """The MoE router-aware term applies only to sparse-expert bundles
    (DESIGN.md §8); other families silently ignore the flag."""
    return bool(getattr(pgm_cfg, "moe_router_term", False)
                and bundle.cfg.family == "moe")


def _impl_of(pgm_cfg) -> str:
    """Kernel backend string from config, tolerant of older configs that
    predate the ``kernel_impl`` field."""
    return getattr(pgm_cfg, "kernel_impl", "auto") or "auto"


def _soft_random_selection(key, n_units: int, pgm_cfg) -> Selection:
    """Degraded selection when every scorer backend failed: a uniform
    random subset at the configured budget with unit weights — the same
    Selection convention as ``baselines.random_subset`` (inlined here
    because baselines imports this module).  Training proceeds on a
    defensible subset instead of dying mid-run (DESIGN.md §10);
    ``ResidentSelector.degraded_rounds`` counts how often."""
    budget = max(int(pgm_cfg.subset_fraction * n_units), 1)
    idx = jax.random.permutation(key, n_units)[:budget].astype(jnp.int32)
    return Selection(idx, jnp.ones((budget,), jnp.float32),
                     jnp.asarray(budget), jnp.zeros((1,)))


class ResidentSelector:
    """Selection rounds over the epoch engine's device-resident units.

    ``pgm_select`` recomputes stage A from scratch with a sequential
    per-unit map dispatched from host; on the scanned engine the very
    same unit buffers already sit on device, so a resident round is one
    jitted batch-scanned stage-A pass (``units_gradients_batched`` —
    sharded over the ``data`` mesh axis when the units were placed with
    one) followed by the usual stage B.  The sketch ``Projections`` are
    closed over the jit at construction: across rounds both the compiled
    executable and the projection constants are reused instead of being
    re-materialized per call.  With a mesh, stage B additionally routes
    through ``pgm_select_sharded`` exactly like ``pgm_select``.

    Failure ladder (DESIGN.md §10): a round that raises on the resolved
    Pallas backend falls back *once* (warn-once) to the bit-identical
    XLA path — both stage A (re-jitted) and stage B read the updated
    ``kernel_impl`` — and if the scorer still fails the round degrades
    to a soft-random subset (``on_failure="soft_random"``, the default)
    rather than killing a multi-epoch run; ``on_failure="raise"``
    restores fail-fast semantics for tests and debugging.

    Usage (see ``train/loop.py``)::

        selector = ResidentSelector(bundle, pgm_cfg, proj, mesh=mesh)
        sel = selector(params, engine.units, val_units=engine.val_units)
    """

    def __init__(self, bundle, pgm_cfg, proj: Optional[Projections] = None,
                 *, chunk_units: Optional[int] = None, mesh=None,
                 data_axis: str = "data", vocab_chunk: int = 8192,
                 on_failure: str = "soft_random", log_fn=None):
        self.bundle = bundle
        self.cfg = pgm_cfg
        self.mesh = mesh
        self.data_axis = data_axis
        self.on_failure = on_failure
        self._log = log_fn or (lambda s: None)
        self._proj = proj
        self._chunk_units = chunk_units
        self._vocab_chunk = vocab_chunk
        self._exact = not pgm_cfg.use_sketch
        self._rt = _router_term_for(bundle, pgm_cfg)
        impl = _impl_of(pgm_cfg)
        # resolve once at build time and surface the decision: "auto" is
        # data-dependent (TPU vs host), and a silent wrong backend is
        # exactly the kind of perf bug a log line catches
        self.kernel_impl = resolve_kernel_impl(impl)
        self._fell_back = False
        self.degraded_rounds = 0
        self._round = 0
        if log_fn is not None:
            log_fn(f"selection kernels: requested={impl} "
                   f"resolved={self.kernel_impl}")
        self._build_stage_a(impl)

    def _build_stage_a(self, impl):
        bundle, proj = self.bundle, self._proj
        chunk_units, vocab_chunk = self._chunk_units, self._vocab_chunk
        exact, rt = self._exact, self._rt

        def stage_a(params, units):
            return units_gradients_batched(
                bundle, params, units, proj, chunk_units=chunk_units,
                vocab_chunk=vocab_chunk, exact=exact, router_term=rt,
                kernel_impl=impl)

        # one jit for train and val units alike: the cache keys on unit
        # shapes, so each distinct corpus compiles once and every later
        # round is a cache hit (a kernel fallback rebuilds the jit, so
        # the replacement backend traces fresh)
        self._stage_a = jax.jit(stage_a)

    def stage_a(self, params, units) -> jax.Array:
        """(n_units, D) stage-A gradient representations, jit-cached."""
        return self._stage_a(params, units)

    def _select_round(self, params, units, val_units) -> Selection:
        g = self._stage_a(params, units)
        g_val = None
        if self.cfg.val_matching:
            gv = self._stage_a(params, val_units)
            g_val = _val_target(gv, g.shape[0], self.cfg)
        return _stage_b(g, self.cfg, g_val=g_val, mesh=self.mesh,
                        data_axis=self.data_axis)

    def __call__(self, params, units, val_units=None) -> Selection:
        self._round += 1
        try:
            return self._select_round(params, units, val_units)
        except Exception as err:
            if self.kernel_impl == "pallas" and not self._fell_back:
                self._fell_back = True
                self._log(f"warning: Pallas selection round failed "
                          f"({err}); falling back to the bit-identical "
                          f"XLA path for all remaining rounds")
                self.kernel_impl = "xla"
                self.cfg = dataclasses.replace(self.cfg,
                                               kernel_impl="xla")
                self._build_stage_a("xla")
                try:
                    return self._select_round(params, units, val_units)
                except Exception as err2:
                    err = err2
            if self.on_failure != "soft_random":
                raise err
            self.degraded_rounds += 1
            n_units = jax.tree.leaves(units)[0].shape[0]
            self._log(f"warning: selection scorer failed ({err}); "
                      f"degrading this round to a soft-random subset")
            return _soft_random_selection(jax.random.PRNGKey(self._round),
                                          n_units, self.cfg)


def _mesh_divides(mesh, axis: str, n_partitions: int, n_units: int) -> bool:
    """shard_map stage B needs whole partitions (and whole units) per
    shard; fall back to the single-device jit when they don't divide."""
    if axis not in mesh.axis_names:
        return False
    size = mesh.shape[axis]
    return n_partitions % size == 0 and n_units % size == 0


# ---------------------------------------------------------------------------
# shard_map distribution of stage B (partitions over the data axis)
# ---------------------------------------------------------------------------

def pgm_select_sharded(mesh, axis: str, g_units, pgm_cfg, g_val=None):
    """Stage B under shard_map: each ``axis`` shard owns n_partitions/|axis|
    whole partitions and runs its OMPs locally with zero cross-device
    traffic; outputs are concatenated by the final all_gather.

    g_units: (n, D) global array (sharded on axis 0 by the caller).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compat import shard_map

    n = g_units.shape[0]
    size = mesh.shape[axis]
    D = pgm_cfg.n_partitions
    assert D % size == 0, (D, size)
    budget_total = max(int(pgm_cfg.subset_fraction * n), 1)
    budget_per = max(budget_total // D, 1)
    local_parts = D // size

    def local_fn(g_local, g_val_local):
        # g_local: (n/size, D_sk) -> local partitions
        sel = partitioned_gm(
            g_local, local_parts, budget_per, pgm_cfg.lam, pgm_cfg.eps,
            pgm_cfg.nonneg_weights, pgm_cfg.val_matching,
            g_val_local[0] if pgm_cfg.val_matching else None,
            kernel_impl=_impl_of(pgm_cfg))
        # globalize indices by shard offset
        idx = jax.lax.axis_index(axis) * (n // size)
        indices = jnp.where(sel.indices >= 0, sel.indices + idx, -1)
        return (jax.lax.all_gather(indices, axis, tiled=True),
                jax.lax.all_gather(sel.weights, axis, tiled=True),
                jax.lax.psum(sel.n_selected, axis),
                jax.lax.all_gather(sel.errors, axis, tiled=True))

    gv = (jnp.zeros((1, g_units.shape[1]), jnp.float32) if g_val is None
          else g_val[None])
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=(P(), P(), P(), P()),
        # the OMP while_loop creates fresh (unvarying) carries inside the
        # mapped body; disable varying-manual-axes checking
        check_vma=False,
    )
    indices, weights, n_sel, errors = fn(g_units, gv)
    return Selection(indices, weights, n_sel, errors)


# ---------------------------------------------------------------------------
# Applying a selection: expand selected units into a weighted sub-dataset
# ---------------------------------------------------------------------------

def gather_selected(units, selection: Selection):
    """Materialize the selected units (drop -1 padding is the caller's
    concern; padded entries carry weight 0)."""
    idx = jnp.where(selection.indices >= 0, selection.indices, 0)
    sub = jax.tree.map(lambda a: a[idx], units)
    if "weights" in sub:
        w = selection.weights * (selection.indices >= 0)
        # unit weight broadcasts over the unit's examples
        sub = dict(sub, weights=sub["weights"] * w[:, None])
    return sub
