"""Subset-selection baselines from the paper (§5 Baselines):
Random-Subset, LargeOnly, LargeSmall, and GRAD-MATCHPB (Killamsetty et al.
2021a) — the unpartitioned gradient-matching method PGM upper-bounds.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import gm
from repro.core.pgm import Selection, partitioned_gm


def random_subset(key, n_units: int, budget: int) -> Selection:
    idx = jax.random.permutation(key, n_units)[:budget].astype(jnp.int32)
    return Selection(indices=idx, weights=jnp.ones((budget,)),
                     n_selected=jnp.asarray(budget, jnp.int32),
                     errors=jnp.zeros((1,)))


def large_only(durations: jax.Array, budget: int) -> Selection:
    """Longest utterances first (paper's LargeOnly)."""
    idx = jnp.argsort(-durations)[:budget].astype(jnp.int32)
    return Selection(idx, jnp.ones((budget,)),
                     jnp.asarray(budget, jnp.int32), jnp.zeros((1,)))


def large_small(durations: jax.Array, budget: int) -> Selection:
    """Half smallest + half largest (paper's LargeSmall)."""
    order = jnp.argsort(durations)
    k_small = budget // 2
    k_large = budget - k_small
    idx = jnp.concatenate([order[:k_small], order[-k_large:]]).astype(jnp.int32)
    return Selection(idx, jnp.ones((budget,)),
                     jnp.asarray(budget, jnp.int32), jnp.zeros((1,)))


def gradmatch_pb(g_units: jax.Array, budget: int, lam: float = 0.5,
                 eps: float = 1e-10, nonneg: bool = True,
                 g_val: Optional[jax.Array] = None) -> Selection:
    """GRAD-MATCHPB: single-partition gradient matching over the whole
    candidate set (the sequential baseline; memory-infeasible at paper
    scale, used for the Table-7 comparison)."""
    return partitioned_gm(
        g_units, 1, budget, lam, eps, nonneg,
        val_matching=g_val is not None, g_val=g_val)
