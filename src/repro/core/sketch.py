"""Tensor-JL sketching of last-layer gradients (beyond-paper optimization,
DESIGN.md §2).

The last-layer gradient of a unit (mini-batch) factorizes as
``G = H^T E`` with H the pre-head activations (rows = tokens/lattice
points) and E = dL/dlogits.  We sketch ``S = R1^T G R2`` with independent
Gaussian projections R1 (d_h, k1), R2 (d_v, k2) whose entries are
N(0, 1/k1) / N(0, 1/k2), giving the unbiased inner-product estimate
``E<S, S'> = <G, G'>`` (tensor-product Johnson-Lindenstrauss).

Crucially S is computed as ``(H R1)^T (E R2)`` — the d_h x d_v gradient is
never materialized; E itself is streamed over vocab chunks (the Pallas
``grad_sketch`` kernel fuses this with an online softmax on TPU).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Projections(NamedTuple):
    r_h: jax.Array      # (d_hidden, k1)
    r_v: jax.Array      # (d_vocab, k2)

    @property
    def sketch_dims(self) -> Tuple[int, int]:
        """(k1, k2) — the unflattened sketch block shape the fused
        ``grad_sketch`` kernel emits per unit (DESIGN.md §9)."""
        return self.r_h.shape[1], self.r_v.shape[1]

    @property
    def sketch_dim(self) -> int:
        k1, k2 = self.sketch_dims
        return k1 * k2


def make_projections(key, d_hidden: int, d_vocab: int,
                     k1: int = 64, k2: int = 64) -> Projections:
    kh, kv = jax.random.split(key)
    r_h = jax.random.normal(kh, (d_hidden, k1)) / jnp.sqrt(float(k1))
    r_v = jax.random.normal(kv, (d_vocab, k2)) / jnp.sqrt(float(k2))
    return Projections(r_h, r_v)


def sketch_from_factors(h: jax.Array, e: jax.Array, proj: Projections
                        ) -> jax.Array:
    """h: (N, d_h) fp32; e: (N, d_v) fp32 -> flattened sketch (k1*k2,)."""
    hr = h @ proj.r_h                     # (N, k1)
    er = e @ proj.r_v                     # (N, k2)
    return (hr.T @ er).reshape(-1)


def exact_from_factors(h: jax.Array, e: jax.Array) -> jax.Array:
    """Paper-faithful path: the full flattened last-layer gradient."""
    return (h.T @ e).reshape(-1)
