"""Shared vocab pad/reshape/validity chunking layout (DESIGN.md §2).

Two vocab-streamed consumers scan a head matrix chunk by chunk so no
``(..., V)`` tensor is ever fully live: the fused RNN-T loss's joint
head (``core/rnnt_loss.py:_vocab_chunks``) and the LM last-layer sketch
(``core/lastlayer.py:streamed_er2``).  Their zero-padding and
column-validity conventions must be *identical* — a drifted mask turns
padding columns into real logits and silently changes loss values — so
the layout lives here once and both import it.

Layout contract:

* the vocab axis is zero-padded up to ``n_chunks * chunk`` and reshaped
  into ``(n_chunks, chunk)`` with ``n_chunks`` moved to the front
  (``chunk_vocab_axis``), the xs-leading shape a ``lax.scan`` consumes;
* ``vocab_chunk_mask`` marks which columns of each chunk are real vocab
  entries (``False`` on the zero-padding of the last chunk) — consumers
  must mask padded columns *before* any softmax/logsumexp, since a
  zero-padded logit is a real score of 0, not a missing column.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def resolve_vocab_chunk(V: int, chunk: int) -> int:
    """Effective chunk width: ``<= 0`` means one chunk of the whole
    vocab; larger-than-vocab requests are capped at ``V`` (no point
    padding past the vocabulary)."""
    return V if chunk <= 0 else min(int(chunk), V)


# One budget for every vocab-streaming consumer that must keep its live
# (rows, chunk) slab in fast memory: half of a TPU v5e core's 16 MB VMEM,
# leaving the other half for the non-streamed operands and double
# buffering.  The same number is a sane host-cache working-set bound, so
# the CPU reference paths share it rather than special-casing.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024
LANE = 128                       # TPU minor-dim tile (fp32 lane count)


def auto_vocab_chunk(n_rows: int, V: int, *, dtype_bytes: int = 4,
                     budget_bytes: int = VMEM_BUDGET_BYTES,
                     lane: int = LANE) -> int:
    """Auto-tuned vocab chunk width from ``(live rows, V, memory budget)``.

    Returns ``V`` whenever the whole ``(n_rows, V)`` slab fits the budget
    — small/smoke vocabs keep the single-chunk layout (and its exact
    numerics) untouched.  Otherwise the largest lane-aligned chunk whose
    slab fits, floored at one lane.  Shared by the fused RNN-T loss's
    ``loss_vocab_chunk`` auto-tune (``train/engine.py``, rows =
    ``B * (U+1) + joint_dim``) and the ``grad_sketch`` kernel's vocab
    tiling (rows = ``tn + d``).
    """
    n_rows = max(int(n_rows), 1)
    if n_rows * V * dtype_bytes <= budget_bytes:
        return V
    chunk = budget_bytes // (n_rows * dtype_bytes)
    chunk = max((chunk // lane) * lane, lane)
    return min(chunk, V)


def n_vocab_chunks(V: int, chunk: int) -> int:
    return -(-V // chunk)


def vocab_chunk_mask(V: int, chunk: int) -> jax.Array:
    """Column-validity mask ``(n_chunks, chunk)``: True for real vocab
    columns, False for the zero-padding of the last chunk."""
    nc = n_vocab_chunks(V, chunk)
    return jnp.arange(nc * chunk).reshape(nc, chunk) < V


def chunk_vocab_axis(x: jax.Array, chunk: int, axis: int = -1) -> jax.Array:
    """Zero-pad ``x`` along its vocab ``axis`` to a multiple of ``chunk``
    and split that axis into ``(n_chunks, chunk)``, moving ``n_chunks``
    to the front — the chunks-leading layout every vocab-streaming scan
    consumes as its xs.

    ``(d, V)`` with ``axis=1`` -> ``(nc, d, chunk)``;
    ``(V, k)`` with ``axis=0`` -> ``(nc, chunk, k)``.
    """
    axis = axis % x.ndim
    V = x.shape[axis]
    nc = n_vocab_chunks(V, chunk)
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, nc * chunk - V)
    xp = jnp.pad(x, pad)
    xp = xp.reshape(x.shape[:axis] + (nc, chunk) + x.shape[axis + 1:])
    return jnp.moveaxis(xp, axis, 0)


def vocab_chunks(x: jax.Array, chunk: int, axis: int = -1,
                 ) -> Tuple[jax.Array, jax.Array]:
    """``(chunked x, validity mask)`` in one call — the common case."""
    return (chunk_vocab_axis(x, chunk, axis),
            vocab_chunk_mask(x.shape[axis % x.ndim], chunk))
