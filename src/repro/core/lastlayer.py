"""Per-unit (mini-batch) last-layer gradients for PGM.

Paper §3: full per-instance RNN-T gradients are prohibitively large (4 MB
each / 111 GB per corpus), so GRAD-MATCH-style methods use only the last
layer — for RNN-T the *joint network*, for decoder LMs the ``lm_head``.

This module computes, per selection unit:
  * the exact flattened last-layer gradient (paper-faithful path), or
  * its tensor-JL sketch (beyond-paper; see core/sketch.py), streamed over
    vocab chunks so neither the (N_tok, V) error matrix nor the (d, V)
    gradient is ever materialized.  The Pallas ``grad_sketch`` kernel is
    the TPU-fused version of ``streamed_er2``; this file is its oracle.

The per-token error scaling matches the training loss exactly:
per-example mean over tokens, then mean over examples, i.e.
``E[b,s] = (softmax - onehot) * mask[b,s] / (n_tok_b * B)``.

Family coverage beyond dense LMs / RNN-T (DESIGN.md §8):

* **Sparse-expert (MoE)** — the last-layer head gradient is blind to the
  router: two units that stress different experts can sketch identically.
  With ``PGMConfig.moe_router_term`` the unit representation is the head
  gradient **concatenated with the per-unit gradient of the total
  training loss (task + load-balance aux) w.r.t. every router weight**
  (``moe_router_grads``), sketched per router leaf with the same ``r_h``
  d-model projection.  The router term costs one autodiff backward per
  unit (vs the closed-form head path), so it is opt-in; default off is
  the paper-faithful last-layer definition.
* **Recurrent carries (RWKV6 ``wkv_scan``, RG-LRU)** — no new gradient
  term: recurrent state is a per-utterance *activation*, zero-initialized
  inside every training forward (``final_hidden`` never threads state
  across units), so the per-unit head gradient is exactly as well-defined
  as for attention stacks.  The engine test matrix
  (``tests/test_archs_smoke.py``) proves the state paths through the
  epoch scan (scan-of-scan) stay host/scan parity-exact, resume
  bit-exactly, and are untouched by weight-0 padding steps.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.sketch import Projections, exact_from_factors, sketch_from_factors


# ---------------------------------------------------------------------------
# LM factor extraction
# ---------------------------------------------------------------------------

def lm_unit_factors(bundle, params, batch, shard=None):
    """-> (h (N,d) fp32, targets (N,), scale (N,) fp32).  N = B*(S-1)."""
    from repro.models.common import IDENTITY_SHARDER
    h, targets, mask, _ = bundle.final_hidden(
        params, batch, shard=shard or IDENTITY_SHARDER, remat=False)
    B = h.shape[0]
    denom = jnp.maximum(mask.sum(axis=-1, keepdims=True), 1.0)
    scale = (mask / (denom * B)).astype(jnp.float32)
    d = h.shape[-1]
    return (h.reshape(-1, d).astype(jnp.float32),
            targets.reshape(-1).astype(jnp.int32),
            scale.reshape(-1))


def streamed_er2(h, w_head, targets, scale, r_v, chunk: int = 8192):
    """Computes ``E @ R2`` without materializing E, streaming vocab chunks.

    h: (N,d) fp32; w_head: (d,V); targets (N,); scale (N,);
    r_v: (V,k2).  Returns (N,k2) fp32.
    E[n] = scale[n] * (softmax(h[n] @ W) - onehot(targets[n])).
    """
    N, d = h.shape
    V = w_head.shape[1]
    k2 = r_v.shape[1]
    # chunks-leading pad/reshape/validity layout shared with the fused
    # RNN-T loss (core/chunking.py) so the mask convention cannot drift
    from repro.core.chunking import (chunk_vocab_axis, resolve_vocab_chunk,
                                     vocab_chunk_mask)
    chunk = resolve_vocab_chunk(V, chunk)
    w = chunk_vocab_axis(w_head.astype(jnp.float32), chunk, axis=1)
    rv = chunk_vocab_axis(r_v.astype(jnp.float32), chunk, axis=0)
    valid = vocab_chunk_mask(V, chunk)

    # single pass: flash-style online softmax accumulation of P @ R2 —
    # the unnormalized accumulator is rescaled as the running max moves
    # (§Perf select-iter-2: halves the logits recompute vs two-pass)
    def step(carry, xs):
        m, s, acc = carry
        wc, rc, vc = xs
        lg = jnp.where(vc, h @ wc, -jnp.inf)                  # (N,chunk)
        m_new = jnp.maximum(m, lg.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(lg - m_new[:, None])
        s = s * alpha + p.sum(-1)
        acc = acc * alpha[:, None] + p @ rc
        return (m_new, s, acc), None

    m0 = jnp.full((N,), -jnp.inf, jnp.float32)
    s0 = jnp.zeros((N,), jnp.float32)
    acc0 = jnp.zeros((N, k2), jnp.float32)
    (m, s, acc), _ = jax.lax.scan(step, (m0, s0, acc0), (w, rv, valid))
    er2 = acc / jnp.maximum(s, 1e-30)[:, None]
    er2 = er2 - r_v.astype(jnp.float32)[targets]
    return er2 * scale[:, None]


def lm_unit_sketch(bundle, params, batch, proj: Projections,
                   vocab_chunk: int = 8192, shard=None,
                   kernel_impl: Optional[str] = None) -> jax.Array:
    h, targets, scale = lm_unit_factors(bundle, params, batch, shard)
    w = bundle.head_weight(params)
    # fused gradient+sketch dispatch: Pallas kernel or the streamed_er2
    # XLA path per ``kernel_impl`` (lazy import — ops.py imports our
    # streamed_er2 as its fallback)
    from repro.kernels.grad_sketch.ops import grad_sketch_op
    return grad_sketch_op(h, w, proj.r_h, proj.r_v, targets, scale,
                          vocab_chunk=vocab_chunk,
                          impl=kernel_impl).reshape(-1)


def lm_unit_exact(bundle, params, batch, shard=None) -> jax.Array:
    """Paper-faithful: full flattened lm_head gradient (small models only)."""
    h, targets, scale = lm_unit_factors(bundle, params, batch, shard)
    w = bundle.head_weight(params)
    logits = h @ w.astype(jnp.float32)
    p = jax.nn.softmax(logits, axis=-1)
    e = p - jax.nn.one_hot(targets, w.shape[1], dtype=jnp.float32)
    e = e * scale[:, None]
    return exact_from_factors(h, e)


# ---------------------------------------------------------------------------
# RNN-T (joint network) gradient extraction.
#
# Fused path (cfg.rnnt.loss_impl == "fused", DESIGN.md §2): the joint
# gradient G = dL/dW_out is exactly the ``dw_out`` the fused loss's
# custom_vjp backward emits — alpha/beta occupancies contracted against
# the streamed joint — so ``jax.grad`` of the fused loss w.r.t. the head
# weight alone yields the (J, V) last-layer gradient without ever
# materializing the (B,T,U+1,V) logits, its gradient, or the (B,T,U+1,J)
# activations.  The sketch is then the two-sided projection
# ``R1^T G R2`` (identical in expectation to the factor-side
# ``(H R1)^T (E R2)``, since both equal the projected G).
#
# Dense path: error via autodiff through the materialized lattice — the
# parity oracle.
# ---------------------------------------------------------------------------

def _rnnt_per_example_nll_scale(batch):
    """The training loss's per-example scaling: mean over examples of
    nll / max(u_len, 1)."""
    B = batch["token_lens"].shape[0]
    return 1.0 / (jnp.maximum(batch["token_lens"].astype(jnp.float32), 1.0)
                  * B)


def rnnt_joint_grad(bundle, params, batch, shard=None) -> jax.Array:
    """(J, V) joint-network gradient of the unit's training loss via the
    fused custom_vjp backward (memory-lean; no joint materialization).
    ``shard`` pins the joint factors like the training loss does
    (``act_bsd``; see models/api.py) — identity when None."""
    from repro.core.rnnt_loss import rnnt_loss_fused
    from repro.models import rnnt as rnnt_mod
    from repro.models.common import IDENTITY_SHARDER
    cfg = bundle.cfg
    r = cfg.rnnt
    shard = shard or IDENTITY_SHARDER
    ze, zp = rnnt_mod.joint_factors(params, cfg, batch["feats"],
                                    batch["tokens"])
    ze = shard(ze, "act_bsd")
    zp = shard(zp, "act_bsd")
    t_lens = jnp.maximum(batch["feat_lens"] // r.time_reduction, 1)
    scale = _rnnt_per_example_nll_scale(batch)

    def loss_of_head(w_out):
        per_ex = rnnt_loss_fused(ze, zp, w_out, batch["tokens"], t_lens,
                                 batch["token_lens"],
                                 vocab_chunk=r.loss_vocab_chunk)
        return jnp.sum(per_ex * scale)

    return jax.grad(loss_of_head)(
        bundle.head_weight(params).astype(jnp.float32))


def rnnt_unit_factors(bundle, params, batch, shard=None):
    from repro.models import rnnt as rnnt_mod
    from repro.models.common import IDENTITY_SHARDER
    cfg = bundle.cfg
    r = cfg.rnnt
    z, _, _, _ = bundle.final_hidden(
        params, batch, shard=shard or IDENTITY_SHARDER)        # (B,T,U1,J)
    w_out = bundle.head_weight(params)

    def loss_of_logits(logits):
        from repro.core.rnnt_loss import rnnt_loss_from_logits
        t_lens = jnp.maximum(batch["feat_lens"] // r.time_reduction, 1)
        per_ex = rnnt_loss_from_logits(logits, batch["tokens"], t_lens,
                                       batch["token_lens"])
        per_ex = per_ex / jnp.maximum(batch["token_lens"].astype(jnp.float32),
                                      1.0)
        return per_ex.mean()

    logits = rnnt_mod.joint_logits(params, z)
    e = jax.grad(loss_of_logits)(logits.astype(jnp.float32))   # (B,T,U1,V)
    J = z.shape[-1]
    return (z.reshape(-1, J).astype(jnp.float32),
            e.reshape(-1, e.shape[-1]))


def rnnt_unit_sketch(bundle, params, batch, proj: Projections,
                     shard=None) -> jax.Array:
    if bundle.cfg.rnnt.loss_impl == "fused":
        g = rnnt_joint_grad(bundle, params, batch, shard)
        return (proj.r_h.astype(jnp.float32).T @ g
                @ proj.r_v.astype(jnp.float32)).reshape(-1)
    h, e = rnnt_unit_factors(bundle, params, batch, shard)
    return sketch_from_factors(h, e, proj)


def rnnt_unit_exact(bundle, params, batch, shard=None) -> jax.Array:
    if bundle.cfg.rnnt.loss_impl == "fused":
        return rnnt_joint_grad(bundle, params, batch, shard).reshape(-1)
    h, e = rnnt_unit_factors(bundle, params, batch, shard)
    return exact_from_factors(h, e)


# ---------------------------------------------------------------------------
# Sparse-expert (MoE) router-aware gradients (DESIGN.md §8)
# ---------------------------------------------------------------------------

def moe_router_grads(bundle, params, batch, shard=None):
    """Per-unit gradients of the total training loss (task + router
    load-balance aux) w.r.t. every ``router`` weight leaf.

    Returns a list of fp32 arrays shaped like the router leaves (stacked
    pattern-group routers keep their leading group dim).  One autodiff
    backward through the full stack per unit — deliberately NOT a
    closed-form last-layer trick: the router's gradient flows through
    the top-k combine weights and the aux loss, which is the signal the
    head gradient cannot see.  Opt-in via ``PGMConfig.moe_router_term``.
    """
    from repro.models.common import IDENTITY_SHARDER
    shard = shard or IDENTITY_SHARDER
    flat, tdef = jax.tree_util.tree_flatten_with_path(params)
    r_ix = [i for i, (p, _) in enumerate(flat)
            if "router" in jax.tree_util.keystr(p)]
    if not r_ix:
        raise ValueError(
            f"{bundle.cfg.name}: moe_router_term set but the params tree "
            f"has no 'router' leaves (family={bundle.cfg.family!r})")
    leaves = [l for _, l in flat]

    def loss_of_routers(router_leaves):
        lv = list(leaves)
        for i, v in zip(r_ix, router_leaves):
            lv[i] = v.astype(leaves[i].dtype)
        total, _ = bundle.loss_fn(
            jax.tree_util.tree_unflatten(tdef, lv), batch, shard=shard)
        return total

    return jax.grad(loss_of_routers)(
        [leaves[i].astype(jnp.float32) for i in r_ix])


def moe_unit_sketch(bundle, params, batch, proj: Projections,
                    vocab_chunk: int = 8192, shard=None,
                    kernel_impl: Optional[str] = None) -> jax.Array:
    """Router-aware MoE unit representation: the lm_head sketch
    concatenated with each router gradient projected through ``r_h`` on
    its d_model dim (router weights are (..., d, E), so the same
    projection matrix serves both terms).  The router term itself stays
    on the XLA autodiff path regardless of ``kernel_impl`` — only the
    head block dispatches to the fused kernel."""
    head = lm_unit_sketch(bundle, params, batch, proj, vocab_chunk, shard,
                          kernel_impl)
    rh = proj.r_h.astype(jnp.float32)
    parts = [jnp.einsum("...de,dk->...ke", g, rh).reshape(-1)
             for g in moe_router_grads(bundle, params, batch, shard)]
    return jnp.concatenate([head] + parts)


def moe_unit_exact(bundle, params, batch, shard=None) -> jax.Array:
    """Exact variant: flattened lm_head gradient + raw router gradients."""
    head = lm_unit_exact(bundle, params, batch, shard)
    parts = [g.reshape(-1)
             for g in moe_router_grads(bundle, params, batch, shard)]
    return jnp.concatenate([head] + parts)


# ---------------------------------------------------------------------------
# Unified entry points
# ---------------------------------------------------------------------------

def unit_gradient(bundle, params, batch, proj: Optional[Projections],
                  exact: bool = False, vocab_chunk: int = 8192,
                  shard=None, router_term: bool = False,
                  kernel_impl: Optional[str] = None) -> jax.Array:
    """One selection unit -> gradient representation vector.

    ``router_term`` (MoE family only) appends the router-logit gradient
    term to the head-gradient representation — see module docstring and
    DESIGN.md §8 for the definition and its cost.  ``kernel_impl``
    (``auto``/``pallas``/``xla``) picks the fused grad-sketch backend for
    the LM/MoE head block; the RNN-T sketch already rides the fused
    loss's ``dw_out`` custom_vjp factors, and the exact path is XLA-only."""
    if bundle.cfg.family == "rnnt":
        return (rnnt_unit_exact(bundle, params, batch, shard) if exact
                else rnnt_unit_sketch(bundle, params, batch, proj, shard))
    if router_term and bundle.cfg.family == "moe":
        return (moe_unit_exact(bundle, params, batch, shard) if exact
                else moe_unit_sketch(bundle, params, batch, proj,
                                     vocab_chunk, shard, kernel_impl))
    return (lm_unit_exact(bundle, params, batch, shard) if exact
            else lm_unit_sketch(bundle, params, batch, proj, vocab_chunk,
                                shard, kernel_impl))


def units_gradients(bundle, params, units, proj: Optional[Projections],
                    exact: bool = False, vocab_chunk: int = 8192,
                    router_term: bool = False,
                    kernel_impl: Optional[str] = None) -> jax.Array:
    """units: batch pytree with leading (n_units, ...) axis.
    Returns (n_units, D) fp32.  Sequential lax.map bounds peak memory to a
    single unit's forward pass (the paper's partition rationale)."""
    fn = lambda u: unit_gradient(bundle, params, u, proj, exact, vocab_chunk,
                                 router_term=router_term,
                                 kernel_impl=kernel_impl)
    return jax.lax.map(fn, units)


def _chunk_size(U: int, chunk_units: Optional[int]) -> int:
    """Largest chunk size <= the requested one that divides U."""
    cu = min(chunk_units or max(U // 16, 1), U)
    while U % cu:
        cu -= 1
    return cu


def units_gradients_scanned(bundle, params, units,
                            proj: Optional[Projections],
                            exact: bool = False,
                            chunk_units: Optional[int] = None,
                            vocab_chunk: int = 8192,
                            shard=None,
                            router_term: bool = False,
                            kernel_impl: Optional[str] = None) -> jax.Array:
    """Family-agnostic batched stage A: scan over unit *chunks*, vmap the
    per-unit gradient representation within a chunk.  Peak memory is
    bounded by ``chunk_units`` forward passes (vs one for the fully
    sequential ``units_gradients``, vs all for a flat vmap); the scan keeps
    it a single executable so a jitted selection round dispatches once.
    Used for RNN-T (autodiff through the transducer lattice resists the
    flattened-example trick below) and for the exact/paper-faithful path.
    ``shard`` is forwarded into the per-unit forward pass for activation
    sharding constraints; note that unlike the flattened LM path this
    still scans the (possibly sharded) unit axis, so under a mesh it does
    not avoid the §Perf select-iter-1 redundancy.
    """
    U = jax.tree.leaves(units)[0].shape[0]
    cu = _chunk_size(U, chunk_units)
    xs = jax.tree.map(
        lambda a: a.reshape((U // cu, cu) + a.shape[1:]), units)
    fn = lambda u: unit_gradient(bundle, params, u, proj, exact, vocab_chunk,
                                 shard, router_term=router_term,
                                 kernel_impl=kernel_impl)

    def chunk_fn(_, cb):
        return None, jax.vmap(fn)(cb)

    _, sks = jax.lax.scan(chunk_fn, None, xs)
    return sks.reshape(U, -1)


def units_gradients_batched(bundle, params, units,
                            proj: Optional[Projections] = None,
                            chunk_units: Optional[int] = None,
                            shard=None, vocab_chunk: int = 8192,
                            exact: bool = False,
                            router_term: bool = False,
                            kernel_impl: Optional[str] = None) -> jax.Array:
    """Batched stage-A gradient representations for resident/distributed
    selection rounds.

    ``units_gradients`` maps sequentially over units — correct and
    memory-bounded on one host, but under GSPMD a scan over a *sharded*
    units axis degenerates to every device computing every unit (16x
    redundant compute; §Perf select-iter-1).  Here LM units are flattened
    to an example axis that stays sharded over the data mesh axes
    (batches of ``chunk_units`` units at a time); per-unit sketches are
    recovered with a segment contraction.  RNN-T and the exact
    (paper-faithful) path route through ``units_gradients_scanned`` —
    same chunked single-executable shape, per-unit math inside a vmap.

    This is the kernel of ``core/pgm.ResidentSelector``: jit it once with
    the projections closed over and every selection round reuses both the
    executable and the device-resident ``proj`` constants.
    """
    # RNN-T, exact, and router-aware MoE route through the scanned path:
    # the flattened-example trick below recovers per-unit sketches with a
    # segment contraction over head factors, which cannot express the
    # per-unit autodiff router term (one backward per unit is required)
    if bundle.cfg.family == "rnnt" or exact or \
            (router_term and bundle.cfg.family == "moe"):
        return units_gradients_scanned(bundle, params, units, proj,
                                       exact=exact, chunk_units=chunk_units,
                                       vocab_chunk=vocab_chunk, shard=shard,
                                       router_term=router_term,
                                       kernel_impl=kernel_impl)
    from repro.kernels.grad_sketch.ops import grad_sketch_units_op
    from repro.models.common import IDENTITY_SHARDER
    shard = shard or IDENTITY_SHARDER
    lead = jax.tree.leaves(units)[0].shape
    U, b = lead[0], lead[1]
    flat = jax.tree.map(lambda a: a.reshape((U * b,) + a.shape[2:]), units)
    cu = _chunk_size(U, chunk_units)
    n_chunks = U // cu
    xs = jax.tree.map(
        lambda a: a.reshape((n_chunks, cu * b) + a.shape[1:]), flat)
    w = bundle.head_weight(params)

    def chunk_fn(_, cb):
        h, targets, mask, _ = bundle.final_hidden(params, cb, shard=shard,
                                                  remat=False)
        d = h.shape[-1]
        S = h.shape[1]
        denom = jnp.maximum(mask.sum(axis=-1, keepdims=True), 1.0)
        scale = (mask / (denom * b)).astype(jnp.float32)
        # fused per-unit gradient + two-sided sketch: one kernel call per
        # chunk (Pallas streams the vocab axis in VMEM; the XLA fallback
        # is the historical streamed_er2 + segment-einsum, bit-identical)
        sk = grad_sketch_units_op(
            h.reshape(cu, b * S, d), w, proj.r_h, proj.r_v,
            targets.reshape(cu, b * S), scale.reshape(cu, b * S),
            vocab_chunk=vocab_chunk, impl=kernel_impl)
        return None, sk.reshape(cu, -1)

    _, sks = jax.lax.scan(chunk_fn, None, xs)
    return sks.reshape(U, -1)


def make_proj_for(bundle, key, k1: int = 64, k2: int = 64) -> Projections:
    from repro.core.sketch import make_projections
    cfg = bundle.cfg
    if cfg.family == "rnnt":
        return make_projections(key, cfg.rnnt.joint_dim, cfg.rnnt.vocab_size,
                                k1, k2)
    return make_projections(key, cfg.d_model, cfg.vocab_size, k1, k2)
