"""Gradient Matching (paper Algorithm 2): Orthogonal Matching Pursuit with
l2-regularized weight refits, solved entirely in Gram space.

Given unit-gradient vectors G (n, D) and a target gradient g_t, the OMP
loop only ever needs  K = G G^T  and  c = G g_t  (plus ||g_t||^2 for the
error term).  The O(n D) inner products are paid once in two MXU-friendly
matmuls (the ``omp_gram`` Pallas kernel); each OMP iteration is then O(k^2)
gathers + a ridge refit — O(k^2) triangular solves against an
incrementally grown Cholesky factor (``solver="chol"``, the default), or
the O(k^3) dense refactorization kept as the oracle (``solver="dense"``)
— tiny and fully jittable (``lax.while_loop`` with a static budget
bound).

E_lambda(w, X) = lambda ||w||^2 + || sum_i w_i g_i - g_t ||^2
              = lambda ||w||^2 + w^T K_XX w - 2 w^T c_X + ||g_t||^2.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class OMPResult(NamedTuple):
    indices: jax.Array     # (budget,) int32, padded with -1
    weights: jax.Array     # (budget,) fp32, 0 for unused slots
    n_selected: jax.Array  # scalar int32
    error: jax.Array       # final E_lambda value


def gram(g: jax.Array) -> jax.Array:
    """(n, D) -> (n, n) fp32 Gram matrix (oracle for the omp_gram kernel)."""
    g = g.astype(jnp.float32)
    return g @ g.T


def _masked_ridge_solve(K_sub, c_sub, active, lam):
    """Solve (K_sub + lam I) w = c_sub over the first ``n_active`` rows;
    inactive rows are replaced by identity => w_i = 0 there.  The dense
    O(k^3)-per-iteration oracle for the incremental Cholesky path."""
    k = K_sub.shape[0]
    act = active.astype(jnp.float32)
    outer = act[:, None] * act[None, :]
    M = K_sub * outer + jnp.eye(k) * (lam * act + (1.0 - act))
    rhs = c_sub * act
    w = jnp.linalg.solve(M, rhs)
    return w * act


def _chol_append(L, K, safe, j, i, lam):
    """Grow the Cholesky factor of (K_active + lam I) by one row for the
    atom ``j`` just placed at slot ``i``: O(k^2) against the dense
    refactorization's O(k^3).

    Rows past the active prefix stay identity rows (from the ``eye``
    init), which decouples them from both triangular solves: their
    right-hand sides are zeroed, their off-diagonals are zero, and their
    unit diagonal maps zero to zero.
    """
    budget = L.shape[0]
    idx = jnp.arange(budget)
    k_col = jnp.where(idx < i, K[safe, j], 0.0)
    v = jax.scipy.linalg.solve_triangular(L, k_col, lower=True)
    dsq = K[j, j] + lam - v @ v
    dnew = jnp.sqrt(jnp.maximum(dsq, 1e-12))
    row = jnp.where(idx < i, v, jnp.where(idx == i, dnew, 0.0))
    return jnp.where((idx == i)[:, None], row[None, :], L)


def _chol_ridge_solve(L, c_sub, active):
    """Two triangular solves against the maintained factor: the same
    masked ridge solution as ``_masked_ridge_solve`` (identity rows pass
    zeros through), without rebuilding or refactorizing the system."""
    act = active.astype(jnp.float32)
    y = jax.scipy.linalg.solve_triangular(L, c_sub * act, lower=True)
    w = jax.scipy.linalg.solve_triangular(L.T, y, lower=False)
    return w * act


@partial(jax.jit, static_argnames=("budget", "nonneg", "solver"))
def gram_omp(
    K: jax.Array,          # (n, n) fp32
    c: jax.Array,          # (n,)  <g_i, g_target>
    target_sq: jax.Array,  # scalar ||g_target||^2
    budget: int,
    lam: float = 0.5,
    eps: float = 1e-10,
    nonneg: bool = True,
    solver: str = "chol",
) -> OMPResult:
    if solver not in ("chol", "dense"):
        raise ValueError(f"unknown gram_omp solver {solver!r}")
    n = K.shape[0]
    budget = min(budget, n)

    def error_of(w_full):
        quad = w_full @ (K @ w_full)
        return lam * jnp.sum(w_full ** 2) + quad - 2.0 * w_full @ c + target_sq

    def cond(state):
        i, sel, w_full, err, L = state
        return jnp.logical_and(i < budget, err > eps)

    def body(state):
        i, sel, w_full, _, L = state
        # alignment of each unit with the residual r = g_t - sum w g
        scores = c - K @ w_full
        # OR-combine scatter: -1 padding maps to slot 0 with value 0, which
        # must never clear a previously taken slot
        taken = jnp.zeros((n,), jnp.int32).at[
            jnp.where(sel >= 0, sel, 0)].add((sel >= 0).astype(jnp.int32)) > 0
        scores = jnp.where(taken, -jnp.inf, scores)
        j = jnp.argmax(scores).astype(jnp.int32)
        sel = sel.at[i].set(j)
        # ridge refit on the selected set (gathered (budget, budget) block)
        safe = jnp.where(sel >= 0, sel, 0)
        c_sub = c[safe]
        active = jnp.arange(budget) <= i
        if solver == "chol":
            L = _chol_append(L, K, safe, j, i, lam)
            w_sub = _chol_ridge_solve(L, c_sub, active)
        else:
            K_sub = K[safe][:, safe]
            w_sub = _masked_ridge_solve(K_sub, c_sub, active, lam)
        if nonneg:
            w_sub = jnp.maximum(w_sub, 0.0)
        w_full = jnp.zeros((n,)).at[safe].set(w_sub * active)
        return i + 1, sel, w_full, error_of(w_full), L

    sel0 = jnp.full((budget,), -1, jnp.int32)
    w0 = jnp.zeros((n,))
    L0 = jnp.eye(budget, dtype=jnp.float32)
    state = (jnp.asarray(0, jnp.int32), sel0, w0, target_sq + 0.0, L0)
    i, sel, w_full, err, _ = jax.lax.while_loop(cond, body, state)
    safe = jnp.where(sel >= 0, sel, 0)
    w_sel = w_full[safe] * (sel >= 0)
    return OMPResult(sel, w_sel, i, err)


def gm_select(
    g_units: jax.Array,    # (n, D) unit gradients (sketched or exact)
    g_target: jax.Array,   # (D,)
    budget: int,
    lam: float = 0.5,
    eps: float = 1e-10,
    nonneg: bool = True,
    solver: str = "chol",
) -> OMPResult:
    """Algorithm 2 entry point on raw gradient vectors."""
    g = g_units.astype(jnp.float32)
    t = g_target.astype(jnp.float32)
    return gram_omp(gram(g), g @ t, t @ t, budget, lam, eps, nonneg, solver)
