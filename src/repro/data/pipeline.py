"""Deterministic, shardable batch pipeline.

Selection *units* are fixed mini-batches (the paper's PerBatch
granularity): `make_units` stacks a corpus into (n_units, unit_size, ...)
arrays once; PGM selects unit indices + weights; `subset_iterator` then
re-shuffles the selected units into SGD batches each epoch (paper §4:
"randomly shuffle elements in the subset, divide into mini-batches of
size B, run weighted mini-batch SGD").

Everything is keyed by (seed, epoch) so a restart resumes the exact
stream (fault tolerance: the checkpoint records epoch + microstep).

Two consumers share the plan arrays produced here (DESIGN.md §1/§3):
the scanned epoch engine (`train/engine.py`) gathers batches from them
on device — with ``pad_to_steps`` padding subset plans to a fixed shape
so changing ``n_selected`` between selection rounds never retraces the
epoch executable — and the host iterators below are thin unpadded views
over the same plans, so both execution paths see byte-identical batch
order by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.data.synthetic import ASRCorpus, LMCorpus


def lm_units(corpus: LMCorpus, unit_size: int) -> Dict[str, np.ndarray]:
    """-> dict with leading (n_units, unit_size, ...) arrays."""
    n = (corpus.tokens.shape[0] // unit_size) * unit_size
    toks = corpus.tokens[:n]
    lens = corpus.lengths[:n]
    S = toks.shape[1]
    mask = (np.arange(S)[None, :] < lens[:, None]).astype(np.float32)
    nu = n // unit_size
    return {
        "tokens": toks.reshape(nu, unit_size, S).astype(np.int32),
        "loss_mask": mask.reshape(nu, unit_size, S),
        "weights": np.ones((nu, unit_size), np.float32),
    }


def asr_units(corpus: ASRCorpus, unit_size: int) -> Dict[str, np.ndarray]:
    n = (corpus.feats.shape[0] // unit_size) * unit_size
    nu = n // unit_size
    sh = lambda a: a[:n].reshape((nu, unit_size) + a.shape[1:])
    return {
        "feats": sh(corpus.feats).astype(np.float32),
        "feat_lens": sh(corpus.feat_lens).astype(np.int32),
        "tokens": sh(corpus.tokens).astype(np.int32),
        "token_lens": sh(corpus.token_lens).astype(np.int32),
        "weights": np.ones((nu, unit_size), np.float32),
    }


def unit_durations(units: Dict[str, np.ndarray]) -> np.ndarray:
    """Per-unit total duration (for LargeOnly/LargeSmall baselines)."""
    if "feat_lens" in units:
        return units["feat_lens"].sum(axis=1).astype(np.float32)
    return units["loss_mask"].sum(axis=(1, 2)).astype(np.float32)


# ---------------------------------------------------------------------------
# Epoch plans: the (seed, epoch)-keyed batch schedule as index/weight arrays.
# The scanned epoch engine (train/engine.py) gathers batches from these on
# device; the host iterators below are thin views over the same plans, so
# both execution paths see byte-identical batch order by construction.
# ---------------------------------------------------------------------------

def epoch_plan(n_units: int, seed: int, epoch: int,
               batch_units: int = 1) -> np.ndarray:
    """Full-data epoch schedule -> (n_steps, batch_units) int32 unit ids.

    Seeded shuffle of all units, remainder dropped (warm-start phase).
    The plan is a pure function of ``(seed, epoch)``: a resumed run
    rebuilds byte-identical schedules for the remaining epochs, which is
    what makes checkpoint/resume exact (see ``train/loop.py``).
    """
    order = np.random.default_rng((seed, epoch)).permutation(n_units)
    n_steps = n_units // batch_units
    return order[: n_steps * batch_units].reshape(
        n_steps, batch_units).astype(np.int32)


def subset_epoch_plan(indices, weights, seed: int, epoch: int,
                      batch_units: int = 1,
                      pad_to_steps: Optional[int] = None,
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Weighted-subset epoch schedule -> (unit ids, unit weights), each
    ``(n_steps, batch_units)``.  Drops -1 padding from the selection,
    shuffles the survivors with the (seed, epoch, 1) stream, drops the
    remainder.

    ``pad_to_steps`` (the retrace-free contract used by the scanned epoch
    engine): when given, the plan is padded with *padding rows* up to
    exactly ``(pad_to_steps, batch_units)`` — id ``-1`` and weight ``0`` —
    so every selection round produces the same plan shape regardless of
    ``n_selected`` and one compiled epoch executable serves them all.
    Padding-row semantics downstream (DESIGN.md §3): the engine clamps the
    gather index to 0, runs the step, and gates the update with
    ``optim.gate_step`` so a padding row advances neither params nor
    optimizer state and contributes nothing to metrics.  Host iterators
    never see padding rows (they call this with ``pad_to_steps=None``).
    """
    valid = np.asarray(indices) >= 0
    idx = np.asarray(indices)[valid]
    w = np.asarray(weights)[valid]
    order = np.random.default_rng((seed, epoch, 1)).permutation(len(idx))
    idx, w = idx[order], w[order]
    n_steps = len(idx) // batch_units
    shape = (n_steps, batch_units)
    plan_idx = idx[: n_steps * batch_units].reshape(shape).astype(np.int32)
    plan_w = w[: n_steps * batch_units].reshape(shape).astype(np.float32)
    if pad_to_steps is not None:
        if n_steps > pad_to_steps:
            raise ValueError(
                f"subset plan needs {n_steps} steps > pad_to_steps="
                f"{pad_to_steps}")
        n_pad = pad_to_steps - n_steps
        plan_idx = np.concatenate(
            [plan_idx, np.full((n_pad, batch_units), -1, np.int32)])
        plan_w = np.concatenate(
            [plan_w, np.zeros((n_pad, batch_units), np.float32)])
    return plan_idx, plan_w


def full_iterator(units, seed: int, epoch: int,
                  batch_units: int = 1) -> Iterator[Dict[str, np.ndarray]]:
    """Iterate all units in a seeded epoch shuffle (warm-start phase)."""
    nu = units[next(iter(units))].shape[0]
    for sel in epoch_plan(nu, seed, epoch, batch_units):
        yield {k: _merge_units(v[sel]) for k, v in units.items()}


def subset_iterator(units, indices, weights, seed: int, epoch: int,
                    batch_units: int = 1) -> Iterator[Dict[str, np.ndarray]]:
    """Weighted iteration over a PGM/baseline selection."""
    plan_idx, plan_w = subset_epoch_plan(indices, weights, seed, epoch,
                                         batch_units)
    for sel, w in zip(plan_idx, plan_w):
        batch = {k: _merge_units(v[sel]) for k, v in units.items()}
        uw = np.repeat(w, units["weights"].shape[1]).astype(np.float32)
        batch["weights"] = batch["weights"] * uw
        yield batch


def _merge_units(a: np.ndarray) -> np.ndarray:
    """(k, unit, ...) -> (k*unit, ...)."""
    return a.reshape((-1,) + a.shape[2:])


def shard_batch(batch, sharding=None):
    """Host batch -> device arrays (optionally with a NamedSharding)."""
    import jax
    if sharding is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    return {k: jax.device_put(v, sharding[k] if isinstance(sharding, dict)
                              else sharding) for k, v in batch.items()}
