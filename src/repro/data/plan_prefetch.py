"""Async host-side plan generation (DESIGN.md §1 step 4).

Epoch plans are tiny ``(seed, epoch)``-keyed index/weight arrays built
with numpy on the host (``data/pipeline.epoch_plan`` /
``subset_epoch_plan`` behind ``EpochEngine.full_plan`` /
``subset_plan``).  Building them synchronously between epoch dispatches
puts that (cheap but serial) host work — plus its ``device_put`` — on
the critical path.  ``PlanPrefetcher`` double-buffers upcoming plans on
a single worker thread so they build and transfer while the current
epoch chunk executes on device.

Determinism is free: plan builders are pure functions of
``(seed, epoch, selection)``, so a prefetched plan is bit-identical to
one built synchronously, and a resumed run — which starts with an empty
prefetch buffer — rebuilds exactly the plans the interrupted run would
have used (asserted by ``tests/test_sharded_engine.py``).

Keys are caller-chosen hashables (the training loop uses
``("full", epoch)`` / ``("subset", selection_round, epoch)``): a new
selection round changes the key, so a superseded plan can never be
served.  A key that will no longer be fetched still occupies a buffer
slot, so callers that re-key (the loop, after each selection round)
should call ``invalidate()`` to drop pending work — otherwise orphans
accumulate until the buffer is permanently full.

Failure semantics: a *transient* builder failure (flaky storage, an
injected chaos fault) is retried in place — ``retries`` attempts with
capped exponential backoff — on whichever thread runs the build, the
worker or the ``get()`` fallback, so both paths degrade identically
(DESIGN.md §10).  A builder that keeps failing must not strand the
consumer or leak the thread: ``get()`` re-raises the final exception at
the consumer (and frees the buffer slot, so the caller can retry
synchronously); an *orphaned* failed build is simply dropped by
``invalidate()``; ``close()`` — also run by ``__del__`` and the context
manager — cancels what hasn't started and joins the worker thread, and
is idempotent.
"""
from __future__ import annotations

import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, Hashable


class PlanPrefetcher:
    """Single-worker double buffer for plan construction.

    ``schedule(key, build)`` submits ``build`` (no-arg, returns the plan
    — typically already ``device_put``) to the worker thread; at most
    ``max_pending`` submissions are outstanding so a long horizon cannot
    pile up host memory.  ``get(key, build)`` returns the prefetched
    result when ``key`` was scheduled, else falls back to calling
    ``build`` synchronously — the two paths return identical values
    because builders are pure.  A prefetched build that *failed*
    re-raises its exception from ``get()``.
    """

    def __init__(self, max_pending: int = 2, retries: int = 2,
                 backoff_s: float = 0.05, max_backoff_s: float = 2.0):
        self.max_pending = int(max_pending)
        self.retries = int(retries)
        self.backoff_s = float(backoff_s)
        self.max_backoff_s = float(max_backoff_s)
        self._pending: Dict[Hashable, Future] = {}
        self._ex = ThreadPoolExecutor(max_workers=1,
                                      thread_name_prefix="plan-prefetch")
        self._closed = False
        #: observability: get() calls served from the buffer / built
        #: synchronously, and builds recovered by a retry (used by tests
        #: and the benchmark harness)
        self.hits = 0
        self.misses = 0
        self.retried = 0

    def _build_with_retries(self, build: Callable[[], object]):
        """Run ``build``, retrying transient failures ``retries`` times
        with capped exponential backoff before letting the exception
        propagate.  Builders are pure, so a retry returns exactly the
        plan a clean first attempt would have."""
        delay = self.backoff_s
        for attempt in range(self.retries + 1):
            try:
                return build()
            except Exception:
                if attempt == self.retries:
                    raise
                self.retried += 1
                time.sleep(delay)
                delay = min(delay * 2, self.max_backoff_s)

    def schedule(self, key: Hashable, build: Callable[[], object]) -> bool:
        """Queue ``build`` for ``key``.  Idempotent: an already-scheduled
        key reports True (so a caller topping up a look-ahead window can
        keep walking forward past keys it queued earlier); returns False
        only when closed or the buffer is full."""
        if key in self._pending:
            return True
        if self._closed or len(self._pending) >= self.max_pending:
            return False
        self._pending[key] = self._ex.submit(self._build_with_retries,
                                             build)
        return True

    def get(self, key: Hashable, build: Callable[[], object]):
        """The plan for ``key`` — from the buffer when prefetched, else
        built synchronously.  A builder exception raised on the worker
        thread propagates here, to the consumer that asked for the key
        (the slot is freed first, so retrying falls back to a
        synchronous ``build``)."""
        fut = self._pending.pop(key, None)
        if fut is None:
            self.misses += 1
            return self._build_with_retries(build)
        self.hits += 1
        return fut.result()        # re-raises the worker's exception

    def invalidate(self):
        """Drop every pending entry (cancelling what hasn't started):
        call when the keys change — e.g. a new selection round — so
        superseded plans don't pin buffer slots or device memory.  A
        dropped entry's result (or exception) is deliberately discarded."""
        for fut in self._pending.values():
            fut.cancel()
        self._pending.clear()

    def close(self):
        """Cancel anything not yet running, drain pending state and join
        the worker thread.  Idempotent; also invoked by ``__del__`` so a
        prefetcher dropped without an explicit ``close()`` (e.g. when
        the training loop dies mid-epoch) still releases its thread."""
        if self._closed:
            return
        self._closed = True
        self.invalidate()
        self._ex.shutdown(wait=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:       # interpreter teardown: best effort
            pass
