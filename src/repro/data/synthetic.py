"""Seeded synthetic corpora (offline stand-ins for Librispeech/TIMIT).

Design goal: the corpora must carry enough *structure* that data-subset
selection has signal to exploit —
  * a latent "difficulty" mixture: easy examples come from a low-entropy
    Markov chain, hard examples from a higher-entropy one (subset methods
    that match gradients should prefer a difficulty profile matching the
    target distribution);
  * length variation (log-normal-ish) so LargeOnly/LargeSmall behave like
    in the paper;
  * noise injection à la Librispeech-noise: a fraction of examples gets
    feature noise at a given SNR (ASR) or corrupted labels (LM).
Everything is generated from an integer seed — runs are reproducible and
shard-deterministic.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class LMCorpus:
    tokens: np.ndarray        # (N, S) int32, padded with pad_id
    lengths: np.ndarray       # (N,)
    difficulty: np.ndarray    # (N,) float in [0,1]
    noisy: np.ndarray         # (N,) bool
    vocab_size: int
    pad_id: int = 0


@dataclasses.dataclass
class ASRCorpus:
    feats: np.ndarray         # (N, T, F) float32
    feat_lens: np.ndarray     # (N,)
    tokens: np.ndarray        # (N, U) int32 (0 = blank/pad)
    token_lens: np.ndarray    # (N,)
    durations: np.ndarray     # (N,) float (seconds-like, for Large* baselines)
    noisy: np.ndarray         # (N,) bool
    vocab_size: int
    n_feats: int


def _markov_tokens(rng, n, s_max, vocab, temperature):
    """Rows of a random Markov chain; temperature controls entropy."""
    k = min(vocab - 1, 64)
    logits = rng.normal(size=(k, k)) / max(temperature, 1e-3)
    probs = np.exp(logits - logits.max(axis=1, keepdims=True))
    probs /= probs.sum(axis=1, keepdims=True)
    cdf = np.cumsum(probs, axis=1)
    out = np.zeros((n, s_max), np.int32)
    state = rng.integers(0, k, size=n)
    for t in range(s_max):
        out[:, t] = state + 1                         # reserve 0 for pad
        u = rng.random(n)
        state = (cdf[state] > u[:, None]).argmax(axis=1)
    return out


def make_lm_corpus(
    seed: int, n_examples: int, seq_len: int, vocab_size: int,
    hard_fraction: float = 0.4, noise_fraction: float = 0.0,
    min_len_frac: float = 0.3,
) -> LMCorpus:
    rng = np.random.default_rng(seed)
    n_hard = int(n_examples * hard_fraction)
    easy = _markov_tokens(rng, n_examples - n_hard, seq_len, vocab_size, 0.3)
    hard = _markov_tokens(rng, n_hard, seq_len, vocab_size, 2.5)
    tokens = np.concatenate([easy, hard], axis=0)
    difficulty = np.concatenate([
        np.zeros(n_examples - n_hard), np.ones(n_hard)])
    perm = rng.permutation(n_examples)
    tokens, difficulty = tokens[perm], difficulty[perm]

    lengths = np.clip(
        (np.exp(rng.normal(0.0, 0.5, n_examples))
         * seq_len * (min_len_frac + 0.35)).astype(np.int32),
        max(int(seq_len * min_len_frac), 4), seq_len)
    for i in range(n_examples):
        tokens[i, lengths[i]:] = 0

    noisy = np.zeros(n_examples, bool)
    if noise_fraction > 0:
        idx = rng.choice(n_examples, int(n_examples * noise_fraction),
                         replace=False)
        noisy[idx] = True
        for i in idx:                                  # label corruption
            L = lengths[i]
            n_corrupt = max(L // 3, 1)
            pos = rng.choice(L, n_corrupt, replace=False)
            tokens[i, pos] = rng.integers(1, vocab_size, n_corrupt)
    return LMCorpus(tokens, lengths, difficulty, noisy, vocab_size)


def make_asr_corpus(
    seed: int, n_examples: int, n_feats: int = 16, vocab_size: int = 32,
    min_tokens: int = 4, max_tokens: int = 12, frames_per_token: int = 4,
    noise_fraction: float = 0.0, snr_db: float = 10.0,
) -> ASRCorpus:
    """Feats are emissions of the token sequence (tokens are recoverable
    from feats), so an acoustic model can actually learn the mapping."""
    rng = np.random.default_rng(seed)
    emb = rng.normal(size=(vocab_size, n_feats)).astype(np.float32)
    U = max_tokens
    T = max_tokens * frames_per_token
    tokens = np.zeros((n_examples, U), np.int32)
    feats = np.zeros((n_examples, T, n_feats), np.float32)
    token_lens = rng.integers(min_tokens, max_tokens + 1, n_examples)
    noisy = np.zeros(n_examples, bool)
    if noise_fraction > 0:
        noisy[rng.choice(n_examples, int(n_examples * noise_fraction),
                         replace=False)] = True
    for i in range(n_examples):
        u = token_lens[i]
        seq = rng.integers(1, vocab_size, u)
        tokens[i, :u] = seq
        frames = np.repeat(emb[seq], frames_per_token, axis=0)
        frames = frames + rng.normal(size=frames.shape) * 0.1
        if noisy[i]:
            # additive noise at the given SNR
            sig_pow = float((frames ** 2).mean())
            noise_pow = sig_pow / (10 ** (snr_db / 10))
            frames = frames + rng.normal(size=frames.shape) * np.sqrt(noise_pow)
        feats[i, : u * frames_per_token] = frames
    feat_lens = (token_lens * frames_per_token).astype(np.int32)
    durations = feat_lens.astype(np.float32) / frames_per_token
    return ASRCorpus(feats, feat_lens, tokens, token_lens.astype(np.int32),
                     durations, noisy, vocab_size, n_feats)
