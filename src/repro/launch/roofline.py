"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (brief-specified).

Per (arch, shape, mesh) cell, from the compiled per-device program:
  compute_term    = HLO_FLOPs_per_device / peak_FLOPs
  memory_term     = HLO_bytes_per_device / HBM_bw
  collective_term = wire_bytes_per_device / ICI_bw
(cost_analysis of an SPMD-partitioned module reports the single-device
program; wire bytes use the per-op ring models in dryrun.parse_collectives)

Also reported: MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) per device
per step, and the usefulness ratio MODEL_FLOPS / HLO_FLOPs (catches
remat/redundancy waste; > 1 would indicate XLA undercounting, < 1/3-ish
indicates heavy recompute).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import get_config, get_shape

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link (one link assumed per the brief)


def model_flops(arch: str, shape_name: str, step: str) -> float:
    """Ideal model FLOPs per step (global): 6*N*D for training,
    2*N*D for prefill, 2*N*tokens for decode (one token per sequence)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    n_active = cfg.n_active_params()
    if step == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if step == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch            # decode: 1 new token per seq
    return 2.0 * n_active * tokens


def ideal_decode_bytes(arch: str, shape_name: str, n_dev: int) -> float:
    """Decode is memory-bound by construction: the floor per step is
    reading the active params (bf16) + the KV/state cache once."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    params_b = cfg.n_active_params() * 2
    cache_b = 0.0
    for kind in cfg.layer_kinds():
        if kind in ("attn", "global"):
            cache_b += (shape.global_batch * shape.seq_len * cfg.kv_dim
                        * 2 * 2)
        elif kind == "local":
            cache_b += (shape.global_batch * min(cfg.window or shape.seq_len,
                                                 shape.seq_len)
                        * cfg.kv_dim * 2 * 2)
        elif kind == "rwkv":
            cache_b += (shape.global_batch * cfg.n_heads
                        * cfg.rwkv_head_dim ** 2 * 4)
        elif kind == "rec":
            cache_b += shape.global_batch * (cfg.lru_width or cfg.d_model) * 4
    return (params_b + cache_b) / n_dev


def roofline_terms(rec: Dict) -> Dict:
    n_dev = rec["n_devices"]
    flops = rec.get("flops") or 0.0
    bytes_acc = rec.get("bytes_accessed") or 0.0
    wire = sum(c["wire_bytes"] for c in rec["collectives"].values())
    compute_t = flops / PEAK_FLOPS
    memory_t = bytes_acc / HBM_BW
    coll_t = wire / ICI_BW
    mf = model_flops(rec["arch"], rec["shape"], rec["step"])
    mf_per_dev = mf / n_dev
    bound = max(compute_t, memory_t, coll_t, 1e-30)
    if rec["step"] == "decode":
        # decode roofline = ideal HBM traffic (params + cache once) vs bound
        ideal_t = ideal_decode_bytes(rec["arch"], rec["shape"],
                                     n_dev) / HBM_BW
        frac = ideal_t / bound
    else:
        frac = (mf_per_dev / PEAK_FLOPS) / bound
    terms = {
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": coll_t,
        "dominant": max(
            [("compute", compute_t), ("memory", memory_t),
             ("collective", coll_t)], key=lambda kv: kv[1])[0],
        "model_flops_per_dev": mf_per_dev,
        "hlo_flops_per_dev": flops,
        "useful_ratio": (mf_per_dev / flops) if flops else None,
        "bound_s": bound,
        "roofline_fraction": frac,
        # CPU-backend caveat (DESIGN.md §6): XLA-CPU promotes bf16 matmuls
        # to f32, so HLO traffic for semantically-bf16 tensors is ~2x the
        # TPU value; adjusted terms assume bf16 on the wire/HBM.
        "memory_s_bf16adj": memory_t / 2.0,
        "collective_s_bf16adj": coll_t / 2.0,
    }
    return terms


def generic_terms(rec: Dict) -> Dict:
    """Roofline terms for a record that is not an (arch, shape, step)
    training cell — e.g. the selection round — from raw per-device
    ``flops`` / ``bytes_accessed`` / ``wire_bytes``.  No model-FLOPs
    usefulness ratio: the round's ideal FLOP count is the sketch
    contraction itself, which IS the measured program."""
    flops = rec.get("flops") or 0.0
    bytes_acc = rec.get("bytes_accessed") or 0.0
    wire = rec.get("wire_bytes") or 0.0
    compute_t = flops / PEAK_FLOPS
    memory_t = bytes_acc / HBM_BW
    coll_t = wire / ICI_BW
    return {
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": coll_t,
        "dominant": max(
            [("compute", compute_t), ("memory", memory_t),
             ("collective", coll_t)], key=lambda kv: kv[1])[0],
        "bound_s": max(compute_t, memory_t, coll_t, 1e-30),
        "flops_per_byte": (flops / bytes_acc) if bytes_acc else None,
    }


def selection_round_records(n_examples: int = 128, seq: int = 12,
                            unit_size: int = 2,
                            arch: str = "starcoder2-3b-smoke") -> List[Dict]:
    """Compile one full PGM selection round — stage A fused grad-sketch
    over all units + stage B partitioned Gram/OMP — with the selection
    kernels on (``pallas``) vs off (``xla``) and analyze the optimized
    HLO of each (launch/hlo_analysis.py): FLOPs, HBM bytes, wire bytes,
    and the v5e roofline terms.

    Caveat (DESIGN.md §9): off-TPU the ``pallas`` variant compiles the
    *interpreter's* lowering — its FLOP count still reflects the fused
    algorithm (the dots are real), but its byte count includes
    interpreter bookkeeping traffic that does not exist on TPU, so
    kernel-on bytes off-TPU are an overcount, not a measurement.
    """
    import jax
    import jax.numpy as jnp

    from repro.configs.base import PGMConfig
    from repro.core.lastlayer import make_proj_for, units_gradients_batched
    from repro.core.pgm import partitioned_gm
    from repro.data.pipeline import lm_units
    from repro.data.synthetic import make_lm_corpus
    from repro.launch import hlo_analysis
    from repro.models.api import build_model

    cfg = get_config(arch)
    bundle = build_model(cfg)
    corpus = make_lm_corpus(0, n_examples, seq, cfg.vocab_size,
                            hard_fraction=0.4)
    units = {k: jnp.asarray(v)
             for k, v in lm_units(corpus, unit_size=unit_size).items()}
    n_units = int(units["tokens"].shape[0])
    params = bundle.init_params(jax.random.PRNGKey(0))
    proj = make_proj_for(bundle, jax.random.fold_in(jax.random.PRNGKey(0),
                                                    17), 32, 32)
    pc = PGMConfig(subset_fraction=0.3, n_partitions=4,
                   sketch_dim_h=32, sketch_dim_v=32)
    budget_per = max(int(pc.subset_fraction * n_units)
                     // pc.n_partitions, 1)

    recs = []
    for impl in ("xla", "pallas"):
        def round_fn(params, units, impl=impl):
            g = units_gradients_batched(bundle, params, units, proj,
                                        kernel_impl=impl)
            return partitioned_gm(g, pc.n_partitions, budget_per, pc.lam,
                                  pc.eps, pc.nonneg_weights, False, None,
                                  kernel_impl=impl)

        text = jax.jit(round_fn).lower(params, units).compile().as_text()
        an = hlo_analysis.analyze(text)
        rec = {
            "variant": f"selection_round[{impl}]",
            "kernel_impl": impl,
            "arch": arch,
            "n_units": n_units,
            "flops": an.flops,
            "bytes_accessed": an.bytes,
            "wire_bytes": an.wire_bytes,
        }
        rec["terms"] = generic_terms(rec)
        recs.append(rec)
    return recs


def selection_table(recs: Optional[List[Dict]] = None) -> str:
    recs = selection_round_records() if recs is None else recs
    hdr = (f"{'variant':26s} {'flops':>12s} {'hbm_bytes':>12s} "
           f"{'compute_s':>11s} {'memory_s':>11s} {'domin':>7s} "
           f"{'flop/B':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in recs:
        t = r["terms"]
        fb = f"{t['flops_per_byte']:.2f}" if t["flops_per_byte"] else "n/a"
        lines.append(
            f"{r['variant']:26s} {r['flops']:12.3e} "
            f"{r['bytes_accessed']:12.3e} {t['compute_s']:11.3e} "
            f"{t['memory_s']:11.3e} {t['dominant']:>7s} {fb:>7s}")
    return "\n".join(lines)


def load_artifacts(art_dir: str = "artifacts/dryrun") -> List[Dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(p) as f:
            rec = json.load(f)
        if rec.get("status") == "skip" or "n_devices" not in rec:
            continue
        rec["terms"] = roofline_terms(rec)
        out.append(rec)
    return out


def table(art_dir: str = "artifacts/dryrun", mesh: Optional[str] = None
          ) -> str:
    rows = [r for r in load_artifacts(art_dir)
            if mesh is None or r["mesh"] == mesh]
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':10s} {'step':7s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
           f"{'domin':>7s} {'useful':>7s} {'roofl%':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        t = r["terms"]
        mesh_tag = "multi" if "multi" in r["mesh"] else "single"
        ur = f"{t['useful_ratio']:.2f}" if t["useful_ratio"] else "n/a"
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {mesh_tag:10s} "
            f"{r['step']:7s} {t['compute_s']:10.4f} {t['memory_s']:10.4f} "
            f"{t['collective_s']:10.4f} {t['dominant']:>7s} {ur:>7s} "
            f"{100*t['roofline_fraction']:6.1f}%")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    print(table(sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"))
