"""Production mesh construction (DESIGN.md §5).

Kept as functions (not module constants) so importing never touches jax
device state — the dry-run must set XLA_FLAGS before any jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips as (data, model).
    Multi-pod: 2 pods x 16 x 16 = 512 chips as (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small host-device mesh for CI-scale sharding tests."""
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple:
    """Axes that carry the batch (all but 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")
