"""Dry-run sweep driver: every (arch x shape) cell on the single-pod and
multi-pod meshes, each in a fresh subprocess (jax device count is locked at
first init).  Results -> artifacts/dryrun/*.json; skips recorded too.

  python -m repro.launch.sweep [--only arch] [--mesh single|multi|both]
                               [--jobs N] [--timeout S]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import cells

ART = "artifacts/dryrun"


def cell_path(arch: str, shape: str, mesh: str) -> str:
    return os.path.join(ART, f"{arch}__{shape}__{mesh}.json")


def run_one(arch: str, shape: str, mesh: str, timeout: int) -> str:
    out = cell_path(arch, shape, mesh)
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--out", out]
    if mesh == "multi":
        cmd.append("--multi-pod")
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    t0 = time.time()
    try:
        p = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=timeout, env=env)
    except subprocess.TimeoutExpired:
        return f"TIMEOUT after {timeout}s"
    if p.returncode != 0:
        tail = "\n".join(p.stderr.strip().splitlines()[-15:])
        return f"FAIL ({time.time()-t0:.0f}s):\n{tail}"
    return f"ok ({time.time()-t0:.0f}s)"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--timeout", type=int, default=3000)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    os.makedirs(ART, exist_ok=True)
    todo = []
    for arch, shape, status in cells(include_skips=True):
        if args.only and arch != args.only:
            continue
        if args.shape and shape != args.shape:
            continue
        if status == "skip":
            with open(cell_path(arch, shape, "skipped"), "w") as f:
                json.dump({"arch": arch, "shape": shape, "status": "skip",
                           "reason": "full-attention arch at 500k context "
                                     "(DESIGN.md §4)"}, f)
            continue
        for mesh in meshes:
            if not args.force and os.path.exists(cell_path(arch, shape, mesh)):
                continue
            todo.append((arch, shape, mesh))
    print(f"{len(todo)} cells to run")
    failures = 0
    for i, (arch, shape, mesh) in enumerate(todo):
        msg = run_one(arch, shape, mesh, args.timeout)
        print(f"[{i+1}/{len(todo)}] {arch} x {shape} x {mesh}: {msg}",
              flush=True)
        if not msg.startswith("ok"):
            failures += 1
    print(f"done; {failures} failures")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
