"""Serving launcher CLI.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-27b-smoke
      --batch 4 --prompt-len 16 --new 32 [--temperature 0.7]
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.models.api import build_model
from repro.serve.engine import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    bundle = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = bundle.init_params(key)
    prompts = jax.random.randint(
        jax.random.fold_in(key, 1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)
    extra = {}
    if cfg.family == "vlm":
        extra["patches"] = jax.random.normal(
            jax.random.fold_in(key, 2),
            (args.batch, cfg.n_prefix, cfg.d_model))
    toks, stats = generate(bundle, params, prompts, args.new,
                           temperature=args.temperature, key=key,
                           extra_inputs=extra)
    print(f"{cfg.name}: {toks.shape} tokens — prefill "
          f"{stats.prefill_s*1e3:.1f} ms, decode {stats.decode_s*1e3:.1f} ms"
          f" ({stats.tokens_per_s:.1f} tok/s)")


if __name__ == "__main__":
    main()
