"""Serving launcher CLI.

One-shot static batching (LM/VLM families):

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-27b-smoke
      --batch 4 --prompt-len 16 --new 32 [--temperature 0.7]

Continuous batching (LM families and the paper's RNN-T CRDNN):

  PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b-smoke
      --engine slots --requests 16 --n-slots 4 --new 32
  PYTHONPATH=src python -m repro.launch.serve --arch rnnt-crdnn-smoke
      --engine slots --requests 8 --prompt-len 48
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models.api import build_model
from repro.serve.engine import Request, SlotEngine, generate


def _oneshot(args, cfg, bundle, params, key):
    prompts = jax.random.randint(
        jax.random.fold_in(key, 1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)
    extra = {}
    if cfg.family == "vlm":
        extra["patches"] = jax.random.normal(
            jax.random.fold_in(key, 2),
            (args.batch, cfg.n_prefix, cfg.d_model))
    toks, stats = generate(bundle, params, prompts, args.new,
                           temperature=args.temperature, key=key,
                           extra_inputs=extra)
    print(f"{cfg.name}: {toks.shape} tokens — prefill "
          f"{stats.prefill_s*1e3:.1f} ms "
          f"({stats.prompt_tokens}+{stats.prefill_tokens} tok), decode "
          f"{stats.decode_s*1e3:.1f} ms / {stats.decode_steps} steps "
          f"({stats.decode_tokens} live tok, {stats.tokens_per_s:.1f} tok/s)")


def _slots(args, cfg, bundle, params, key):
    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        L = int(rng.integers(args.prompt_len // 2, args.prompt_len + 1))
        if cfg.family == "rnnt":
            inputs = {"feats": rng.normal(
                size=(L, cfg.rnnt.n_feats)).astype(np.float32)}
        else:
            inputs = {"tokens": rng.integers(
                0, cfg.vocab_size, (L,)).astype(np.int32)}
        reqs.append(Request(uid=i, inputs=inputs, max_new_tokens=args.new))
    eng = SlotEngine(bundle, params, n_slots=args.n_slots,
                     max_new_tokens=args.new,
                     max_prompt_len=args.prompt_len,
                     temperature=args.temperature, eos_id=args.eos_id,
                     sync_every=args.sync_every, seed=args.seed)
    import time
    t0 = time.time()
    comps = eng.run(reqs)
    wall = time.time() - t0
    lat = sorted(c.latency_s for c in comps)
    n_tok = sum(len(c.tokens) for c in comps)
    print(f"{cfg.name}: {len(comps)} requests / {eng.n_slots} slots — "
          f"{wall*1e3:.0f} ms wall, {len(comps)/wall:.1f} req/s, "
          f"{n_tok} tokens, p50 latency {lat[len(lat)//2]*1e3:.0f} ms, "
          f"{eng.n_decode_dispatches} decode dispatches")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--engine", choices=("oneshot", "slots"),
                    default="oneshot")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--sync-every", type=int, default=4)
    ap.add_argument("--eos-id", type=int, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    bundle = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = bundle.init_params(key)
    if args.engine == "slots" or cfg.family == "rnnt":
        _slots(args, cfg, bundle, params, key)
    else:
        _oneshot(args, cfg, bundle, params, key)


if __name__ == "__main__":
    main()
