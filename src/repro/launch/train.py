"""Training launcher: mesh + sharding policy around Algorithm 1.

  PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b-smoke
      --method pgm --epochs 6 [--engine scan|host] [--mesh 2x4]
      [--mesh-axes data,pod --compress-mode bf16|topk]
      [--epoch-chunk 4] [--resident-selection] [--ckpt DIR] [--resume]
      [--noise 0.2 --snr-db 5]

``launch_train`` is the programmatic entry point the examples and
benchmarks share.  With ``--mesh DATAxMODEL`` the *whole* training run
is mesh-native (DESIGN.md §5): the scanned epoch engine device_puts the
selection units sharded over ``data``, compiles the epoch scan with
FSDP/TP param shardings from ``sharding/specs.py`` and data-sharded
batches, and PGM selection (stage A GSPMD, stage B
``pgm_select_sharded``) reuses the same sharded unit buffers — one code
path on 1 and N devices.  ``--epoch-chunk N`` folds N bucketed epochs
into one dispatch with on-device validation/newbob, and plan prefetch
overlaps host-side plan generation with the running chunk.  On CPU
without a mesh it runs the smoke-scale loop for development and CI.

``--noise``/``--snr-db`` inject the paper's robustness setting into the
synthetic corpora: a ``noise`` fraction of training utterances gets
additive feature noise at ``snr_db`` (ASR) or corrupted labels (LM),
and validation matching (``Val=True``) turns on automatically so PGM
selects against the clean validation gradient.
"""
from __future__ import annotations

import argparse
from typing import Optional

import jax

from repro.configs import get_config
from repro.configs.base import PGMConfig, TrainConfig
from repro.data.pipeline import asr_units, lm_units
from repro.data.synthetic import make_asr_corpus, make_lm_corpus
from repro.models.api import build_model
from repro.train.loop import History, train_with_selection


def parse_mesh(spec: Optional[str], axes: str = "data,model"):
    """'2x4' -> a 2-axis mesh; None/'' -> no mesh (single device).

    ``axes`` names the two mesh axes (comma-separated).  The default
    ``data,model`` is the GSPMD FSDP/TP training mesh; ``data,pod``
    builds the two-level mesh whose slow ``pod`` axis carries the
    explicit compressed gradient collective (``--compress-mode``,
    DESIGN.md §5)."""
    if not spec:
        return None
    dims = tuple(int(x) for x in spec.lower().split("x"))
    names = tuple(a.strip() for a in axes.split(","))
    if len(dims) != 2 or len(names) != 2:
        raise ValueError(f"mesh spec must be AxB over two named axes, "
                         f"got {spec!r} over {axes!r}")
    return jax.make_mesh(dims, names)


def make_units_for(cfg, *, n: int, seq: int, noise: float, seed: int = 0,
                   unit_size: int = 4, snr_db: float = 10.0):
    """(train units, val units) for the arch family — RNN-T gets the ASR
    corpus, everything else the LM corpus.  ``noise`` corrupts that
    fraction of *training* examples (additive feature noise at
    ``snr_db`` for ASR, label corruption for LM); validation stays
    clean, as in the paper's robustness setting."""
    if cfg.family == "rnnt":
        r = cfg.rnnt
        corpus = make_asr_corpus(seed, n, n_feats=r.n_feats,
                                 vocab_size=r.vocab_size,
                                 noise_fraction=noise, snr_db=snr_db)
        vc = make_asr_corpus(seed + 7, max(n // 4, 8), n_feats=r.n_feats,
                             vocab_size=r.vocab_size)
        return asr_units(corpus, unit_size), asr_units(vc, unit_size)
    corpus = make_lm_corpus(seed, n, seq, cfg.vocab_size,
                            noise_fraction=noise)
    vc = make_lm_corpus(seed + 7, max(n // 4, 8), seq, cfg.vocab_size)
    return lm_units(corpus, unit_size), lm_units(vc, unit_size)


def launch_train(
    arch: str,
    tc: TrainConfig,
    *,
    method: str = "pgm",
    engine: str = "scan",
    resident_selection: bool = False,
    mesh=None,
    data_axis: str = "data",
    spec_mode: str = "tp",
    epoch_chunk: int = 1,
    plan_prefetch: bool = True,
    n: int = 96,
    seq: int = 24,
    noise: float = 0.0,
    snr_db: float = 10.0,
    batch_units: int = 1,
    loss_impl: Optional[str] = None,
    ckpt_dir: Optional[str] = None,
    resume: bool = False,
    log_fn=print,
) -> History:
    cfg = get_config(arch)
    if loss_impl is not None and cfg.family == "rnnt":
        import dataclasses
        cfg = dataclasses.replace(
            cfg, rnnt=dataclasses.replace(cfg.rnnt, loss_impl=loss_impl))
    bundle = build_model(cfg)
    units, val = make_units_for(cfg, n=n, seq=seq, noise=noise,
                                seed=tc.seed, snr_db=snr_db)
    # unit placement (data-sharded on a mesh) is owned by the engine
    return train_with_selection(
        bundle, units, tc, method=method, val_units=val,
        batch_units=batch_units, ckpt_dir=ckpt_dir, resume=resume,
        engine=engine, resident_selection=resident_selection, mesh=mesh,
        data_axis=data_axis, spec_mode=spec_mode, epoch_chunk=epoch_chunk,
        plan_prefetch=plan_prefetch, log_fn=log_fn)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--method", default="pgm")
    ap.add_argument("--engine", default="scan", choices=["scan", "host"])
    ap.add_argument("--resident-selection", action="store_true",
                    help="PGM stage A as one jitted batch-scanned pass "
                         "over the device-resident units (no host "
                         "round-trip per selection round)")
    ap.add_argument("--mesh", default=None,
                    help="DATAxMODEL, e.g. 2x4 (default: no mesh); shards "
                         "the epoch engine, units and selection")
    ap.add_argument("--mesh-axes", default="data,model",
                    help="names of the two mesh axes; 'data,pod' builds "
                         "the two-level data x pod mesh whose slow axis "
                         "runs the explicit compressed gradient "
                         "collective (DESIGN.md §5)")
    ap.add_argument("--compress-mode", default="none",
                    choices=["none", "bf16", "topk"],
                    help="cross-pod gradient compressor on the 'pod' "
                         "mesh axis (train/compress.py): bf16 halves "
                         "the collective's wire width, topk sends the "
                         "k largest entries per leaf with error "
                         "feedback; requires --mesh-axes data,pod")
    ap.add_argument("--compress-k-frac", type=float, default=0.05,
                    help="top-k fraction per gradient leaf for "
                         "--compress-mode topk")
    ap.add_argument("--spec-mode", default="tp",
                    choices=["tp", "fsdp_sp", "fsdp_batch"],
                    help="SpecBuilder param-sharding policy for the "
                         "training carry (DESIGN.md §5)")
    ap.add_argument("--epoch-chunk", type=int, default=1,
                    help="fold up to N epochs into one scan dispatch "
                         "(on-device validation/newbob; metrics fetched "
                         "once per chunk)")
    ap.add_argument("--no-plan-prefetch", action="store_true",
                    help="build epoch plans synchronously instead of on "
                         "the prefetch thread")
    ap.add_argument("--subset", type=float, default=0.3)
    ap.add_argument("--partitions", type=int, default=4)
    ap.add_argument("--select-every", type=int, default=5)
    ap.add_argument("--warm-start", type=int, default=2)
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--n", type=int, default=96)
    ap.add_argument("--seq", type=int, default=24)
    ap.add_argument("--lr", type=float, default=0.5)
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--noise", type=float, default=0.0,
                    help="fraction of corrupted training examples "
                         "(feature noise for ASR, label noise for LM)")
    ap.add_argument("--snr-db", type=float, default=10.0,
                    help="SNR of the injected ASR feature noise (dB); "
                         "only meaningful with --noise > 0 on an RNN-T "
                         "arch")
    ap.add_argument("--loss-impl", default=None,
                    choices=["fused", "dense"],
                    help="RNN-T loss path (DESIGN.md §2): fused "
                         "custom_vjp lattice (default) or the dense "
                         "autodiff parity oracle")
    ap.add_argument("--exact-gradients", action="store_true",
                    help="paper-faithful exact last-layer gradients "
                         "(no sketching)")
    ap.add_argument("--selection-kernels", default="auto",
                    choices=["auto", "pallas", "xla"],
                    help="selection-round kernel backend "
                         "(PGMConfig.kernel_impl): fused Pallas "
                         "grad-sketch + Gram kernels vs the XLA "
                         "streamed paths; auto = pallas on TPU only")
    ap.add_argument("--nonfinite-guard", action="store_true",
                    help="gate NaN/Inf steps off inside the jitted step "
                         "(bit-exact no-op, no host sync) and count them "
                         "in the epoch metrics (DESIGN.md §10)")
    ap.add_argument("--max-skipped-steps", type=int, default=0,
                    help="divergence watchdog: this many *consecutive* "
                         "guarded-off steps triggers a rollback to the "
                         "last good checkpoint with a re-keyed batch "
                         "plan (0 = never; requires --nonfinite-guard)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    tc = TrainConfig(
        lr=args.lr, optimizer=args.optimizer, epochs=args.epochs,
        seed=args.seed,
        compress_mode=args.compress_mode,
        compress_k_frac=args.compress_k_frac,
        nonfinite_guard=args.nonfinite_guard,
        max_skipped_steps=args.max_skipped_steps,
        pgm=PGMConfig(subset_fraction=args.subset,
                      n_partitions=args.partitions,
                      select_every=args.select_every,
                      warm_start_epochs=args.warm_start,
                      val_matching=args.noise > 0,
                      use_sketch=not args.exact_gradients,
                      kernel_impl=args.selection_kernels))
    h = launch_train(args.arch, tc, method=args.method, engine=args.engine,
                     resident_selection=args.resident_selection,
                     mesh=parse_mesh(args.mesh, args.mesh_axes),
                     spec_mode=args.spec_mode,
                     epoch_chunk=args.epoch_chunk,
                     plan_prefetch=not args.no_plan_prefetch,
                     n=args.n, seq=args.seq, noise=args.noise,
                     snr_db=args.snr_db, loss_impl=args.loss_impl,
                     ckpt_dir=args.ckpt, resume=args.resume)
    if h.val_loss:
        print(f"done: val {h.val_loss[-1]:.4f}, "
              f"cost {h.cost_units:.2f} epoch-units, "
              f"wall {h.wall_time:.1f}s on {jax.device_count()} device(s)")


if __name__ == "__main__":
    main()
