"""Training launcher CLI.

  PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b-smoke
      --method pgm --epochs 6 [--ckpt DIR] [--resume] [--noise 0.2]

On a real TPU slice the same entry point applies the production mesh and
the per-family sharding policy (``--mesh single|multi``); on CPU it runs
the smoke-scale loop (identity sharding) for development and CI.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.configs.base import PGMConfig, TrainConfig
from repro.data.pipeline import asr_units, lm_units
from repro.data.synthetic import make_asr_corpus, make_lm_corpus
from repro.models.api import build_model
from repro.train.loop import train_with_selection


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--method", default="pgm")
    ap.add_argument("--subset", type=float, default=0.3)
    ap.add_argument("--partitions", type=int, default=4)
    ap.add_argument("--select-every", type=int, default=5)
    ap.add_argument("--warm-start", type=int, default=2)
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--n", type=int, default=96)
    ap.add_argument("--seq", type=int, default=24)
    ap.add_argument("--lr", type=float, default=0.5)
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--noise", type=float, default=0.0)
    ap.add_argument("--exact-gradients", action="store_true",
                    help="paper-faithful exact last-layer gradients "
                         "(no sketching)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    bundle = build_model(cfg)
    if cfg.family == "rnnt":
        corpus = make_asr_corpus(args.seed, args.n,
                                 n_feats=cfg.rnnt.n_feats,
                                 vocab_size=cfg.rnnt.vocab_size,
                                 noise_fraction=args.noise)
        units = asr_units(corpus, 4)
        vc = make_asr_corpus(args.seed + 7, max(args.n // 4, 8),
                             n_feats=cfg.rnnt.n_feats,
                             vocab_size=cfg.rnnt.vocab_size)
        val = asr_units(vc, 4)
    else:
        corpus = make_lm_corpus(args.seed, args.n, args.seq, cfg.vocab_size,
                                noise_fraction=args.noise)
        units = lm_units(corpus, 4)
        val = lm_units(make_lm_corpus(args.seed + 7, max(args.n // 4, 8),
                                      args.seq, cfg.vocab_size), 4)

    tc = TrainConfig(
        lr=args.lr, optimizer=args.optimizer, epochs=args.epochs,
        seed=args.seed,
        pgm=PGMConfig(subset_fraction=args.subset,
                      n_partitions=args.partitions,
                      select_every=args.select_every,
                      warm_start_epochs=args.warm_start,
                      val_matching=args.noise > 0,
                      use_sketch=not args.exact_gradients))
    h = train_with_selection(bundle, units, tc, method=args.method,
                             val_units=val, ckpt_dir=args.ckpt,
                             resume=args.resume, log_fn=print)
    if h.val_loss:
        print(f"done: val {h.val_loss[-1]:.4f}, "
              f"cost {h.cost_units:.2f} epoch-units, "
              f"wall {h.wall_time:.1f}s on {jax.device_count()} device(s)")


if __name__ == "__main__":
    main()
