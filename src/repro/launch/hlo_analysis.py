"""Multiplicity-corrected analysis of optimized (post-SPMD) HLO text.

Why: ``compiled.cost_analysis()`` counts each while-loop body ONCE, but our
programs are scan-heavy (layer groups, microbatches, flash kv blocks) — a
32-layer scan underreports FLOPs by 32x.  This module parses the HLO text,
recovers loop trip counts from scan-style conditions, propagates a
multiplicity to every computation (while bodies, fusions, calls,
conditionals), and accumulates:

  * flops       — dots (2 * prod(out) * prod(contracted lhs dims)) and
                  convolutions (2 * prod(out) * window * Cin / groups);
  * bytes       — per *non-fused* op: output + resolved operand bytes
                  (fusion internals are VMEM-resident and excluded; the
                  fusion op itself counts its inputs/outputs) — a
                  roofline-grade HBM-traffic estimate;
  * collectives — count / buffer bytes / per-chip wire bytes (ring models,
                  see dryrun.parse_collectives) at loop multiplicity.

Everything is per device: SPMD-partitioned HLO is the single-device
program.  Validated in tests/test_hlo_analysis.py against hand-computed
scan/matmul examples.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_shape_re = re.compile(
    r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|"
    r"c64|c128)\[([0-9,]*)\]")
_def_re = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_op_re = re.compile(r"^\s*(?:\(([^()]*(?:\([^()]*\)[^()]*)*)\)|([\w\[\],{}: ]+?))\s*([\w\-]+)\(")
_comp_start_re = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")


def _tensor_bytes(type_str: str) -> int:
    total = 0
    for m in _shape_re.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _dims(type_str: str) -> List[int]:
    m = _shape_re.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    kind: str
    out_type: str
    line: str
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    ops: Dict[str, Op] = field(default_factory=dict)
    order: List[str] = field(default_factory=list)
    params: Dict[str, str] = field(default_factory=dict)


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = re.sub(r"/\*.*?\*/", "", raw.rstrip())
        stripped = line.strip()
        if not stripped:
            continue
        if stripped.endswith("{") and "->" in stripped:
            head = stripped.split("(")[0].strip()
            if head and " = " not in stripped.split("->")[0].rsplit(
                    "(", 1)[0]:
                name = head.split()[-1].lstrip("%")
                if re.fullmatch(r"[\w.\-]+", name):
                    cur = Computation(name)
                    comps[cur.name] = cur
                    continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        dm = _def_re.match(stripped)
        if not dm:
            continue
        name, rhs = dm.group(1), dm.group(2)
        # rhs: "<type> <op>(<operands>), attrs..."  (comments pre-stripped;
        # tuple types have no nested parens)
        om = re.match(r"^((?:\([^()]*\))|(?:[\w\[\],{} ]+?))\s+([\w\-]+)\(",
                      rhs)
        if not om:
            continue
        out_type, kind = om.group(1), om.group(2)
        # operand names: %refs inside the first (...) after the op kind
        after = rhs[om.end():]
        depth = 1
        args = []
        buf = ""
        for ch in after:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args.append(buf)
                    break
            if depth >= 1 and ch != "(" or depth > 1:
                buf += ch
        operand_names = re.findall(r"%([\w.\-]+)", args[0] if args else "")
        op = Op(name, kind, out_type, stripped, operand_names)
        cur.ops[name] = op
        cur.order.append(name)
        if kind == "parameter":
            cur.params[name] = out_type
    return comps


def _trip_count(cond: Computation) -> int:
    """Scan-style condition: the loop bound appears as an integer constant
    in the condition region (the compare itself may live in a wrapped
    fusion with the constant passed as a parameter, so we take the max
    integer constant in the region — scan conds contain only the bound)."""
    best = 1
    for op in cond.ops.values():
        if op.kind == "constant":
            m = re.search(r"constant\((\d+)\)", op.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _called_comps(op: Op) -> List[str]:
    names = []
    for attr in ("calls", "to_apply", "body", "condition"):
        for m in re.finditer(attr + r"=%?([\w.\-]+)", op.line):
            names.append(m.group(1))
    m = re.search(r"branch_computations=\{([^}]*)\}", op.line)
    if m:
        names += re.findall(r"%?([\w.\-]+)", m.group(1))
    return names


def _dot_flops(op: Op, comp: Computation) -> float:
    out = _dims(op.out_type)
    lhs_type = _operand_type(op, 0, comp)
    lhs = _dims(lhs_type) if lhs_type else []
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    contract = 1
    if m and lhs:
        for d in m.group(1).split(","):
            if d:
                contract *= lhs[int(d)]
    n_out = 1
    for d in out:
        n_out *= d
    return 2.0 * n_out * contract


def _conv_flops(op: Op, comp: Computation) -> float:
    out = _dims(op.out_type)
    rhs_type = _operand_type(op, 1, comp)
    rhs = _dims(rhs_type) if rhs_type else []
    n_out = 1
    for d in out:
        n_out *= d
    # kernel = spatial dims * input channels (HWIO: all but last dim)
    kernel = 1
    for d in rhs[:-1]:
        kernel *= d
    m = re.search(r"feature_group_count=(\d+)", op.line)
    groups = int(m.group(1)) if m else 1
    return 2.0 * n_out * kernel / max(groups, 1)


def _operand_type(op: Op, idx: int, comp: Computation) -> Optional[str]:
    if idx >= len(op.operands):
        return None
    name = op.operands[idx]
    if name in comp.ops:
        return comp.ops[name].out_type
    return None


def _group_size(line: str, default: int = 1) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len(m.group(1).split(","))
    return default


_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "copy-done", "all-reduce-done", "all-gather-done",
               "custom-call", "after-all", "partition-id", "replica-id"}


@dataclass
class Analysis:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: Dict[str, Dict[str, float]] = field(default_factory=dict)

    @property
    def wire_bytes(self) -> float:
        return sum(c["wire_bytes"] for c in self.collectives.values())


def analyze(text: str, entry: Optional[str] = None) -> Analysis:
    comps = parse_hlo(text)
    if not comps:
        return Analysis()
    if entry is None:
        # entry = computation never called by others
        called = set()
        for c in comps.values():
            for op in c.ops.values():
                called.update(_called_comps(op))
        entries = [n for n in comps if n not in called]
        entry = entries[-1] if entries else next(iter(comps))

    mult: Dict[str, float] = {}

    def visit(name: str, m: float):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        comp = comps[name]
        for op in comp.ops.values():
            if op.kind == "while":
                body = cond = None
                bm = re.search(r"body=%?([\w.\-]+)", op.line)
                cm = re.search(r"condition=%?([\w.\-]+)", op.line)
                if bm:
                    body = bm.group(1)
                if cm:
                    cond = cm.group(1)
                trips = _trip_count(comps[cond]) if cond in comps else 1
                if body:
                    visit(body, m * trips)
                if cond:
                    visit(cond, m * (trips + 1))
            else:
                for c in _called_comps(op):
                    visit(c, m)

    visit(entry, 1.0)

    res = Analysis(collectives={c: {"count": 0, "bytes": 0.0,
                                    "wire_bytes": 0.0} for c in COLLECTIVES})
    fused_names = {n for n, c in comps.items()
                   if n.startswith("fused_") or ".fused" in n}
    for cname, m in mult.items():
        comp = comps[cname]
        in_fusion = cname in fused_names
        for op in comp.ops.values():
            if op.kind == "dot":
                res.flops += m * _dot_flops(op, comp)
            elif op.kind == "convolution":
                res.flops += m * _conv_flops(op, comp)
            base = next((c for c in COLLECTIVES
                         if op.kind == c or op.kind == c + "-start"), None)
            if base is not None:
                nbytes = _tensor_bytes(op.out_type)
                g = _group_size(op.line)
                if base == "all-reduce":
                    wire = 2.0 * nbytes * (g - 1) / max(g, 1)
                elif base in ("all-gather", "all-to-all"):
                    wire = nbytes * (g - 1) / max(g, 1)
                elif base == "reduce-scatter":
                    wire = nbytes * (g - 1)
                else:
                    wire = nbytes
                c = res.collectives[base]
                c["count"] += m
                c["bytes"] += m * nbytes
                c["wire_bytes"] += m * wire
            # HBM-traffic estimate: outputs + operands of non-fused ops.
            # Slice-consumed operands count at slice size, not buffer size
            # (a scan's stacked residuals are read one step per iteration —
            # counting the full stack per step overcounts by trip_count).
            if not in_fusion and op.kind not in _SKIP_BYTES:
                b = _tensor_bytes(op.out_type)
                if op.kind in ("dynamic-slice", "gather"):
                    b *= 2.0          # read slice + write output
                else:
                    slice_params = _slice_only_params(op, comps)
                    for i in range(len(op.operands)):
                        t = _operand_type(op, i, comp)
                        if not t:
                            continue
                        ob = _tensor_bytes(t)
                        if i in slice_params:
                            ob = min(ob, slice_params[i])
                        b += ob
                res.bytes += m * b
    return res


def _slice_only_params(op: Op, comps: Dict[str, Computation]
                       ) -> Dict[int, float]:
    """For a fusion op: {operand index: slice bytes} for parameters whose
    only consumers inside the fused computation are dynamic-slice/gather."""
    if op.kind != "fusion":
        return {}
    called = [c for c in _called_comps(op) if c in comps]
    if not called:
        return {}
    fc = comps[called[0]]
    idx_to_name = {}
    for o in fc.ops.values():
        if o.kind == "parameter":
            mm = re.search(r"parameter\((\d+)\)", o.line)
            if mm:
                idx_to_name[int(mm.group(1))] = o.name
    out = {}
    for idx, pname in idx_to_name.items():
        consumers = [o for o in fc.ops.values() if pname in o.operands]
        if consumers and all(o.kind in ("dynamic-slice", "gather")
                             for o in consumers):
            out[idx] = sum(_tensor_bytes(o.out_type) for o in consumers)
    return out
