"""Multi-pod dry-run: prove the distribution config is coherent without
hardware.  For every (arch x shape x mesh) cell this lowers + compiles the
real train/prefill/decode step against ShapeDtypeStruct inputs (no
allocation), prints memory/cost analysis, and records the collective
schedule for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k
  python -m repro.launch.dryrun --arch rwkv6-3b --shape long_500k --multi-pod
  python -m repro.launch.dryrun --list
"""
# The next two lines MUST run before any other import (jax locks the device
# count on first init).  REPRO_DRYRUN_DEVICES overrides for small CI runs.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512"))

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
from typing import Dict, Optional      # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp                # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P   # noqa: E402

from repro.configs import SHAPES, cells, get_config, get_shape   # noqa: E402
from repro.launch.mesh import make_production_mesh               # noqa: E402
from repro.models.api import build_model                         # noqa: E402
from repro.sharding.specs import MeshSharder, SpecBuilder        # noqa: E402
from repro.train.optim import adamw_init, adamw_update, clip_by_global_norm  # noqa: E402

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _sds(tree, shardings):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, shardings)


def auto_microbatches(arch: str, shape, mesh) -> int:
    """Split the per-device token budget so stored inter-layer activations
    (bf16 carries saved for the remat backward) stay within ~2 GB/device
    (perf iteration 2, EXPERIMENTS.md §Perf)."""
    cfg = get_config(arch)
    n_data = int(np.prod([v for k, v in mesh.shape.items() if k != "model"]))
    tokens_per_dev = shape.global_batch * shape.seq_len / max(n_data, 1)
    carry_bytes = tokens_per_dev * cfg.d_model * 2 * cfg.n_layers
    # 6 GB activation-carry budget: fewer microbatches = fewer per-microbatch
    # FSDP param regathers (perf iteration 2b, EXPERIMENTS.md §Perf)
    m = max(int(np.ceil(carry_bytes / 6e9)), 1)
    # keep the microbatch count a divisor of the per-device batch
    b_per_dev = max(shape.global_batch // max(n_data, 1), 1)
    while b_per_dev % m:
        m += 1
    return min(m, b_per_dev)


def train_policy(cfg, shape, mesh) -> str:
    """Sharding policy per (family, step) — DESIGN.md §5 / §Perf iter 4:
      * MoE training keeps 'tp' (expert parallelism over 'model');
      * recurrent archs (rwkv/rg-lru) cannot shard the sequence ->
        'fsdp_batch' when the batch covers every device, else 'tp';
      * dense-attention training uses 'fsdp_sp' (batch over data axes,
        sequence over 'model', fully-FSDP params: no TP all-reduces)."""
    total = int(np.prod(list(mesh.shape.values())))
    if cfg.moe is not None or cfg.family == "rnnt":
        return "tp"
    kinds = set(cfg.layer_kinds())
    if kinds & {"rec", "rwkv"}:
        return "fsdp_batch" if shape.global_batch % total == 0 else "tp"
    return "fsdp_sp"


def build_step(arch: str, shape_name: str, mesh, step: Optional[str] = None,
               microbatches: Optional[int] = None,
               policy: Optional[str] = None):
    """Returns (fn, example_args as sharded ShapeDtypeStructs)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    bundle = build_model(cfg)
    step = step or shape.kind
    if policy is None:
        if step in ("train", "select"):
            policy = train_policy(cfg, shape, mesh)
        elif step == "prefill" and cfg.moe is None and not (
                set(cfg.layer_kinds()) & {"rec", "rwkv"}):
            # prefill is throughput-oriented forward-only work: sequence
            # sharding beats TP for it just as in training (§Perf iter 6);
            # recurrent archs keep TP (sequence cannot shard)
            policy = "fsdp_sp"
        else:
            policy = "tp"
    sb = SpecBuilder(mesh, mode=policy)
    sharder = MeshSharder(mesh, mode=policy)

    params_shapes = jax.eval_shape(bundle.init_params, jax.random.PRNGKey(0))
    params_sh = sb.to_shardings(sb.param_specs(params_shapes))
    params_sds = _sds(params_shapes, params_sh)

    batch_shapes = bundle.input_specs(shape)
    batch_sh = sb.to_shardings(sb.batch_specs(batch_shapes))
    batch_sds = _sds(batch_shapes, batch_sh)

    if step == "train":
        opt_shapes = jax.eval_shape(adamw_init, params_shapes)
        opt_sh = sb.to_shardings(sb.param_specs(opt_shapes))
        opt_sds = _sds(opt_shapes, opt_sh)
        # fsdp policies shard tokens over (nearly) all devices: the stored
        # activation carry is tiny, no microbatching needed
        if microbatches is None:
            mb = (auto_microbatches(arch, shape, mesh)
                  if policy == "tp" else 1)
        else:
            mb = microbatches

        def grads_of(params, batch):
            def loss(p):
                total, metrics = bundle.loss_fn(p, batch, shard=sharder)
                return total, metrics
            (_, metrics), grads = jax.value_and_grad(
                loss, has_aux=True)(params)
            return grads, metrics

        def cast_working(params):
            """bf16 working copy, cast shard-local BEFORE any FSDP gather
            (halves param-gather wire; grads come back bf16 -> bf16
            gradient reduction; optimizer applies them to fp32 masters).
            Perf iteration 5, EXPERIMENTS.md §Perf."""
            dt = jnp.dtype(get_config(arch).compute_dtype)
            if dt == jnp.float32:
                return params
            return jax.tree.map(
                lambda p, s: jax.lax.with_sharding_constraint(
                    p.astype(dt) if p.dtype == jnp.float32 else p, s),
                params, params_sh)

        def train_step(params, opt_state, batch):
            working = cast_working(params)
            if mb <= 1:
                grads, metrics = grads_of(working, batch)
                # pin grads to the param sharding: XLA emits reduce-scatter
                # into FSDP shards instead of a full all-reduce (§Perf)
                grads = jax.tree.map(
                    lambda g, s: jax.lax.with_sharding_constraint(
                        g.astype(jnp.float32), s),
                    grads, params_sh)
            else:
                # gradient accumulation: activation live-set shrinks by mb,
                # gradient all-reduce happens once on the accumulated sum
                micro = jax.tree.map(
                    lambda a: a.reshape((mb, a.shape[0] // mb) + a.shape[1:]),
                    batch)

                def acc_step(carry, mbatch):
                    g_acc = carry
                    g, metrics = grads_of(working, mbatch)
                    g_acc = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                    return g_acc, metrics

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                grads, metrics_all = jax.lax.scan(acc_step, g0, micro)
                grads = jax.tree.map(lambda g: g / mb, grads)
                metrics = jax.tree.map(lambda m: m.mean(), metrics_all)
            grads, gnorm = clip_by_global_norm(grads, 1.0)
            params, opt_state = adamw_update(params, grads, opt_state,
                                             lr=1e-4)
            return params, opt_state, dict(metrics, grad_norm=gnorm)

        fn = jax.jit(train_step, out_shardings=(params_sh, opt_sh, None),
                     donate_argnums=(0, 1))
        return fn, (params_sds, opt_sds, batch_sds)

    if step == "prefill":
        def prefill_step(params, batch):
            return bundle.prefill(params, batch, shard=sharder)
        fn = jax.jit(prefill_step)
        return fn, (params_sds, batch_sds)

    if step == "select":
        # the paper's selection round (stage A sketching + stage B
        # partitioned OMP) over one candidate chunk of `global_batch`
        # units of `unit_size` examples each
        from repro.core.lastlayer import units_gradients_batched
        from repro.core.pgm import partitioned_gm
        from repro.core.sketch import Projections
        unit_size = 4
        n_units = shape.global_batch
        D_parts = 16
        budget = max(int(0.3 * n_units) // D_parts, 1)
        k1 = k2 = 64

        unit_specs = {
            k: jax.ShapeDtypeStruct((n_units,) + v.shape, v.dtype)
            for k, v in bundle.input_specs(
                type(shape)(shape.name, shape.seq_len, unit_size,
                            "train")).items()}
        units_sh = sb.to_shardings(sb.batch_specs(unit_specs))
        units_sds = _sds(unit_specs, units_sh)
        proj_specs = (jax.ShapeDtypeStruct((cfg.d_model, k1), jnp.float32),
                      jax.ShapeDtypeStruct((cfg.vocab_size, k2),
                                           jnp.float32))
        psh = sb.to_shardings((sb.param_spec(".proj_h", proj_specs[0].shape),
                               sb.param_spec(".proj_v", proj_specs[1].shape)))
        proj_sds = tuple(_sds(s, h) for s, h in zip(proj_specs, psh))

        def select_step(params, units, r_h, r_v):
            g = units_gradients_batched(bundle, params, units,
                                        Projections(r_h, r_v),
                                        shard=sharder)
            return partitioned_gm(g, D_parts, budget)

        fn = jax.jit(select_step)
        return fn, (params_sds, units_sds) + proj_sds

    if step == "decode":
        B = shape.global_batch
        cache_shapes = jax.eval_shape(
            lambda: bundle.init_cache(B, shape.seq_len))
        cache_sh = sb.to_shardings(sb.cache_specs(cache_shapes, B))
        cache_sds = _sds(cache_shapes, cache_sh)

        def decode_step(params, cache, tokens):
            return bundle.decode(params, cache, tokens, shard=sharder)

        fn = jax.jit(decode_step, donate_argnums=(1,),
                     out_shardings=(None, cache_sh))
        return fn, (params_sds, cache_sds, batch_sds["tokens"])

    raise ValueError(step)


# ---------------------------------------------------------------------------
# Compiled-artifact analysis
# ---------------------------------------------------------------------------

def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum output bytes + estimated per-chip wire bytes for each collective
    op in the (post-SPMD) optimized HLO.  Wire-byte model per op:
      all-reduce      2*size*(g-1)/g      (ring AR, size = buffer bytes)
      all-gather      size*(g-1)/g        (size = output bytes)
      reduce-scatter  size*(g-1)         ~= input traffic, size = out bytes
      all-to-all      size*(g-1)/g
      collective-permute  size
    """
    out: Dict[str, Dict[str, float]] = {
        c: {"count": 0, "bytes": 0.0, "wire_bytes": 0.0} for c in COLLECTIVES}
    shape_re = re.compile(r"=\s*(?:\(([^)]*)\)|((?:f|bf|s|u|pred)[\w]*\[[^\]]*\]))\s*([\w-]+)")
    tensor_re = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")
    group_re = re.compile(r"replica_groups=\{\{([^}]*)\}")
    iota_re = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = shape_re.search(stripped)
        if not m:
            continue
        op = m.group(3)
        base = None
        for c in COLLECTIVES:
            if op == c or op.startswith(c + "-") or op == c + "-start":
                base = c
                break
        if base is None:
            continue
        if op.endswith("-done"):
            continue
        shapes_str = m.group(1) if m.group(1) else m.group(2)
        nbytes = 0.0
        for t in tensor_re.finditer(shapes_str):
            dt, dims = t.group(1), t.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES.get(dt, 4)
        g = 1
        gm = iota_re.search(stripped)
        if gm:
            g = int(gm.group(2))
        else:
            gm = group_re.search(stripped)
            if gm:
                g = len(gm.group(1).split(","))
        g = max(g, 1)
        if base == "all-reduce":
            wire = 2.0 * nbytes * (g - 1) / g
        elif base in ("all-gather", "all-to-all"):
            wire = nbytes * (g - 1) / g
        elif base == "reduce-scatter":
            wire = nbytes * (g - 1)
        else:
            wire = nbytes
        out[base]["count"] += 1
        out[base]["bytes"] += nbytes
        out[base]["wire_bytes"] += wire
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             step: Optional[str] = None, out_path: Optional[str] = None,
             verbose: bool = True, microbatches: Optional[int] = None,
             policy: Optional[str] = None) -> Dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    fn, args = build_step(arch, shape_name, mesh, step,
                          microbatches=microbatches, policy=policy)
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    try:
        ca = compiled.cost_analysis() or {}
    except Exception as e:   # backend may not support it
        ca = {"error": str(e)}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                ma, "generated_code_size_in_bytes", None),
        }
    except Exception as e:
        ma, mem = None, {"error": str(e)}

    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    # multiplicity-corrected per-device analysis (XLA cost_analysis counts
    # while bodies once; our programs are scan-heavy — see hlo_analysis.py)
    from repro.launch.hlo_analysis import analyze as hlo_analyze
    corrected = hlo_analyze(hlo)

    cfgx = get_config(arch)
    eff_policy = policy or (train_policy(
        cfgx, get_shape(shape_name),
        mesh) if (step or get_shape(shape_name).kind) == "train" else "tp")
    result = {
        "arch": arch,
        "shape": shape_name,
        "step": step or get_shape(shape_name).kind,
        "policy": eff_policy,
        "mesh": "multi_pod_2x16x16" if multi_pod else "single_pod_16x16",
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "flops": corrected.flops,
        "bytes_accessed": corrected.bytes,
        "flops_xla_raw": ca.get("flops"),
        "bytes_xla_raw": ca.get("bytes accessed"),
        "cost_analysis": {k: v for k, v in ca.items()
                          if isinstance(v, (int, float))},
        "memory": mem,
        "collectives": corrected.collectives,
        "collectives_raw_once": colls,
        "lower_s": t_lower,
        "compile_s": t_compile,
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {result['mesh']} "
              f"({result['step']}) OK — lower {t_lower:.1f}s, "
              f"compile {t_compile:.1f}s")
        print(f"  memory_analysis: {mem}")
        print(f"  flops={result['flops']}, bytes={result['bytes_accessed']}")
        tot_wire = sum(c["wire_bytes"] for c in colls.values())
        print("  collectives: " + ", ".join(
            f"{k}:{v['count']} ({v['bytes']/1e6:.1f} MB out, "
            f"{v['wire_bytes']/1e6:.1f} MB wire)"
            for k, v in colls.items() if v["count"]) +
            f" | total wire {tot_wire/1e6:.1f} MB")
    if out_path:
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--step", default=None,
                    help="train|prefill|decode (default: shape kind)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--policy", default=None,
                    choices=[None, "tp", "fsdp_sp", "fsdp_batch"])
    args = ap.parse_args()
    if args.list:
        for arch, shape, status in cells(include_skips=True):
            print(f"{arch:24s} {shape:12s} {status}")
        return
    run_cell(args.arch, args.shape, multi_pod=args.multi_pod, step=args.step,
             out_path=args.out, microbatches=args.microbatch,
             policy=args.policy)


if __name__ == "__main__":
    main()
