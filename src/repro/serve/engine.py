"""Batched serving engine: prefill + decode loops over a ModelBundle,
greedy or temperature sampling, simple continuous-batching simulation
(requests of different lengths padded into one prefill, decoded until
eos/budget)."""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ServeStats:
    prefill_s: float
    decode_s: float
    tokens_out: int

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / max(self.decode_s, 1e-9)


def sample_token(logits, key, temperature: float = 0.0):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def generate(
    bundle,
    params,
    prompts: jnp.ndarray,           # (B, S_prompt) int32
    max_new_tokens: int,
    *,
    temperature: float = 0.0,
    eos_id: Optional[int] = None,
    key=None,
    extra_inputs: Optional[Dict] = None,
):
    """Greedy/temperature batched generation.  Returns (tokens (B, T_new),
    stats)."""
    key = jax.random.PRNGKey(0) if key is None else key
    B, Sp = prompts.shape
    batch = dict(extra_inputs or {}, tokens=prompts)

    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, b: bundle.prefill(p, b, cache_len=Sp + max_new_tokens)
    )(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    decode = jax.jit(bundle.decode)
    out = []
    tok = sample_token(logits, key, temperature)
    out.append(tok)
    done = jnp.zeros((B,), bool) if eos_id is not None else None
    t0 = time.time()
    for i in range(max_new_tokens - 1):
        key = jax.random.fold_in(key, i)
        logits, cache = decode(params, cache, tok)
        tok = sample_token(logits, key, temperature)
        if eos_id is not None:
            done = done | (tok == eos_id)
            tok = jnp.where(done, eos_id, tok)
        out.append(tok)
        if eos_id is not None and bool(done.all()):
            break
    jax.block_until_ready(out[-1])
    t_decode = time.time() - t0
    tokens = jnp.stack(out, axis=1)
    return tokens, ServeStats(t_prefill, t_decode, int(tokens.size))
