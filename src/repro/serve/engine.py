"""Serving engines (DESIGN.md §4).

Two surfaces:

* :func:`generate` — one-shot batched prefill + decode for LM-family
  bundles.  Kept as the static-batching baseline (and the parity oracle
  for the slot engine), with honest accounting: ``ServeStats`` reports
  *live* (pre-eos) decode tokens with the prefill-sampled token
  attributed to prefill, ``done`` is seeded from that first sampled
  token (a batch that immediately emits eos decodes zero steps), and the
  host checks termination every ``sync_every`` steps instead of forcing
  a device→host round-trip per token.

* :class:`SlotEngine` — the continuous-batching engine.  A host-side
  request queue feeds a fixed pool of ``n_slots`` decode slots; one
  donated ``jit`` step (``lax.scan`` over ``sync_every`` micro-steps)
  advances *all* slots with per-slot KV/state caches in the carry — the
  engine-carry discipline of ``train/engine.py``.  Admit/evict happens
  between scans by writing a freshly prefilled request into a freed
  slot; prompts are right-padded to bucketed lengths (pad positions get
  position id -1, invalid under every attention mask rule) so prefill
  compiles once per bucket and the decode executable never retraces —
  the ``subset_epoch_plan`` pad/gate trick transferred to serving: dead
  slots still run the step but their state is selected back bit-exactly
  (like ``optim.gate_step``).

The slot engine serves two families behind one loop: decoder LMs
(per-slot KV cache, eos termination) and the paper's RNN-T CRDNN
(per-slot encoder buffer + prediction-network state; one *joint step*
per scan micro-step, blank advances the frame cursor — streaming greedy
transducer search, token-for-token equal to
:func:`rnnt_greedy_reference`).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ===========================================================================
# One-shot generate (static batching)
# ===========================================================================

@dataclasses.dataclass
class ServeStats:
    """Timing/throughput for one :func:`generate` call.

    ``decode_tokens`` counts only *live* tokens — sampled for an example
    that had not already emitted eos — so padded post-eos eos tokens
    never inflate tok/s.  The token sampled from the prefill logits is
    attributed to prefill (``prefill_tokens``), not to the decode phase.
    """

    prefill_s: float
    decode_s: float
    prompt_tokens: int        # prompt tokens processed by prefill (B * Sp)
    prefill_tokens: int       # tokens sampled from prefill logits (B)
    decode_tokens: int        # live (pre-eos) tokens emitted by decode steps
    decode_steps: int         # decode dispatches actually executed

    @property
    def tokens_per_s(self) -> float:
        """Decode-phase throughput over live decode tokens only."""
        return self.decode_tokens / max(self.decode_s, 1e-9)

    @property
    def prefill_tokens_per_s(self) -> float:
        return (self.prompt_tokens + self.prefill_tokens) \
            / max(self.prefill_s, 1e-9)


def sample_token(logits, key, temperature: float = 0.0):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def generate(
    bundle,
    params,
    prompts: jnp.ndarray,           # (B, S_prompt) int32
    max_new_tokens: int,
    *,
    temperature: float = 0.0,
    eos_id: Optional[int] = None,
    key=None,
    extra_inputs: Optional[Dict] = None,
    sync_every: int = 8,
):
    """Greedy/temperature batched generation.  Returns ``(tokens
    (B, T_new), stats)``.

    Termination is checked on the host every ``sync_every`` steps (the
    ``done`` mask stays on device in between), so up to
    ``sync_every - 1`` trailing all-eos columns may be returned after
    every example has finished — token values are unchanged vs a
    per-step check because finished examples are pinned to ``eos_id``
    (tests/test_serve_engine.py asserts exact equality).
    """
    if bundle.cfg.family == "rnnt":
        raise ValueError(
            "generate() is the LM one-shot path; RNN-T uses streaming "
            "greedy transducer search — SlotEngine or "
            "rnnt_greedy_reference")
    key = jax.random.PRNGKey(0) if key is None else key
    B, Sp = prompts.shape
    batch = dict(extra_inputs or {}, tokens=prompts)

    t0 = time.time()
    # jit on bundle.prefill itself (not a fresh lambda) so repeated
    # generate() calls hit the cached lowering instead of recompiling
    prefill = jax.jit(bundle.prefill, static_argnames=("cache_len",))
    logits, cache = prefill(params, batch, cache_len=Sp + max_new_tokens)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    decode = jax.jit(bundle.decode)
    out = []
    tok = sample_token(logits, key, temperature)
    out.append(tok)
    # the token sampled from the *prefill* logits can already be eos:
    # seed `done` from it instead of assuming a live batch
    done = (tok == eos_id) if eos_id is not None else None
    n_live = jnp.zeros((), jnp.int32)
    steps = 0
    t0 = time.time()
    for i in range(max_new_tokens - 1):
        # one device->host sync per `sync_every` steps, not per token
        if done is not None and i % sync_every == 0 and bool(done.all()):  # repro: noqa[host-sync-loop] -- the amortized early-exit probe; rate is capped by sync_every
            break
        key = jax.random.fold_in(key, i)
        logits, cache = decode(params, cache, tok)
        tok = sample_token(logits, key, temperature)
        if done is not None:
            n_live = n_live + jnp.sum(~done)        # live *before* this step
            done = done | (tok == eos_id)
            tok = jnp.where(done, eos_id, tok)
        else:
            n_live = n_live + B
        out.append(tok)
        steps += 1
    jax.block_until_ready(out[-1])
    t_decode = time.time() - t0
    tokens = jnp.stack(out, axis=1)
    stats = ServeStats(t_prefill, t_decode,
                       prompt_tokens=B * Sp, prefill_tokens=B,
                       decode_tokens=int(n_live), decode_steps=steps)
    return tokens, stats


# ===========================================================================
# Continuous batching: requests, completions, slot engine
# ===========================================================================

@dataclasses.dataclass
class Request:
    """One serving request.  ``inputs`` holds host arrays: ``tokens``
    (Lp,) int32 for LM families, ``feats`` (T, F) float32 for RNN-T.
    ``arrival_s`` is the offered-load arrival time relative to
    ``SlotEngine.run``'s start (0 = already queued).  ``deadline_s``
    (seconds after arrival, None = no deadline) bounds total latency: a
    request still queued or still decoding past its deadline is evicted
    with ``status="expired"`` instead of holding a slot forever."""

    uid: int
    inputs: Dict[str, np.ndarray]
    max_new_tokens: int
    arrival_s: float = 0.0
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class Completion:
    """Terminal record for one request.  ``status`` is ``"ok"`` (decoded
    to eos/budget), ``"rejected"`` (host queue overflow — backpressure,
    the request never held a slot) or ``"expired"`` (deadline passed in
    queue or mid-decode; ``tokens`` holds whatever was emitted)."""

    uid: int
    tokens: List[int]
    arrival_s: float
    admit_s: float
    done_s: float
    status: str = "ok"

    @property
    def latency_s(self) -> float:
        """Queue wait + decode: arrival to completion."""
        return self.done_s - self.arrival_s


def _select_slots(mask, new, old):
    """Leafwise per-slot select: slots where ``mask`` is False keep their
    old state bit-exactly (the serving twin of ``optim.gate_step``)."""
    def sel(n, o):
        m = mask.reshape(mask.shape + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)
    return jax.tree.map(sel, new, old)


class SlotEngine:
    """Continuous-batching serving engine over a ``ModelBundle``.

    Slot lifecycle (DESIGN.md §4):

    1. **admit** — a pending request is prefilled (prompt right-padded to
       its length bucket) and written into a free slot of the donated
       state: per-slot cache pool, last-token vector, live mask, output
       buffer and budget.  One compiled admit executable per bucket.
    2. **decode** — one donated ``jit(lax.scan)`` advances every slot
       ``sync_every`` micro-steps; non-live slots are selected back
       bit-exactly.  The host syncs once per scan (live flags + counts),
       never per token.
    3. **evict** — slots whose request finished (eos / frame cursor
       exhausted / budget) are read out and freed; the next pending
       request is admitted into the freed slot without recompiling.

    Families: decoder LMs (``tokens`` prompts, per-slot KV caches, eos
    termination) and RNN-T (``feats`` prompts; the slot cache is the
    encoder buffer + prediction-net state, a micro-step is one joint
    step, blanks advance the frame cursor and are never emitted).
    """

    def __init__(self, bundle, params, *,
                 n_slots: int = 8,
                 max_new_tokens: int = 32,
                 max_prompt_len: int = 64,
                 temperature: float = 0.0,
                 eos_id: Optional[int] = None,
                 sync_every: int = 4,
                 max_symbols: int = 8,
                 bucket_min: int = 8,
                 seed: int = 0,
                 max_queue: Optional[int] = None,
                 clock=time.time):
        cfg = bundle.cfg
        if cfg.family in ("vlm", "encdec"):
            raise ValueError(f"SlotEngine serves LM and RNN-T families, "
                             f"not {cfg.family!r}")
        self.bundle = bundle
        self.params = params
        self.cfg = cfg
        self.n_slots = int(n_slots)
        self.max_new_tokens = int(max_new_tokens)
        self.temperature = float(temperature)
        self.eos_id = eos_id
        self.sync_every = int(sync_every)
        self.max_symbols = int(max_symbols)
        self.bucket_min = int(bucket_min)
        self.is_rnnt = cfg.family == "rnnt"
        self._key = jax.random.PRNGKey(seed)
        # bounded host queue: None = unbounded (legacy); a bound turns
        # overflow into an immediate backpressure rejection instead of
        # unbounded host memory growth under offered overload
        self.max_queue = None if max_queue is None else int(max_queue)
        # injectable for deterministic deadline tests; must be monotonic
        self._clock = clock
        self.n_decode_dispatches = 0
        self.n_admits = 0
        self.n_rejected = 0
        self.n_expired = 0

        if self.is_rnnt:
            red = cfg.rnnt.time_reduction
            # feats buckets must stay multiples of the conv reduction so
            # encoder frame counts are exact per bucket
            self.bucket_min = max(self.bucket_min, red)
            self.max_prompt_len = self._bucket_of(int(max_prompt_len))
            self.cache_capacity = self.max_prompt_len // red
        else:
            # ring (sliding-window) caches evict oldest-first by buffer
            # order: bucket padding would push real keys out of a full
            # window, so windowed archs use exact-length prompts (one
            # prefill trace per distinct length — still correct)
            self.exact_lengths = bool(
                cfg.window and "local" in cfg.layer_kinds())
            self.max_prompt_len = (int(max_prompt_len) if self.exact_lengths
                                   else self._bucket_of(int(max_prompt_len)))
            self.cache_capacity = self.max_prompt_len + self.max_new_tokens

        # -- slot-state pool (the donated carry) ------------------------
        if self.is_rnnt:
            cache1 = bundle.init_cache(1, self.cache_capacity,
                                       max_symbols=self.max_symbols)
        else:
            cache1 = bundle.init_cache(1, self.cache_capacity)
        n = self.n_slots
        pool = jax.tree.map(
            lambda l: jnp.broadcast_to(l, (n,) + l.shape).copy(), cache1)
        fill = int(eos_id) if eos_id is not None else 0
        self._state = {
            "cache": pool,
            "tok": jnp.zeros((n,), jnp.int32),
            "live": jnp.zeros((n,), bool),
            "n_out": jnp.zeros((n,), jnp.int32),
            "budget": jnp.ones((n,), jnp.int32),
            "out": jnp.full((n, self.max_new_tokens), fill, jnp.int32),
        }
        self._fill = fill

        self._admit_jit = jax.jit(self._admit_fn, donate_argnums=(1,))
        self._decode_jit = jax.jit(self._decode_fn, donate_argnums=(1,))
        # deadline eviction: clear live flags for a host-computed kill
        # mask — the slot becomes a dead-slot no-op on the next scan,
        # exactly like a finished request (no retrace, no state rebuild)
        self._expire_jit = jax.jit(
            lambda state, kill: dict(state, live=state["live"] & ~kill),
            donate_argnums=(0,))

    # -- buckets --------------------------------------------------------
    def _bucket_of(self, length: int) -> int:
        """Smallest power-of-two bucket >= length (>= bucket_min)."""
        b = self.bucket_min
        while b < length:
            b *= 2
        return b

    def bucket_for(self, request: Request) -> int:
        key = "feats" if self.is_rnnt else "tokens"
        L = int(np.shape(request.inputs[key])[0])
        if L > self.max_prompt_len:
            raise ValueError(f"request {request.uid}: prompt length {L} "
                             f"exceeds max_prompt_len={self.max_prompt_len}")
        if not self.is_rnnt and self.exact_lengths:
            return L
        return self._bucket_of(L)

    # -- family hooks ---------------------------------------------------
    def _prefill_one(self, params, inputs, length):
        """B=1 prefill of one bucketed request -> (logits (1,V), cache)."""
        if self.is_rnnt:
            logits, cache = self.bundle.prefill(
                params,
                {"feats": inputs["feats"][None],
                 "feat_lens": length[None]},
                max_symbols=self.max_symbols)
            pad = self.cache_capacity - cache["enc"].shape[1]
            if pad:
                cache = dict(cache, enc=jnp.pad(
                    cache["enc"], ((0, 0), (0, pad), (0, 0))))
            return logits, cache
        return self.bundle.prefill(
            params, {"tokens": inputs["tokens"][None]},
            cache_len=self.cache_capacity, prompt_lens=length[None])

    def _emit_and_done(self, tok, cache):
        """Per-slot emission mask + termination mask for sampled ``tok``
        given the *post-step* cache (leaves carry the pool's (n, 1, ...)
        layout; scalars arrive as (n,))."""
        if self.is_rnnt:
            from repro.models.rnnt import BLANK_ID
            t = cache["t"].reshape(-1)
            t_len = cache["t_len"].reshape(-1)
            exhausted = t >= t_len
            return (tok != BLANK_ID) & ~exhausted, exhausted
        emit = jnp.ones(tok.shape, bool)
        done = (tok == self.eos_id) if self.eos_id is not None \
            else jnp.zeros(tok.shape, bool)
        return emit, done

    # -- jitted executables ---------------------------------------------
    def _admit_fn(self, params, state, slot, inputs, length, budget, key):
        logits, cache1 = self._prefill_one(params, inputs, length)
        tok0 = sample_token(logits, key, self.temperature)[0]
        if self.is_rnnt:
            from repro.models.rnnt import BLANK_ID
            emit0 = tok0 != BLANK_ID
            done0 = jnp.zeros((), bool)       # frame 0 is always valid
        else:
            emit0 = jnp.ones((), bool)
            done0 = (tok0 == self.eos_id) if self.eos_id is not None \
                else jnp.zeros((), bool)
        cache = jax.tree.map(lambda pool, leaf: pool.at[slot].set(leaf),
                             state["cache"], cache1)
        n_out0 = emit0.astype(jnp.int32)
        out_row = jnp.full((self.max_new_tokens,), self._fill, jnp.int32)
        out_row = out_row.at[0].set(jnp.where(emit0, tok0, self._fill))
        live0 = ~done0 & (n_out0 < budget)
        return {
            "cache": cache,
            "tok": state["tok"].at[slot].set(tok0),
            "live": state["live"].at[slot].set(live0),
            "n_out": state["n_out"].at[slot].set(n_out0),
            "budget": state["budget"].at[slot].set(budget),
            "out": state["out"].at[slot].set(out_row),
        }

    def _decode_fn(self, params, state, key):
        n = self.n_slots

        def one(cache, tok):
            logits, cache = self.bundle.decode(params, cache, tok[None])
            return logits[0], cache

        def micro_step(st, k):
            live = st["live"]
            logits, new_cache = jax.vmap(one)(st["cache"], st["tok"])
            tok = sample_token(logits, jax.random.fold_in(key, k),
                               self.temperature)
            emit, done_now = self._emit_and_done(tok, new_cache)
            emit = emit & live
            idx = jnp.clip(st["n_out"], 0, self.max_new_tokens - 1)
            rows = jnp.arange(n)
            cur = st["out"][rows, idx]
            out = st["out"].at[rows, idx].set(jnp.where(emit, tok, cur))
            n_out = st["n_out"] + emit.astype(jnp.int32)
            finished = live & (done_now | (n_out >= st["budget"]))
            # dead slots are bit-exact no-ops: state selected back leafwise
            return {
                "cache": _select_slots(live, new_cache, st["cache"]),
                "tok": jnp.where(live, tok, st["tok"]),
                "live": live & ~finished,
                "n_out": n_out,
                "budget": st["budget"],
                "out": out,
            }, None

        state, _ = jax.lax.scan(micro_step, state,
                                jnp.arange(self.sync_every))
        return state

    # -- host-side admit/evict loop --------------------------------------
    def _pad_inputs(self, request: Request, bucket: int):
        if self.is_rnnt:
            feats = np.asarray(request.inputs["feats"], np.float32)
            L = feats.shape[0]
            padded = np.zeros((bucket,) + feats.shape[1:], np.float32)
            padded[:L] = feats
            return {"feats": jnp.asarray(padded)}, L
        toks = np.asarray(request.inputs["tokens"], np.int32)
        L = toks.shape[0]
        padded = np.zeros((bucket,), np.int32)
        padded[:L] = toks
        return {"tokens": jnp.asarray(padded)}, L

    def _admit(self, slot: int, request: Request):
        bucket = self.bucket_for(request)
        inputs, L = self._pad_inputs(request, bucket)
        budget = min(int(request.max_new_tokens), self.max_new_tokens)
        self._key, sub = jax.random.split(self._key)
        self._state = self._admit_jit(
            self.params, self._state, jnp.asarray(slot, jnp.int32),
            inputs, jnp.asarray(L, jnp.int32),
            jnp.asarray(budget, jnp.int32), sub)
        self.n_admits += 1

    def _expired(self, req: Request, now: float) -> bool:
        return (req.deadline_s is not None
                and now > req.arrival_s + req.deadline_s)

    def run(self, requests: Sequence[Request]) -> List[Completion]:
        """Serve ``requests`` (offered load via ``arrival_s``) to
        completion.  Admission, decoding and eviction interleave: freed
        slots are refilled between decode scans without recompiling.
        Arrivals land in a host queue bounded by ``max_queue`` (overflow
        -> ``status="rejected"`` backpressure); a request whose
        ``deadline_s`` passes while queued is dropped without ever
        taking a slot, and one that expires mid-decode is evicted as a
        dead-slot no-op with its partial tokens (``status="expired"``)."""
        clock = self._clock
        schedule = collections.deque(
            sorted(requests, key=lambda r: (r.arrival_s, r.uid)))
        queue: "collections.deque[Request]" = collections.deque()
        active: Dict[int, Tuple[Request, float]] = {}
        free = list(range(self.n_slots))
        completions: List[Completion] = []
        t0 = clock()
        while schedule or queue or active:
            now = clock() - t0
            # arrivals -> bounded host queue; overflow is rejected NOW
            # (backpressure) instead of growing the queue without bound
            while schedule and schedule[0].arrival_s <= now:
                req = schedule.popleft()
                if (self.max_queue is not None
                        and len(queue) >= self.max_queue):
                    self.n_rejected += 1
                    completions.append(Completion(
                        uid=req.uid, tokens=[], arrival_s=req.arrival_s,
                        admit_s=float("nan"), done_s=clock() - t0,
                        status="rejected"))
                    continue
                queue.append(req)
            # queued requests whose deadline passed never take a slot
            now = clock() - t0
            kept: "collections.deque[Request]" = collections.deque()
            for req in queue:
                if self._expired(req, now):
                    self.n_expired += 1
                    completions.append(Completion(
                        uid=req.uid, tokens=[], arrival_s=req.arrival_s,
                        admit_s=float("nan"), done_s=clock() - t0,
                        status="expired"))
                else:
                    kept.append(req)
            queue = kept
            while free and queue:
                req = queue.popleft()
                slot = free.pop()
                self._admit(slot, req)
                active[slot] = (req, clock() - t0)
            if not active:
                if schedule:
                    # idle: nothing decoding, next arrival in the future
                    time.sleep(min(max(schedule[0].arrival_s - now, 0.0),
                                   0.005))
                continue
            self._key, sub = jax.random.split(self._key)
            self._state = self._decode_jit(self.params, self._state, sub)
            self.n_decode_dispatches += 1
            # ONE host sync per scan: live flags + emission counts
            live = np.asarray(self._state["live"])    # repro: noqa[host-sync-loop] -- the documented once-per-scan sync point (DESIGN §4)
            n_out = np.asarray(self._state["n_out"])  # repro: noqa[host-sync-loop] -- fetched alongside live, same single sync point
            # deadline sweep over active slots: expired ones are killed
            # on device (live cleared) and read out below like finished
            now = clock() - t0
            kill = np.zeros(self.n_slots, bool)
            for slot, (req, _) in active.items():
                if live[slot] and self._expired(req, now):
                    kill[slot] = True
            if kill.any():
                self._state = self._expire_jit(self._state,
                                               jnp.asarray(kill))
                live = live & ~kill
            finished = [s for s in list(active) if not live[s]]
            if finished:
                # one fetch of the whole out pool for the sweep — indexing
                # `out[slot]` per finished slot would dispatch a device
                # gather + blocking D2H transfer for every eviction
                out_pool = np.asarray(self._state["out"])  # repro: noqa[host-sync-loop] -- single pool fetch, only on sweeps that evict
            for slot in finished:
                req, admit_s = active.pop(slot)
                toks = out_pool[slot][: int(n_out[slot])]
                if kill[slot]:
                    self.n_expired += 1
                completions.append(Completion(
                    uid=req.uid, tokens=[int(t) for t in toks],
                    arrival_s=req.arrival_s, admit_s=admit_s,
                    done_s=clock() - t0,
                    status="expired" if kill[slot] else "ok"))
                free.append(slot)
        return completions


# ===========================================================================
# RNN-T greedy decode: non-streaming reference
# ===========================================================================

def rnnt_greedy_reference(bundle, params, feats, feat_lens,
                          max_symbols: int = 8) -> List[List[int]]:
    """Greedy transducer search as the textbook host loop (Graves 2012):
    for each frame, emit argmax symbols until blank (or ``max_symbols``
    emissions), then advance.  The oracle the streaming SlotEngine path
    must match token-for-token (tests/test_serve_engine.py)."""
    from repro.models import rnnt as rnnt_mod
    cfg = bundle.cfg
    enc = rnnt_mod.encode(params, cfg, jnp.asarray(feats))
    red = cfg.rnnt.time_reduction
    t_lens = np.minimum(
        np.maximum(np.asarray(feat_lens) // red, 1), enc.shape[1])
    results: List[List[int]] = []
    for b in range(enc.shape[0]):
        g, h = rnnt_mod.pred_start(params, cfg, 1, dtype=enc.dtype)
        toks: List[int] = []
        for t in range(int(t_lens[b])):
            for _ in range(max_symbols):
                logits = rnnt_mod.joint_step(params, enc[b: b + 1, t], g)
                k = int(jnp.argmax(logits[0]))  # repro: noqa[host-sync-loop] -- textbook host-loop oracle; per-symbol sync is its definition
                if k == rnnt_mod.BLANK_ID:
                    break
                toks.append(k)
                g, h = rnnt_mod.pred_step(
                    params, cfg, jnp.asarray([k], jnp.int32), h)
        results.append(toks)
    return results
