"""Partition specs for params, optimizer state, batches, caches and
activations (DESIGN.md §5).

Strategy: 2-D sharding — FSDP over the data axes (params gathered per
layer by the compiler) + tensor parallelism over ``model``.  All rules are
divisibility-guarded: a dim is sharded only when the mesh axis divides it,
so one policy covers every assigned arch (e.g. seamless' vocab 256206 is
not 16-divisible -> embedding falls back to FSDP-only; starcoder2's 24
heads -> head_dim sharding instead of head sharding).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import Sharder


def _axsize(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def _div(dim: int, mesh, axes) -> bool:
    return axes is not None and dim % _axsize(mesh, axes) == 0


def _best_axes(dim: int, axes_pref):
    """Longest prefix of ``axes_pref`` whose size product divides dim."""
    if axes_pref is None:
        return None
    axes = (axes_pref,) if isinstance(axes_pref, str) else tuple(axes_pref)
    return axes  # divisibility handled by guarded()


class SpecBuilder:
    """mode:
      'tp'         — FSDP over data axes + tensor parallel over 'model'
                     (serving; MoE experts ride the 'model' axis when
                     divisible)
      'expert'     — like 'tp', but MoE expert weights shard their
                     leading n_experts dim over a dedicated ``expert``
                     mesh axis when the mesh has one, else over the FSDP
                     data axes (``P(expert-or-fsdp, ...)``), and router
                     params stay REPLICATED so every shard routes with
                     identical logits under top-k dispatch.  Expert-dim
                     indivisibility is a hard ValueError (naming the
                     arch) instead of a silent fallback — a half-sharded
                     expert bank trains wrong quietly.
      'fsdp_sp'    — batch over data axes, SEQUENCE over 'model', params
                     fully FSDP (dense-attention training: removes the
                     per-layer TP activation all-reduces; perf iter 4)
      'fsdp_batch' — batch over ALL axes, params fully FSDP (recurrent
                     archs whose sequence axis cannot shard)
    """

    def __init__(self, mesh, *, fsdp: bool = True, mode: str = "tp",
                 pod_axis: Optional[str] = None,
                 arch: Optional[str] = None):
        """``pod_axis`` names a slow cross-pod mesh axis that params (and
        their mirrored optimizer/error-feedback states) must NOT shard
        over — the standard multi-pod layout is FSDP *within* a pod and
        plain replication *across* pods, with the cross-pod gradient
        collective handled explicitly (``train/compress.py``,
        DESIGN.md §5).  The pod axis is excluded from both the data-
        parallel and the FSDP axis sets; meshes without a ``model`` axis
        (e.g. ``data x pod``) degrade gracefully to tp=None.  An
        ``expert`` axis is likewise never used for data parallelism —
        it exists solely for the expert-weight dim in ``mode='expert'``.
        ``arch`` names the model config in error messages."""
        self.mesh = mesh
        self.mode = mode
        self.pod_axis = pod_axis
        self.arch = arch
        has_model = "model" in mesh.axis_names
        dp = tuple(a for a in mesh.axis_names
                   if a not in ("model", "expert") and a != pod_axis)
        self.dp_axes = dp
        self.all_axes = tuple(a for a in mesh.axis_names
                              if a != pod_axis and a != "expert")
        self.dp = dp if len(dp) > 1 else (dp[0] if dp else None)
        if mode in ("tp", "expert"):
            self.tp = "model" if has_model else None
            self.fsdp = self.dp if fsdp else None
            #: the expert-or-fsdp axis for MoE expert-weight leading dims
            self.expert = ("expert" if "expert" in mesh.axis_names
                           else self.fsdp)
        elif mode == "fsdp_sp":
            self.tp = None                     # no tensor parallelism
            self.fsdp = self.all_axes          # params over everything
            self.seq = "model" if has_model else None
        elif mode == "fsdp_batch":
            self.tp = None
            self.fsdp = self.all_axes
            self.seq = None
        else:
            raise ValueError(mode)

    # -- parameter rule, dispatched on key-path + shape ---------------------
    def param_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        m = self.mesh
        nd = len(shape)
        is_moe = ".moe." in path or "'moe'" in path

        def guarded(*axes):
            out = []
            for dim, ax in zip(shape, axes):
                out.append(ax if _div(dim, m, ax) else None)
            # never shard one mesh axis twice
            seen = set()
            final = []
            for ax in out:
                key = tuple(ax) if isinstance(ax, tuple) else ax
                if ax is not None and key in seen:
                    final.append(None)
                    continue
                if ax is not None:
                    seen.add(key)
                final.append(ax)
            return P(*final)

        if nd == 0:
            return P()
        if nd == 1:
            return P(None)
        # stacked-group params have 1-2 leading stack dims; identify the
        # trailing "real" dims by known key names
        leaf = re.split(r"[.\[\]']+", path.strip("."))
        name = next((t for t in reversed(leaf) if t and t != "w"), "")
        core = _PARAM_RULES.get(name)
        if is_moe and name in ("w_in", "w_gate"):
            core = ("experts", "fsdp", "tp")        # (E, d, ff)
        if is_moe and name == "w_out":
            core = ("experts", "tp", "fsdp")        # (E, ff, d)
        if is_moe and name == "router" and self.mode == "expert":
            # routers replicate in expert mode: every shard must compute
            # identical top-k routing decisions for the dispatched slots
            # (and the GSPMD mean-psum of their grads over data) to agree
            return P(*([None] * nd))
        if "embed" in path and nd >= 2:
            # vocab over 'model' in every mode: the fwd gather needs only a
            # small (B,S,d) combine, and unembed logits come out
            # vocab-sharded (no full-table replication; §Perf iter 5)
            core = (("tp", "fsdp") if self.mode in ("tp", "expert")
                    else ("model", None))
        if "lm_head" in path and nd >= 2:
            core = (("fsdp", "tp") if self.mode in ("tp", "expert")
                    else (None, "model"))
        if core is None:
            core = ("fsdp", "tp") if nd >= 2 else (None,)
        core_nd = len(core)
        lead = nd - core_nd
        if lead < 0:        # e.g. rule for stacked but leaf unstacked
            core = core[-nd:]
            lead = 0
        axes = [None] * lead + [self._resolve(c, shape[lead + i])
                                for i, c in enumerate(core)]
        return guarded(*axes)

    def _resolve(self, tag, dim):
        if tag is None:
            return None
        if tag == "fsdp":
            return self.fsdp
        if tag == "tp":
            return self.tp
        if tag == "experts":
            if self.mode == "expert":
                ax = self.expert
                if ax is None or not _div(dim, self.mesh, ax):
                    raise ValueError(
                        f"arch {self.arch or '<unknown>'}: MoE expert dim "
                        f"{dim} does not divide over expert axis {ax!r} "
                        f"(size {_axsize(self.mesh, ax)}) in "
                        f"mode='expert' — resize the mesh or drop the "
                        f"expert axis instead of silently half-sharding "
                        f"the expert bank")
                return ax
            return self.tp if _div(dim, self.mesh, self.tp) else None
        return tag

    def param_specs(self, shapes_tree):
        flat, treedef = jax.tree_util.tree_flatten_with_path(shapes_tree)
        specs = [self.param_spec(jax.tree_util.keystr(p), l.shape)
                 for p, l in flat]
        return jax.tree_util.tree_unflatten(treedef, specs)

    # -- batches ------------------------------------------------------------
    def batch_spec(self, name: str, shape: Tuple[int, ...]) -> P:
        B = shape[0]
        if self.mode == "fsdp_batch":
            ax = self.all_axes if _div(B, self.mesh, self.all_axes) else (
                self.dp if _div(B, self.mesh, self.dp) else None)
            return P(ax, *([None] * (len(shape) - 1)))
        dp = self.dp if _div(B, self.mesh, self.dp) else None
        rest = [None] * (len(shape) - 1)
        if (self.mode == "fsdp_sp" and len(shape) >= 2
                and _div(shape[1], self.mesh, "model")):
            rest[0] = "model"                  # sequence over 'model'
        return P(dp, *rest)

    def batch_specs(self, tree):
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        specs = [self.batch_spec(jax.tree_util.keystr(p), l.shape)
                 for p, l in flat]
        return jax.tree_util.tree_unflatten(treedef, specs)

    # -- decode caches --------------------------------------------------------
    def cache_spec(self, path: str, shape: Tuple[int, ...],
                   batch: int) -> P:
        """KV caches: batch over dp when divisible, else the sequence dim
        (long-context, batch=1) over dp; kv-heads over model when
        divisible, else head_dim (flash-decoding-style layouts are a perf
        iteration, see EXPERIMENTS.md §Perf)."""
        nd = len(shape)
        if nd == 0:
            return P()
        leaf = re.split(r"[.\[\]']+", path.strip("."))
        name = next((t for t in reversed(leaf) if t), "")
        # locate the batch dim: caches may carry leading stack dims
        try:
            b_idx = shape.index(batch)
        except ValueError:
            b_idx = None
        axes = [None] * nd
        dp_used = False
        if b_idx is not None and _div(batch, self.mesh, self.dp):
            axes[b_idx] = self.dp
            dp_used = True
        if name in ("k", "v") and nd >= 3:
            # (..., B, L, KV, hd)
            kv_dim, hd_dim = shape[-2], shape[-1]
            if _div(kv_dim, self.mesh, self.tp):
                axes[-2] = self.tp
            elif _div(hd_dim, self.mesh, self.tp):
                axes[-1] = self.tp
            if not dp_used and _div(shape[-3], self.mesh, self.dp):
                axes[-3] = self.dp          # seq-sharded long context
        elif name in ("ck", "cv") and nd >= 3:
            if _div(shape[-2], self.mesh, self.tp):
                axes[-2] = self.tp
            elif _div(shape[-1], self.mesh, self.tp):
                axes[-1] = self.tp
        elif name == "S" and nd >= 3:       # rwkv state (..., B, H, N, N)
            if _div(shape[-3], self.mesh, self.tp):
                axes[-3] = self.tp
        elif name in ("h", "conv") and nd >= 2:   # rg-lru state (..., B, w)
            if _div(shape[-1], self.mesh, self.tp):
                axes[-1] = self.tp
        return P(*axes)

    def cache_specs(self, tree, batch: int):
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        specs = [self.cache_spec(jax.tree_util.keystr(p), l.shape, batch)
                 for p, l in flat]
        return jax.tree_util.tree_unflatten(treedef, specs)

    # -- shardings ------------------------------------------------------------
    def to_shardings(self, spec_tree):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))


# trailing-dim rules per param name: tags resolve via SpecBuilder._resolve
_PARAM_RULES: Dict[str, Tuple] = {
    "wq": ("fsdp", "tp"),
    "wk": ("fsdp", "tp"),
    "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    "wg": ("fsdp", "tp"),
    "wr": ("fsdp", "tp"),
    "w_in": ("fsdp", "tp"),
    "w_gate": ("fsdp", "tp"),
    "w_gate_branch": ("fsdp", "tp"),
    "w_out": ("tp", "fsdp"),
    "router": ("fsdp", None),
    "w_enc": ("fsdp", "tp"),
    "w_pred": ("fsdp", "tp"),
    "wa": ("fsdp", "tp"),
    "wx": ("fsdp", "tp"),
    "wh": ("fsdp", "tp"),
    "decay_w1": ("fsdp", None),
    "decay_w2": (None, "tp"),
    "ddlerp_w1": ("fsdp", None),
    "ddlerp_w2": (None, None, "fsdp"),
    "conv_w": (None, "tp"),
    "pred_embed": ("tp", "fsdp"),
}


class MeshSharder(Sharder):
    """Activation-constraint callback handed into model forwards."""

    def __init__(self, mesh, *, enable: bool = True, mode: str = "tp",
                 pod_axis: Optional[str] = None,
                 arch: Optional[str] = None):
        self.mesh = mesh
        self.b = SpecBuilder(mesh, mode=mode, pod_axis=pod_axis, arch=arch)
        self.enable = enable

    def kv_repeat(self, n_heads: int, n_kv_heads: int) -> int:
        """Smallest r dividing the GQA group count with (n_kv*r) divisible
        by the TP degree, so attention scores shard over heads instead of
        being computed via per-block all-reduces (head_dim contraction).
        Returns 1 when no such r exists (falls back to head_dim sharding)
        or when KV heads already align."""
        if not self.enable or self.b.mode not in ("tp", "expert") \
                or "model" not in self.mesh.axis_names:
            return 1
        tp = _axsize(self.mesh, "model")
        if n_kv_heads % tp == 0 or tp == 1:
            return 1
        g = n_heads // n_kv_heads
        for r in range(2, g + 1):
            if g % r == 0 and (n_kv_heads * r) % tp == 0:
                return r
        return 1

    def __call__(self, x, name: str):
        if not self.enable:
            return x
        m, dp = self.mesh, self.b.dp
        shape = x.shape
        spec = None
        if self.b.mode not in ("tp", "expert"):
            # fsdp_sp: (B, S, ...) activations -> batch over dp, seq over
            # 'model'; fsdp_batch: batch over all axes
            if x.ndim >= 2 and name in ("act_bsd", "act_ff", "act_q",
                                        "act_kv", "act_q_flat"):
                if self.b.mode == "fsdp_batch":
                    ax = (self.b.all_axes
                          if _div(shape[0], m, self.b.all_axes) else
                          (dp if _div(shape[0], m, dp) else None))
                    spec = P(ax, *([None] * (x.ndim - 1)))
                else:
                    seq_ax = ("model"
                              if _div(shape[1], m, "model") else None)
                    spec = P(dp if _div(shape[0], m, dp) else None, seq_ax,
                             *([None] * (x.ndim - 2)))
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(m, spec))
            return x
        tp = "model" if "model" in m.axis_names else None
        if name == "act_bsd" and x.ndim == 3:
            spec = P(dp if _div(shape[0], m, dp) else None, None, None)
        elif name == "act_ff" and x.ndim == 3:
            spec = P(dp if _div(shape[0], m, dp) else None, None,
                     tp if _div(shape[2], m, tp) else None)
        elif name in ("act_q", "act_kv"):
            # (B,S,KV,G,hd) or (B,S,KV,hd): prefer head sharding, fall back
            # to head_dim
            axes = [dp if _div(shape[0], m, dp) else None] + \
                   [None] * (x.ndim - 1)
            if _div(shape[2], m, tp):
                axes[2] = tp
            elif _div(shape[-1], m, tp):
                axes[-1] = tp
            spec = P(*axes)
        elif name == "act_q_flat" and x.ndim == 3:
            spec = P(dp if _div(shape[0], m, dp) else None, None,
                     tp if _div(shape[2], m, tp) else None)
        elif name == "moe_expert_in" or name == "moe_expert_out":
            # (E, G, C, d): the all-to-all boundary — the E dim rides the
            # expert axis in mode='expert', the TP axis otherwise
            eax = self.b.expert if self.b.mode == "expert" else tp
            axes = [eax if _div(shape[0], m, eax) else None,
                    dp if _div(shape[1], m, dp) else None, None, None]
            spec = P(*axes)
        elif name == "moe_dispatch":
            spec = P(dp if _div(shape[0], m, dp) else None, None, None, None)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(m, spec))
