"""Level-2 static contracts: checkers over the compiled artifacts of the
real engine builds.

Four invariants carried the last nine PRs and were each asserted once,
ad hoc, in whichever test introduced them.  This module promotes them to
reusable checkers the engine tests import:

  * ``track_compiles`` / ``assert_retrace_free`` — a shared
    compile-counter context manager (replaces the bespoke
    ``EpochEngine.n_epoch_traces`` python-side-effect counter).  Counts
    *actual XLA compilations* via ``jax.log_compiles``, so it also sees
    op-by-op compiles a hand-rolled per-function counter never could,
    and it applies to executables that never had a counter (the
    ``SlotEngine`` admit/decode path).
  * ``assert_donated`` — the donated carry really aliases its outputs,
    read off the ``tf.aliasing_output`` / ``jax.buffer_donor``
    attributes of the lowered module's entry parameters.
  * ``assert_no_host_transfers`` — the epoch/decode body contains no
    infeed/outfeed, host callback custom-calls, or async host copies;
    ``no_implicit_transfers`` is its runtime twin (a transfer guard
    that fails the block on any implicit device-to-host fetch).
  * ``assert_collective_width`` / ``assert_replica_groups`` — the PR-5
    bf16-wire check generalized to any mesh: dtype is proven on the
    *lowered* StableHLO (XLA:CPU float-normalization promotes compiled
    reduces, DESIGN §5), group shape on the *compiled* HLO, with both
    the literal ``{{0,2},{1,3}}`` and iota ``[2,2]<=[2,2]T(1,0)``
    replica-group encodings parsed against the mesh's expected groups.

All checkers accept either HLO text or a ``jax.stages.Lowered``.
"""
from __future__ import annotations

import contextlib
import logging
import re
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "CompileLog", "track_compiles", "assert_retrace_free",
    "donated_flat_args", "assert_donated",
    "assert_no_host_transfers", "no_implicit_transfers",
    "lowered_reduce_dtypes", "assert_collective_width",
    "parse_replica_groups", "expected_groups", "assert_replica_groups",
]

# ---------------------------------------------------------------------------
# retrace freedom
# ---------------------------------------------------------------------------

_COMPILE_RE = re.compile(r"Finished XLA compilation of (.+?) in [\d.eE+-]+")


class CompileLog:
    """Names of every XLA compilation finished inside a
    ``track_compiles`` block.  Cache hits do not log, so ``count == 0``
    means the block dispatched only already-compiled executables."""

    def __init__(self):
        self.names: List[str] = []

    @property
    def count(self) -> int:
        return len(self.names)

    def __repr__(self):
        return f"CompileLog(count={self.count}, names={self.names!r})"


class _Capture(logging.Handler):
    def __init__(self, log: CompileLog):
        super().__init__(level=logging.DEBUG)
        self._log = log

    def emit(self, record):
        m = _COMPILE_RE.search(record.getMessage())
        if m:
            self._log.names.append(m.group(1))


@contextlib.contextmanager
def track_compiles():
    """``with track_compiles() as log: ...; assert log.count == 0``.

    Implemented on ``jax.log_compiles()`` + a handler on the dispatch
    logger — the only place every compilation (jit, pjit, op-by-op)
    funnels through.  Nesting is fine; each context gets its own log.
    """
    import jax
    log = CompileLog()
    logger = logging.getLogger("jax._src.dispatch")
    handler = _Capture(log)
    old_level = logger.level
    logger.addHandler(handler)
    if old_level > logging.DEBUG or old_level == logging.NOTSET:
        logger.setLevel(logging.DEBUG)
    # log_compiles raises these loggers to WARNING-visible; keep the
    # records out of stderr while we capture them
    muted = [logging.getLogger(n) for n in
             ("jax._src.dispatch", "jax._src.interpreters.pxla")]
    old_prop = [lg.propagate for lg in muted]
    for lg in muted:
        lg.propagate = False
    try:
        with jax.log_compiles():
            yield log
    finally:
        logger.removeHandler(handler)
        logger.setLevel(old_level)
        for lg, p in zip(muted, old_prop):
            lg.propagate = p


@contextlib.contextmanager
def assert_retrace_free(what: str = "block", allowed: int = 0):
    """Assert the wrapped block triggers no (or at most ``allowed``)
    XLA compilations — i.e. everything it dispatches was already
    compiled.  Use after a warm-up call that builds the executables."""
    with track_compiles() as log:
        yield log
    if log.count > allowed:
        raise AssertionError(
            f"{what} retraced: {log.count} compilation(s) "
            f"(allowed {allowed}): {log.names}")


# ---------------------------------------------------------------------------
# donation (input-output aliasing)
# ---------------------------------------------------------------------------

def _lowered_text(lowered_or_text) -> str:
    if isinstance(lowered_or_text, str):
        return lowered_or_text
    return lowered_or_text.as_text()


# plain jit marks aliasing directly; under a mesh the same donation
# lowers to a buffer-donor hint instead (aliases resolve at compile)
_DONOR_MARKERS = ("tf.aliasing_output", "jax.buffer_donor")
_ARG_POS_RE = re.compile(r"%arg(\d+):")


def donated_flat_args(lowered_or_text) -> List[bool]:
    """Per flattened entry argument: is it donated?  Read from the
    ``tf.aliasing_output`` / ``jax.buffer_donor`` attributes on
    ``@main``'s parameters in the lowered StableHLO."""
    text = _lowered_text(lowered_or_text)
    m = re.search(r"func\.func (?:public )?@main\((.*?)\)\s*(?:->|\{)",
                  text, flags=re.S)
    if not m:
        raise AssertionError("no @main entry function in lowered module")
    sig = m.group(1)
    hits = list(_ARG_POS_RE.finditer(sig))
    flags = {}
    for i, h in enumerate(hits):
        end = hits[i + 1].start() if i + 1 < len(hits) else len(sig)
        chunk = sig[h.start():end]
        flags[int(h.group(1))] = any(mk in chunk for mk in _DONOR_MARKERS)
    return [flags[i] for i in sorted(flags)]


def assert_donated(lowered_or_text, carry_leaves, *, skip=None) -> None:
    """Assert the ``len(leaves(carry_leaves))`` flattened entry
    arguments starting after ``leaves(skip)`` are donated.

    The epoch engines place the donated carry (params, opt state[,
    error state]) first — ``skip=None``; ``SlotEngine`` donates the
    slot-state pool that follows the (non-donated) params —
    ``skip=params``."""
    import jax
    n0 = 0 if skip is None else len(jax.tree_util.tree_leaves(skip))
    n = len(jax.tree_util.tree_leaves(carry_leaves))
    flags = donated_flat_args(lowered_or_text)
    if len(flags) < n0 + n:
        raise AssertionError(
            f"entry has {len(flags)} args but carry spans "
            f"[{n0}, {n0 + n})")
    missing = [i for i in range(n) if not flags[n0 + i]]
    if missing:
        raise AssertionError(
            f"carry leaves {missing} are not donated "
            f"(no aliasing/donor mark on the lowered entry) — "
            f"buffers will be double-allocated")


# ---------------------------------------------------------------------------
# no host transfers
# ---------------------------------------------------------------------------

_HOST_TRANSFER_PATTERNS = (
    # compiled HLO
    r"\binfeed\(", r"\boutfeed\(", r"= \S+ send\(", r"= \S+ recv\(",
    r"\bcopy-start\(", r"custom-call[^\n]*callback",
    # lowered StableHLO
    r"stablehlo\.infeed", r"stablehlo\.outfeed", r"stablehlo\.send",
    r"stablehlo\.recv", r"custom_call[^\n]*callback",
)


def assert_no_host_transfers(*hlo_texts) -> None:
    """Assert no host transfer primitives (infeed/outfeed, send/recv,
    async host copies, python-callback custom-calls) appear in the given
    modules.  Pass both the lowered and compiled text of the epoch /
    decode body; callbacks show as ``custom_call`` pre-optimization and
    ``custom-call ... callback`` post."""
    for blob in hlo_texts:
        text = _lowered_text(blob)
        for pat in _HOST_TRANSFER_PATTERNS:
            m = re.search(pat, text)
            if m:
                line = text[:m.start()].count("\n") + 1
                raise AssertionError(
                    f"host transfer `{m.group(0)}` at module line {line} "
                    f"— the scanned body must stay device-resident")


@contextlib.contextmanager
def no_implicit_transfers():
    """Runtime complement to ``assert_no_host_transfers``: raise on any
    *implicit* device-to-host transfer inside the block (a ``float()``
    / ``np.asarray()`` on a device array).  Explicit fetches via
    ``jax.device_get`` still pass — wrap only the dispatch-side code
    whose syncs are supposed to happen elsewhere.

    Only bites on real accelerators: on the CPU backend arrays already
    live in host memory, so the runtime never routes a D2H copy through
    the guard and the block passes vacuously (the static
    ``assert_no_host_transfers`` / ``host-sync-loop`` checks carry the
    invariant there)."""
    import jax
    with jax.transfer_guard_device_to_host("disallow"):
        yield


# ---------------------------------------------------------------------------
# collective width + replica groups
# ---------------------------------------------------------------------------

# vmap-bound axis: pmean becomes a real reduce over the leading pod
# dim, e.g. `stablehlo.reduce(%x init: %c) applies stablehlo.add across
# dimensions = [0] : (tensor<2x64xbf16>, tensor<bf16>) -> ...`
_REDUCE_RE = re.compile(
    r"stablehlo\.reduce\([^\n]*dimensions = \[([\d, ]*)\][^\n]*")
_TENSOR_DTYPE_RE = re.compile(r"tensor<[0-9x]*([a-z][a-z0-9]+)>")
# shard_map-bound axis: an explicit all_reduce; its region block names
# the scalar operand type
_ALL_REDUCE_RE = re.compile(
    r"all_reduce[^\n]*?\n?.*?\^bb0\(%\w+: tensor<([a-z][a-z0-9]+)>",
    flags=re.S)


def lowered_reduce_dtypes(lowered_or_text,
                          dims: Optional[Sequence[int]] = None) -> List[str]:
    """Element dtypes of every cross-replica reduction in the lowered
    module: ``stablehlo.reduce`` over ``dims`` (default ``[0]``, the
    engines' stacked pod axis) plus every ``stablehlo.all_reduce``."""
    text = _lowered_text(lowered_or_text)
    want = list(dims) if dims is not None else [0]
    out: List[str] = []
    for m in _REDUCE_RE.finditer(text):
        got = [int(d) for d in m.group(1).replace(" ", "").split(",") if d]
        if got == want:
            tm = _TENSOR_DTYPE_RE.search(m.group(0))
            if tm:
                out.append(tm.group(1))
    out.extend(m.group(1) for m in _ALL_REDUCE_RE.finditer(text))
    return out


def assert_collective_width(lowered_or_text, *, dtype: str,
                            n_expected: Optional[int] = None,
                            dims: Optional[Sequence[int]] = None) -> None:
    """Assert the *lowered* module's cross-replica reductions run at
    ``dtype`` width — the wire-width claim.  Must be checked
    pre-optimization: XLA:CPU float-normalization promotes bf16 reduces
    to f32 in the compiled module (DESIGN §5).

    With ``n_expected`` (one per gradient leaf for the engines), assert
    exactly that many reductions at ``dtype`` — other-width reductions
    (e.g. the engines' f32 metric pmeans) are tolerated.  Without it,
    assert *every* reduction runs at ``dtype``."""
    got = lowered_reduce_dtypes(lowered_or_text, dims=dims)
    if not got:
        raise AssertionError("no cross-replica reductions in lowered module")
    if n_expected is not None:
        n_at = sum(1 for d in got if d == dtype)
        if n_at != n_expected:
            raise AssertionError(
                f"{n_at} reductions at {dtype!r}, expected {n_expected} "
                f"(one per leaf); widths seen: {got}")
    else:
        wrong = [d for d in got if d != dtype]
        if wrong:
            raise AssertionError(
                f"collective(s) reduce at {sorted(set(wrong))}, expected "
                f"{dtype!r} — the wire moves the wrong number of bytes")


_GROUPS_LITERAL_RE = re.compile(r"replica_groups=\{(\{[\d,{} ]*\})\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


def parse_replica_groups(line: str) -> Optional[List[List[int]]]:
    """Replica groups of one compiled ``all-reduce`` line, handling both
    the literal ``{{0,2},{1,3}}`` and iota ``[2,2]<=[4]`` /
    ``[2,2]<=[2,2]T(1,0)`` encodings."""
    m = _GROUPS_LITERAL_RE.search(line)
    if m:
        return [[int(x) for x in g.split(",") if x.strip()]
                for g in re.findall(r"\{([\d, ]*)\}", m.group(1))]
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        n, g = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(p) for p in m.group(4).split(",")])
        return ids.reshape(n, g).tolist()
    return None


def expected_groups(mesh, axis: str) -> List[List[int]]:
    """Device-id groups a reduction over mesh axis ``axis`` must form:
    one group per cross-section, each holding the ids along ``axis``."""
    ids = np.vectorize(lambda d: d.id)(mesh.devices)
    k = list(mesh.axis_names).index(axis)
    moved = np.moveaxis(ids, k, -1)
    return moved.reshape(-1, ids.shape[k]).tolist()


def _norm(groups: Iterable[Iterable[int]]) -> Tuple:
    return tuple(sorted(tuple(sorted(g)) for g in groups))


def assert_replica_groups(compiled_text: str, mesh, axis: str,
                          min_count: int = 1) -> None:
    """Assert the compiled module carries at least ``min_count``
    ``all-reduce`` ops whose replica groups are exactly the groups of
    mesh axis ``axis`` — e.g. pods {0,2},{1,3} on a 2x2 (data, pod)
    mesh.  Generalizes the PR 5 hard-coded group-string check."""
    want = _norm(expected_groups(mesh, axis))
    found = 0
    seen = []
    for line in compiled_text.splitlines():
        if "all-reduce" not in line:
            continue
        groups = parse_replica_groups(line)
        if groups is None:
            continue
        seen.append(groups)
        if _norm(groups) == want:
            found += 1
    if found < min_count:
        raise AssertionError(
            f"no all-reduce grouped over mesh axis {axis!r} "
            f"(want {list(want)}, saw {seen})")
