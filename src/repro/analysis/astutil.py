"""Shared AST helpers for the lint rules."""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set, Tuple

HOST_BUILTINS = {"len", "int", "float", "bool", "str", "range", "min", "max",
                 "sorted", "sum", "abs", "round", "enumerate", "zip", "list",
                 "tuple", "dict", "set"}


def dotted(node: ast.AST) -> Optional[str]:
    """``jax.random.split`` -> "jax.random.split"; None for non-name trees."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def root_name(node: ast.AST) -> Optional[str]:
    """Leftmost Name of an attribute/subscript/call chain, else None."""
    while True:
        if isinstance(node, ast.Name):
            return node.id
        if isinstance(node, (ast.Attribute, ast.Starred)):
            node = node.value
        elif isinstance(node, ast.Subscript):
            node = node.value
        elif isinstance(node, ast.Call):
            node = node.func
        else:
            return None


def functions(tree: ast.AST) -> Iterator[ast.AST]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def call_name(call: ast.Call) -> Optional[str]:
    return dotted(call.func)


def decorator_names(fn) -> List[str]:
    out = []
    for d in fn.decorator_list:
        if isinstance(d, ast.Call):
            name = dotted(d.func)
            # functools.partial(jax.jit, ...) wraps its first argument
            if name and name.endswith("partial") and d.args:
                inner = dotted(d.args[0])
                if inner:
                    out.append(inner)
            if name:
                out.append(name)
        else:
            name = dotted(d)
            if name:
                out.append(name)
    return out


def static_argnames(fn) -> Set[str]:
    """Names declared static in a jit decorator on ``fn`` (best effort)."""
    out: Set[str] = set()
    for d in fn.decorator_list:
        if not isinstance(d, ast.Call):
            continue
        for kw in d.keywords:
            if kw.arg in ("static_argnames", "static_argnums") and \
                    isinstance(kw.value, (ast.Tuple, ast.List)):
                for elt in kw.value.elts:
                    if isinstance(elt, ast.Constant) and \
                            isinstance(elt.value, str):
                        out.add(elt.value)
    return out


def param_names(fn) -> List[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def assign_targets(stmt) -> List[Tuple[str, ast.AST]]:
    """(name, value) pairs for simple / tuple-unpacking assignments."""
    pairs: List[Tuple[str, ast.AST]] = []
    if isinstance(stmt, ast.Assign):
        for tgt in stmt.targets:
            if isinstance(tgt, ast.Name):
                pairs.append((tgt.id, stmt.value))
            elif isinstance(tgt, (ast.Tuple, ast.List)):
                for elt in tgt.elts:
                    if isinstance(elt, ast.Name):
                        pairs.append((elt.id, stmt.value))
    elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None and \
            isinstance(stmt.target, ast.Name):
        pairs.append((stmt.target.id, stmt.value))
    elif isinstance(stmt, ast.AugAssign) and isinstance(stmt.target, ast.Name):
        pairs.append((stmt.target.id, stmt.value))
    return pairs
