"""Pallas kernel hygiene (L4).

``pallas-blockspec``: a ``pl.BlockSpec`` index map must be a pure
function of its grid indices (plus static python ints like block
counts).  Referencing a *traced* value — a kernel operand or anything
derived from one — in the index map is a correctness bug Pallas reports
obscurely (or not at all in interpret mode).  The rule tracks which
names in the enclosing function are traced (non-static jit params and
values derived from them; shape-tuple unpacking yields static ints) and
flags index-map closures over them.

``pallas-interpret``: every ``pl.pallas_call`` and every ``_pallas*``
kernel entry invoked from a ``kernels/*/ops.py`` dispatcher must plumb
``interpret=`` through explicitly, and every public ``*_op`` wrapper
must accept it — CPU validation (``tests/test_kernels.py``, the parity
matrix) relies on forcing interpret mode from the outside; a dropped
kwarg silently pins the kernel to the default and the parity tests stop
testing what ships.
"""
from __future__ import annotations

import ast
import builtins
from typing import List, Optional, Set

from repro.analysis.astutil import (call_name, param_names, static_argnames)
from repro.analysis.lint import Finding, SourceFile, register

_BUILTINS = set(dir(builtins))
_STATIC_ATTRS = ("shape", "size", "ndim", "dtype")


def _module_names(tree: ast.Module) -> Set[str]:
    names: Set[str] = set()
    for node in tree.body:
        if isinstance(node, ast.Import):
            names.update(a.asname or a.name.split(".")[0] for a in node.names)
        elif isinstance(node, ast.ImportFrom):
            names.update(a.asname or a.name for a in node.names)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            names.add(node.name)
        else:
            for tgt in ast.walk(node):
                if isinstance(tgt, ast.Name) and \
                        isinstance(tgt.ctx, ast.Store):
                    names.add(tgt.id)
    return names


def _is_static_value(value: ast.AST) -> bool:
    """Shape/metadata math is static even when rooted at traced names."""
    for node in ast.walk(value):
        if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
            return True
        if isinstance(node, ast.Call) and call_name(node) == "len":
            return True
    return False


def _traced_names(fn) -> Set[str]:
    """Names in ``fn`` holding traced arrays: non-static params plus
    simple derivations of them."""
    traced = set(param_names(fn)) - static_argnames(fn)
    for stmt in ast.walk(fn):
        if not isinstance(stmt, ast.Assign):
            continue
        value = stmt.value
        static = _is_static_value(value) or isinstance(value, ast.Constant)
        mentions = {n.id for n in ast.walk(value)
                    if isinstance(n, ast.Name)}
        for tgt in stmt.targets:
            for name_node in ast.walk(tgt):
                if isinstance(name_node, ast.Name):
                    if not static and mentions & traced:
                        traced.add(name_node.id)
    return traced


def _index_map(call: ast.Call) -> Optional[ast.AST]:
    if len(call.args) >= 2:
        return call.args[1]
    for kw in call.keywords:
        if kw.arg == "index_map":
            return kw.value
    return None


@register("pallas-blockspec",
          "BlockSpec index maps are pure in their grid indices — no "
          "closure over traced values",
          paths=("src/repro/kernels/*",))
def check_pallas_blockspec(sf: SourceFile) -> List[Finding]:
    out = []
    module_names = _module_names(sf.tree)
    for fn in [n for n in ast.walk(sf.tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        traced = _traced_names(fn)
        local_defs = {d.name: d for d in ast.walk(fn)
                      if isinstance(d, ast.FunctionDef)}
        for call in [n for n in ast.walk(fn) if isinstance(n, ast.Call)]:
            if (call_name(call) or "").rsplit(".", 1)[-1] != "BlockSpec":
                continue
            imap = _index_map(call)
            if imap is None:
                continue
            if isinstance(imap, ast.Lambda):
                params, body = {a.arg for a in imap.args.args}, imap.body
            elif isinstance(imap, ast.Name) and imap.id in local_defs:
                d = local_defs[imap.id]
                params, body = set(param_names(d)), d
            else:
                continue
            for name in [n for n in ast.walk(body)
                         if isinstance(n, ast.Name)
                         and isinstance(n.ctx, ast.Load)]:
                if name.id in params or name.id in _BUILTINS or \
                        name.id in module_names:
                    continue
                if name.id in traced:
                    out.append(Finding(
                        "pallas-blockspec", sf.path, call.lineno,
                        f"BlockSpec index map references traced value "
                        f"`{name.id}` — index maps must be pure in the "
                        f"grid indices (static ints are fine)"))
    return out


@register("pallas-interpret",
          "pl.pallas_call and _pallas* dispatch calls plumb interpret= "
          "through; *_op wrappers accept it",
          paths=("src/repro/kernels/*",))
def check_pallas_interpret(sf: SourceFile) -> List[Finding]:
    out = []
    is_ops = sf.path.endswith("/ops.py")
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Call):
            name = call_name(node) or ""
            kws = {kw.arg for kw in node.keywords}
            if name.rsplit(".", 1)[-1] == "pallas_call" and \
                    "interpret" not in kws:
                out.append(Finding(
                    "pallas-interpret", sf.path, node.lineno,
                    "pl.pallas_call without interpret= — CPU validation "
                    "cannot force interpret mode"))
            elif is_ops and name.startswith("_pallas") and \
                    "interpret" not in kws and None not in kws:
                out.append(Finding(
                    "pallas-interpret", sf.path, node.lineno,
                    f"`{name}(...)` drops interpret= — the ops dispatcher "
                    f"must plumb it through to the kernel"))
        if is_ops and isinstance(node, ast.FunctionDef) and \
                node.name.endswith("_op") and \
                "interpret" not in param_names(node):
            out.append(Finding(
                "pallas-interpret", sf.path, node.lineno,
                f"public wrapper `{node.name}` does not accept "
                f"interpret= — parity tests cannot reach the kernel"))
    return out
