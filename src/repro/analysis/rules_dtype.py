"""dtype drift (L3).

``dtype-widen``: device code in this repo is fp32 (accumulators) / bf16
(wire, kernels io) by contract — DESIGN.md §5/§9.  Requesting a 64-bit
dtype from ``jnp`` constructors or ``.astype(float)`` (python ``float``
is float64 under x64) silently doubles memory and wrecks the Pallas
kernels' tiling assumptions the moment ``jax_enable_x64`` flips on.
Host-side ``np.float64`` (the metric logs) is deliberately exempt — the
rule only matches ``jnp`` constructors and bare ``.astype``.

``collective-cast-order``: casting the *result* of a ``psum``/``pmean``
to a narrow dtype means the collective itself already moved full-width
bytes — the exact bug PR 5 fixed in ``train/compress.py`` (cast must
happen *before* the reduce for the documented 2x wire saving to be
true).  Widening casts after the reduce (bf16 -> fp32 upcast) are the
correct pattern and are not flagged.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.astutil import call_name
from repro.analysis.lint import Finding, SourceFile, register

_WIDE = {"float64", "f64", "double"}
_NARROW = {"bfloat16", "float16", "f16", "bf16", "int8", "float8_e4m3fn",
           "float8_e5m2"}
_JNP_CTORS = {"zeros", "ones", "full", "empty", "array", "asarray",
              "arange", "linspace", "zeros_like", "ones_like", "full_like"}
_COLLECTIVES = {"psum", "pmean", "pmax", "pmin", "all_gather",
                "psum_scatter", "all_to_all"}


def _dtype_token(node: ast.AST) -> Optional[str]:
    """'float64' for np.float64 / jnp.float64 / "float64" / float."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.Name):
        return "float64" if node.id == "float" else node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _dtype_args(call: ast.Call):
    """(expr, token) candidates for the dtype argument of ``call``."""
    out = []
    for kw in call.keywords:
        if kw.arg == "dtype":
            out.append((kw.value, _dtype_token(kw.value)))
    name = call_name(call) or ""
    ctor = name.rsplit(".", 1)[-1]
    # positional dtype: jnp.asarray(x, float64-ish), jnp.zeros(shape, dt)
    if ctor in _JNP_CTORS and len(call.args) >= 2:
        out.append((call.args[1], _dtype_token(call.args[1])))
    return out


@register("dtype-widen",
          "no float64 / python-float dtypes in jnp constructors or "
          ".astype on device paths (fp32/bf16 contract, DESIGN §5)")
def check_dtype_widen(sf: SourceFile) -> List[Finding]:
    out = []
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node) or ""
        is_astype = (isinstance(node.func, ast.Attribute)
                     and node.func.attr == "astype")
        if is_astype and node.args:
            tok = _dtype_token(node.args[0])
            if tok in _WIDE:
                out.append(Finding(
                    "dtype-widen", sf.path, node.lineno,
                    f"`.astype({ast.unparse(node.args[0])})` widens to "
                    f"float64 — device accumulators are fp32 by contract"))
            continue
        if name.startswith(("jnp.", "jax.numpy.")):
            for expr, tok in _dtype_args(node):
                if tok in _WIDE:
                    out.append(Finding(
                        "dtype-widen", sf.path, node.lineno,
                        f"`{name}(... dtype={ast.unparse(expr)})` "
                        f"requests a 64-bit device array — keep device "
                        f"state fp32/bf16 (host metrics may use np.float64)"))
    return out


@register("collective-cast-order",
          "narrow casts happen before psum/pmean, never on the reduced "
          "result (the collective must move the narrow bytes)")
def check_collective_cast_order(sf: SourceFile) -> List[Finding]:
    out = []
    for node in ast.walk(sf.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "astype" and node.args):
            continue
        recv = node.func.value
        if not isinstance(recv, ast.Call):
            continue
        rname = call_name(recv) or ""
        if rname.rsplit(".", 1)[-1] not in _COLLECTIVES:
            continue
        tok = _dtype_token(node.args[0])
        if tok in _NARROW:
            out.append(Finding(
                "collective-cast-order", sf.path, node.lineno,
                f"`{rname}(...).astype({ast.unparse(node.args[0])})` "
                f"narrows *after* the reduce — the wire already moved "
                f"full-width bytes; cast the operand before the "
                f"collective (train/compress.py shows the pattern)"))
    return out
