"""Level-1 static analysis: AST lints over the repo's implicit invariants.

Nine PRs of engine work rest on conventions — no host syncs inside
jitted scan bodies, PRNG keys never reused, collectives cast *before*
the reduce, Pallas index maps pure in their grid arguments — that were
each hand-asserted once and can silently rot.  This module is the small
framework that turns them into machine-enforced rules:

  * a rule registry (``@register``); each rule is a pure function from
    a parsed source file (or the repo, for cross-file rules) to
    ``Finding``s;
  * per-line / per-file suppression via ``# repro: noqa[rule-name]``
    followed by a mandatory one-line justification (bare suppressions
    are themselves a lint error — see ``noqa-hygiene``);
  * human and JSON output (stable schema, ``JSON_SCHEMA_VERSION``);
  * a CLI (``python -m repro.analysis``) wired into ``make
    check-static`` which the default ``make test-fast`` path runs.

Rules live in ``rules_*.py`` siblings; ``analysis/contracts.py`` holds
the level-2 compiled-artifact checkers (HLO / retrace / donation).
Adding a rule: write ``def check(file: SourceFile) -> list[Finding]``,
decorate with ``@register("my-rule", "one-line doc")``, import the
module from ``repro.analysis`` so registration runs, and document it in
``docs/DESIGN.md`` §11 (``tests/test_docs.py`` keeps the catalog in
sync with this registry).
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import json
import re
import sys
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence

JSON_SCHEMA_VERSION = 1

# suppression syntax: a comment of the form
#     "repro: noqa[rule-a,rule-b] -- why this is deliberate"
_NOQA_RE = re.compile(r"#\s*repro:\s*noqa\[([^\]]*)\](.*)$")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str          # repo-relative, '/'-separated
    line: int          # 1-indexed
    message: str

    def to_dict(self) -> Dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message}

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclasses.dataclass
class SourceFile:
    """A parsed python file handed to AST rules."""
    path: str                    # repo-relative
    text: str
    tree: ast.Module

    @property
    def lines(self) -> List[str]:
        return self.text.splitlines()


@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    doc: str                     # one-line, surfaced in --list / DESIGN §11
    check: Callable              # SourceFile -> List[Finding]
    scope: str = "python"        # "python" (per .py file) | "repo" (once)
    paths: Sequence[str] = ()    # fnmatch globs; empty = every file in scope


_REGISTRY: Dict[str, Rule] = {}


def register(name: str, doc: str, *, scope: str = "python",
             paths: Sequence[str] = ()):
    def deco(fn):
        if name in _REGISTRY:
            raise ValueError(f"duplicate rule {name!r}")
        _REGISTRY[name] = Rule(name=name, doc=doc, check=fn, scope=scope,
                               paths=tuple(paths))
        return fn
    return deco


def all_rules() -> Dict[str, Rule]:
    # import for the registration side effect; cheap and idempotent
    from repro.analysis import (rules_docs, rules_dtype,  # noqa: F401
                                rules_host_sync, rules_pallas, rules_prng)
    return dict(_REGISTRY)


# ---------------------------------------------------------------------------
# suppression
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Suppression:
    line: int
    rules: List[str]
    justified: bool


def parse_suppressions(text: str) -> List[Suppression]:
    """Suppressions live in real COMMENT tokens only — a docstring that
    *mentions* the noqa syntax (this module's own, say) is not one."""
    import io
    import tokenize
    out = []
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(text).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        tokens = None
    if tokens is None:          # non-parseable: fall back to line regex
        comments = [(i, line) for i, line in
                    enumerate(text.splitlines(), start=1)]
    else:
        comments = [(t.start[0], t.string) for t in tokens
                    if t.type == tokenize.COMMENT]
    for i, comment in comments:
        m = _NOQA_RE.search(comment)
        if m:
            rules = [r.strip() for r in m.group(1).split(",") if r.strip()]
            just = m.group(2).strip().lstrip("-—: ").strip()
            out.append(Suppression(line=i, rules=rules, justified=bool(just)))
    return out


def _is_suppressed(f: Finding, sups: List[Suppression]) -> bool:
    for s in sups:
        if f.rule in s.rules and (s.line == f.line or s.line == 1):
            return True           # same line, or file-level (line 1) noqa
    return False


def check_noqa_hygiene(path: str, text: str,
                       known: Sequence[str]) -> List[Finding]:
    """``noqa-hygiene``: every suppression must name a registered rule and
    carry an inline justification — a bare ``# repro: noqa[x]`` hides a
    finding without recording *why* the exception is deliberate."""
    out = []
    for s in parse_suppressions(text):
        for r in s.rules:
            if r not in known:
                out.append(Finding("noqa-hygiene", path, s.line,
                                   f"suppression names unknown rule {r!r}"))
        if not s.rules:
            out.append(Finding("noqa-hygiene", path, s.line,
                               "suppression lists no rules"))
        if not s.justified:
            out.append(Finding(
                "noqa-hygiene", path, s.line,
                "suppression lacks a justification (write `# repro: "
                "noqa[rule] -- why this sync/cast/... is deliberate`)"))
    return out


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

def _rule_applies(rule: Rule, relpath: str) -> bool:
    if not rule.paths:
        return True
    return any(fnmatch.fnmatch(relpath, pat) for pat in rule.paths)


def iter_python_files(root: Path) -> List[Path]:
    return sorted((root / "src" / "repro").rglob("*.py"))


def run_lint(root: Path, rules: Optional[Dict[str, Rule]] = None,
             files: Optional[Sequence[Path]] = None) -> List[Finding]:
    """Run ``rules`` (default: full registry) over the tree at ``root``.

    ``files`` narrows the python-scope rules to an explicit list (used by
    the fixture tests); repo-scope rules always see the whole root.
    Suppressions are applied here, *after* rule execution, so rules stay
    oblivious to the mechanism; ``noqa-hygiene`` runs over every scanned
    file regardless of the selected rule subset.
    """
    rules = all_rules() if rules is None else rules
    known = sorted(all_rules())
    py_files = list(files) if files is not None else iter_python_files(root)

    findings: List[Finding] = []
    for path in py_files:
        rel = path.relative_to(root).as_posix()
        text = path.read_text()
        try:
            tree = ast.parse(text, filename=str(path))
        except SyntaxError as e:
            findings.append(Finding("syntax", rel, e.lineno or 1, str(e)))
            continue
        sf = SourceFile(path=rel, text=text, tree=tree)
        sups = parse_suppressions(text)
        for rule in rules.values():
            if rule.scope != "python" or not _rule_applies(rule, rel):
                continue
            for f in rule.check(sf):
                if not _is_suppressed(f, sups):
                    findings.append(f)
        if "noqa-hygiene" in rules:
            findings.extend(check_noqa_hygiene(rel, text, known))
    for rule in rules.values():
        if rule.scope == "repo":
            findings.extend(rule.check(root))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


# `noqa-hygiene` registers through the same decorator so it shows up in
# the catalog, but its real implementation runs inside `run_lint` (it
# must see suppression comments, which are stripped before rules do).
register("noqa-hygiene",
         "every `# repro: noqa[rule]` names a known rule and carries an "
         "inline justification")(lambda sf: [])


def to_json(findings: Sequence[Finding],
            rules: Optional[Dict[str, Rule]] = None) -> Dict:
    rules = all_rules() if rules is None else rules
    counts: Dict[str, int] = {}
    for f in findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    return {
        "version": JSON_SCHEMA_VERSION,
        "rules": sorted(rules),
        "findings": [f.to_dict() for f in findings],
        "counts": counts,
    }


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific static lints (level 1 of repro.analysis)")
    p.add_argument("--root", default=".", help="repo root (default: cwd)")
    p.add_argument("--json", action="store_true", help="machine output")
    p.add_argument("--list", action="store_true", dest="list_rules",
                   help="print the rule catalog and exit")
    p.add_argument("--rule", action="append", default=None,
                   help="run only these rules (repeatable)")
    args = p.parse_args(argv)

    rules = all_rules()
    if args.list_rules:
        for name in sorted(rules):
            print(f"{name}: {rules[name].doc}")
        return 0
    if args.rule:
        unknown = set(args.rule) - set(rules)
        if unknown:
            p.error(f"unknown rule(s): {sorted(unknown)}")
        rules = {n: rules[n] for n in args.rule}

    findings = run_lint(Path(args.root).resolve(), rules=rules)
    if args.json:
        print(json.dumps(to_json(findings, rules), indent=2))
    else:
        for f in findings:
            print(f)
        print(f"check-static: {len(findings)} finding(s), "
              f"{len(rules)} rule(s) active")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
