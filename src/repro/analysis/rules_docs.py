"""Bench/docs drift (L5): every `BENCH_*.json` key the docs cite must
actually be emitted.

README.md and docs/DESIGN.md quote benchmark-artifact keys
(`guard_on_over_off`, `{host,scan}_steps_per_s`, ...) as evidence for
perf claims.  When a benchmark renames a key, the prose silently keeps
promising a number nobody produces.  This rule cross-checks every
backticked snake_case token in a paragraph that mentions a
``BENCH_*.json`` artifact against (a) the keys of the committed
artifacts at the repo root (recursively flattened) and (b) string
literals in ``benchmarks/*.py`` — and checks that every concretely
named artifact exists or is emitted by a benchmark.

Doc shorthand is expanded: ``{host,scan}_steps_per_s`` tries both
alternatives, ``*_req_per_s_best`` and ``<arch>_steps_per_s`` are
treated as globs that must match at least one real key.  Extends
``make docs-check`` (``tests/test_docs.py`` runs this rule as a test).
"""
from __future__ import annotations

import ast
import fnmatch
import itertools
import json
import re
from pathlib import Path
from typing import List, Set

from repro.analysis.lint import Finding, register

_DOCS = ("README.md", "docs/DESIGN.md")
_BENCH_RE = re.compile(r"BENCH_[\w*]+\.json")
_TOKEN_RE = re.compile(r"`([a-z0-9_{},*<>]*_[a-z0-9_{},*<>]*)`")
_BRACE_RE = re.compile(r"\{([^{}]*)\}")
# only tokens shaped like benchmark keys are checked — prose in a bench
# paragraph also backticks function and config names, which are the
# path-reference checker's problem (tests/test_docs.py), not ours
_KEY_SUFFIXES = ("_per_s", "_ms", "_bytes", "_speedup", "_best")


def _is_key_shaped(token: str) -> bool:
    return "_over_" in token or token.endswith(_KEY_SUFFIXES)


def _flatten_keys(obj, out: Set[str]):
    if isinstance(obj, dict):
        for k, v in obj.items():
            if isinstance(k, str):
                out.add(k)
            _flatten_keys(v, out)
    elif isinstance(obj, list):
        for v in obj:
            _flatten_keys(v, out)


def _emitted_keys(root: Path) -> Set[str]:
    keys: Set[str] = set()
    for artifact in root.glob("BENCH_*.json"):
        try:
            _flatten_keys(json.loads(artifact.read_text()), keys)
        except (json.JSONDecodeError, OSError):
            continue
    for src in (root / "benchmarks").glob("**/*.py"):
        try:
            tree = ast.parse(src.read_text())
        except (SyntaxError, OSError):
            continue
        for node in ast.walk(tree):
            if isinstance(node, ast.Constant) and isinstance(node.value, str):
                keys.add(node.value)
    return keys


def _expand_braces(token: str) -> List[str]:
    groups = _BRACE_RE.findall(token)
    if not groups:
        return [token]
    template = _BRACE_RE.sub("{}", token)
    return [template.format(*combo)
            for combo in itertools.product(*(g.split(",") for g in groups))]


def _paragraphs(text: str):
    start, block = 1, []
    for i, line in enumerate(text.splitlines(), start=1):
        if line.strip():
            if not block:
                start = i
            block.append(line)
        elif block:
            yield start, "\n".join(block)
            block = []
    if block:
        yield start, "\n".join(block)


@register("bench-docs-drift",
          "every BENCH_*.json key cited in README/DESIGN is emitted by a "
          "benchmark; every named artifact exists",
          scope="repo")
def check_bench_docs_drift(root: Path) -> List[Finding]:
    emitted = _emitted_keys(root)
    bench_sources = "\n".join(
        p.read_text() for p in (root / "benchmarks").glob("**/*.py"))
    out: List[Finding] = []
    for rel in _DOCS:
        doc = root / rel
        if not doc.exists():
            continue
        for lineno, para in _paragraphs(doc.read_text()):
            mentions = set(_BENCH_RE.findall(para))
            if not mentions:
                continue
            for artifact in mentions:
                if "*" in artifact:
                    continue
                if not (root / artifact).exists() and \
                        artifact not in bench_sources:
                    out.append(Finding(
                        "bench-docs-drift", rel, lineno,
                        f"doc cites `{artifact}` but no such artifact "
                        f"exists and no benchmark emits it"))
            for raw in _TOKEN_RE.findall(para):
                if not _is_key_shaped(raw):
                    continue
                candidates = _expand_braces(raw)
                globby = [c.replace("<arch>", "*").replace("<name>", "*")
                          for c in candidates]
                ok = any(
                    (("*" in g and fnmatch.filter(emitted, g)) or g in emitted)
                    for g in globby)
                if not ok:
                    out.append(Finding(
                        "bench-docs-drift", rel, lineno,
                        f"doc cites bench key `{raw}` but no committed "
                        f"BENCH_*.json artifact or benchmarks/*.py source "
                        f"emits it"))
    return out
