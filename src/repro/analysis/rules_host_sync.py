"""Host-sync lints (L1).

``host-sync-jit``: a host synchronization (``float()``/``int()``/
``bool()`` on array data, ``.item()``, ``np.asarray``, ``jax.device_get``)
inside a function that is traced — jit-decorated, passed to
``lax.scan``/``vmap``/``grad``/``shard_map``, or (same-module) called
from one.  These either raise ``ConcretizationTypeError`` at trace time
or, worse, silently constant-fold a value that should be traced.

``host-sync-loop``: a per-element device fetch inside a host-side
``for``/``while`` loop — e.g. ``np.asarray(pool[slot])`` per iteration,
which dispatches a gather and a D2H transfer every pass when one fetch
of the whole array outside the loop would do.  This is the pattern that
throttles the serving sweep and the epoch boundary, so it is scoped to
``train/``, ``serve/`` and ``core/``.  Deliberate sync points (the host
parity oracle, the documented once-per-sweep fetch) carry
``# repro: noqa[host-sync-loop]`` with a justification.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from repro.analysis.astutil import (HOST_BUILTINS, assign_targets, call_name,
                                    decorator_names, dotted, root_name)
from repro.analysis.lint import Finding, SourceFile, register

SYNC_BUILTINS = {"float", "int", "bool"}
NP_SYNCS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
            "onp.asarray", "onp.array"}
DEVICE_GET = {"jax.device_get", "device_get"}

# decorators / callables whose function argument is traced
_JIT_DECOS = {"jax.jit", "jit", "pjit", "jax.pjit", "jax.checkpoint",
              "jax.remat", "jax.custom_vjp", "jax.custom_jvp"}
_TRACING_CALLS = _JIT_DECOS | {
    "jax.lax.scan", "lax.scan", "jax.lax.map", "lax.map",
    "jax.lax.cond", "lax.cond", "jax.lax.switch", "lax.switch",
    "jax.lax.while_loop", "lax.while_loop",
    "jax.lax.fori_loop", "lax.fori_loop",
    "jax.vmap", "vmap", "jax.grad", "jax.value_and_grad",
    "shard_map", "jax.experimental.shard_map.shard_map",
}


def _own_nodes(fn) -> List[ast.AST]:
    """``ast.walk(fn)`` minus everything owned by nested function defs —
    nested defs are analyzed as functions in their own right."""
    skip: Set[int] = set()
    for d in ast.walk(fn):
        if isinstance(d, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.Lambda)) and d is not fn:
            skip.update(id(x) for x in ast.walk(d))
    return [n for n in ast.walk(fn) if id(n) not in skip or n is fn]


def _is_shape_math(expr: ast.AST) -> bool:
    """True when the expression only touches static shape metadata."""
    saw_meta = False
    for node in ast.walk(expr):
        if isinstance(node, ast.Attribute) and node.attr in ("shape", "size",
                                                            "ndim", "dtype"):
            saw_meta = True
        if isinstance(node, ast.Call):
            name = call_name(node)
            if name == "len" or (name or "").startswith(("np.", "numpy.")):
                saw_meta = True
    return saw_meta


def _sync_calls(nodes) -> List[ast.Call]:
    out = []
    for node in nodes:
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        if name in SYNC_BUILTINS and len(node.args) == 1 and \
                not isinstance(node.args[0], ast.Constant) and \
                not _is_shape_math(node.args[0]):
            out.append(node)
        elif name in NP_SYNCS and node.args and \
                not _is_shape_math(node.args[0]):
            out.append(node)
        elif name in DEVICE_GET:
            out.append(node)
        elif isinstance(node.func, ast.Attribute) and \
                node.func.attr == "item" and not node.args:
            out.append(node)
    return out


def _device_functions(tree: ast.Module) -> Set[ast.AST]:
    """Functions traced by jax: jit-decorated, passed to a tracing call,
    nested inside one of those, or (same-module, by bare name) called
    from one — the transitive closure."""
    defs = [n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    by_name: Dict[str, List[ast.AST]] = {}
    for d in defs:
        by_name.setdefault(d.name, []).append(d)

    roots: Set[ast.AST] = set()
    for d in defs:
        if set(decorator_names(d)) & _JIT_DECOS:
            roots.add(d)
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and call_name(node) in _TRACING_CALLS:
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, ast.Name):
                    roots.update(by_name.get(arg.id, []))
                elif isinstance(arg, ast.Call):
                    # functools.partial(step_fn, ...) and friends
                    inner = arg.args[0] if arg.args else None
                    if isinstance(inner, ast.Name):
                        roots.update(by_name.get(inner.id, []))

    device: Set[ast.AST] = set()
    frontier = list(roots)
    while frontier:
        fn = frontier.pop()
        if fn in device:
            continue
        device.add(fn)
        for node in ast.walk(fn):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not fn and node not in device:
                frontier.append(node)
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
                for callee in by_name.get(node.func.id, []):
                    if callee not in device:
                        frontier.append(callee)
    return device


@register("host-sync-jit",
          "no float()/int()/bool()/.item()/np.asarray/device_get on array "
          "data inside jit- or scan-traced functions")
def check_host_sync_jit(sf: SourceFile) -> List[Finding]:
    out = []
    seen = set()
    for fn in _device_functions(sf.tree):
        for call in _sync_calls(_own_nodes(fn)):
            if id(call) in seen:
                continue
            seen.add(id(call))
            out.append(Finding(
                "host-sync-jit", sf.path, call.lineno,
                f"host sync `{ast.unparse(call)[:60]}` inside traced "
                f"function `{fn.name}` — hoist it out of the jitted path"))
    return out


# -- host-sync-loop ---------------------------------------------------------

_HOST_PRODUCERS = ("np.", "numpy.", "time.", "os.", "math.", "re.", "json.")


def _host_names(fn) -> Set[str]:
    """Names that (somewhere in ``fn``) hold host values: assigned from
    numpy/builtin/python-literal expressions, or loop targets over them."""
    host: Set[str] = set()

    def value_is_host(v: ast.AST) -> bool:
        if isinstance(v, (ast.Constant, ast.ListComp, ast.DictComp,
                          ast.SetComp, ast.List, ast.Dict, ast.Set,
                          ast.JoinedStr)):
            return True
        if isinstance(v, ast.Call):
            name = call_name(v) or ""
            if name in HOST_BUILTINS or name.startswith(_HOST_PRODUCERS):
                return True
            if isinstance(v.func, ast.Attribute):
                # a method call on a host value stays host:
                # np.asarray(x).reshape(-1), host_list.index(k), ...
                return value_is_host(v.func.value)
            return False
        if isinstance(v, (ast.Subscript, ast.Attribute)):
            return value_is_host(v.value)
        if isinstance(v, ast.Name):
            return v.id in host
        if isinstance(v, ast.BinOp):
            return value_is_host(v.left) and value_is_host(v.right)
        if isinstance(v, ast.Compare):
            return value_is_host(v.left) and \
                all(value_is_host(c) for c in v.comparators)
        if isinstance(v, ast.BoolOp):
            return all(value_is_host(x) for x in v.values)
        if isinstance(v, ast.UnaryOp):
            return value_is_host(v.operand)
        if isinstance(v, ast.IfExp):
            return value_is_host(v.body) and value_is_host(v.orelse)
        if isinstance(v, (ast.Tuple,)):
            return all(value_is_host(e) for e in v.elts)
        return False

    # two passes so `a = np.asarray(x); b = a[i]` marks both
    for _ in range(2):
        for node in ast.walk(fn):
            for name, value in assign_targets(node):
                if value_is_host(value):
                    host.add(name)
            if isinstance(node, ast.For) and value_is_host(node.iter):
                for tgt in ast.walk(node.target):
                    if isinstance(tgt, ast.Name):
                        host.add(tgt.id)
    return host


def _device_fetch_in(expr: ast.AST, host: Set[str]) -> bool:
    """Does ``expr`` reach into device data: a subscript of a non-host
    array, or a method call / jnp call producing a device value?  Descent
    is pruned inside host-producing calls (np.*, len, ...)."""

    def walk(node) -> bool:
        if isinstance(node, ast.Call):
            name = call_name(node) or ""
            if name in HOST_BUILTINS or name.startswith(_HOST_PRODUCERS):
                return False                     # host call: don't descend
            if name.startswith(("jnp.", "jax.")):
                return True
            if isinstance(node.func, ast.Attribute):
                root = root_name(node.func)
                if root is not None and root not in host and \
                        root not in ("np", "numpy", "math", "time", "os"):
                    return True                  # method call on device value
            return any(walk(c) for c in ast.iter_child_nodes(node))
        if isinstance(node, ast.Subscript):
            root = root_name(node.value)
            if root is not None and root not in host:
                return True
            return walk(node.slice)
        return any(walk(c) for c in ast.iter_child_nodes(node))

    return walk(expr)


@register("host-sync-loop",
          "no per-iteration device fetch (np.asarray(pool[i]), "
          "float(metrics[k]), x.item()) inside host for/while loops",
          paths=("src/repro/train/*", "src/repro/serve/*",
                 "src/repro/core/*"))
def check_host_sync_loop(sf: SourceFile) -> List[Finding]:
    out = []
    device_fns = _device_functions(sf.tree)
    for fn in ast.walk(sf.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if fn in device_fns:
            continue                   # host-sync-jit owns traced functions
        host = _host_names(fn)
        own = _own_nodes(fn)
        own_ids = {id(n) for n in own}
        loops = [n for n in own if isinstance(n, (ast.For, ast.While))]
        seen = set()
        for loop in loops:
            in_loop = [n for n in ast.walk(loop) if id(n) in own_ids]
            for call in _sync_calls(in_loop):
                if id(call) in seen:
                    continue
                seen.add(id(call))
                payload = call.func.value if (
                    isinstance(call.func, ast.Attribute) and
                    call.func.attr == "item") else call.args[0]
                if _device_fetch_in(payload, host):
                    out.append(Finding(
                        "host-sync-loop", sf.path, call.lineno,
                        f"per-iteration device fetch "
                        f"`{ast.unparse(call)[:60]}` — fetch the array "
                        f"once outside the loop (or justify with noqa)"))
    return out
