"""repro.analysis — static contracts for the engine invariants.

Level 1 (``lint``): repo-specific AST lints, run by ``make
check-static`` (``python -m repro.analysis``) and self-tested by
``tests/test_analysis.py``.

Level 2 (``contracts``): reusable checkers over the *compiled
artifacts* of the real engine builds — retrace-freedom, carry donation,
no host transfers, collective wire width — imported by the engine tests
in place of ad-hoc HLO string greps.

Catalog and policy: ``docs/DESIGN.md`` §11.
"""
from repro.analysis.lint import (Finding, JSON_SCHEMA_VERSION, Rule,
                                 all_rules, run_lint, to_json)

__all__ = ["Finding", "JSON_SCHEMA_VERSION", "Rule", "all_rules",
           "run_lint", "to_json"]
