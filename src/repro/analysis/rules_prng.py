"""PRNG discipline (L2): a key consumed twice is a correlated-stream bug.

A key is *consumed* when passed to ``jax.random.split`` or to any
sampler (``normal``, ``randint``, ``categorical``, ...).  Re-using the
same consumed key in another sampler/split call silently draws
correlated randomness — the classic form is sampling with ``key`` in a
loop without re-deriving it each iteration.  ``fold_in`` (and
``PRNGKey``/``key``) are derivation, not consumption: fanning several
``fold_in(key, i)`` streams off one base key is the sanctioned idiom
(``train/loop.py`` does exactly this) and is never flagged.

Tracked key expressions are bare names (``key``) and constant-index
subscripts (``ks[0]``); reassignment of the name resets it.  Branches
of an ``if`` are analyzed independently and merged conservatively; loop
bodies are analyzed twice so a consumption surviving to the next
iteration is caught.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from repro.analysis.astutil import assign_targets, call_name
from repro.analysis.lint import Finding, SourceFile, register

_DERIVERS = {"split", "fold_in", "PRNGKey", "key", "wrap_key_data",
             "clone"}
_RANDOM_PREFIXES = ("jax.random.", "random.", "jrandom.", "jr.")


def _random_fn(call: ast.Call) -> Optional[str]:
    """'split' / 'normal' / ... for a jax.random call, else None."""
    name = call_name(call)
    if not name:
        return None
    for pre in _RANDOM_PREFIXES:
        if name.startswith(pre):
            return name[len(pre):]
    return None


def _key_expr(call: ast.Call) -> Optional[str]:
    """Canonical text of the key argument when it is trackable."""
    arg = None
    if call.args:
        arg = call.args[0]
    else:
        for kw in call.keywords:
            if kw.arg == "key":
                arg = kw.value
    if isinstance(arg, ast.Name):
        return arg.id
    if isinstance(arg, ast.Subscript) and \
            isinstance(arg.value, ast.Name) and \
            isinstance(arg.slice, ast.Constant):
        return f"{arg.value.id}[{arg.slice.value!r}]"
    return None


class _Scope:
    """consumed: key expr -> line of the consuming call."""

    def __init__(self, consumed: Optional[Dict[str, int]] = None):
        self.consumed: Dict[str, int] = dict(consumed or {})
        self.findings: List[Finding] = []

    def copy(self) -> "_Scope":
        s = _Scope(self.consumed)
        s.findings = self.findings      # shared sink
        return s

    def reset_name(self, name: str):
        for k in [k for k in self.consumed
                  if k == name or k.startswith(name + "[")]:
            del self.consumed[k]


def _scan_expr(node: ast.AST, scope: _Scope, path: str):
    for call in [n for n in ast.walk(node) if isinstance(n, ast.Call)]:
        fn = _random_fn(call)
        if fn is None or (fn in _DERIVERS and fn != "split"):
            continue                   # fold_in/PRNGKey derive, not consume
        key = _key_expr(call)
        if key is None:
            continue
        prev = scope.consumed.get(key)
        if prev is not None:
            where = (f"already consumed at line {prev}"
                     if prev != call.lineno
                     else "re-consumed on every loop iteration")
            scope.findings.append(Finding(
                "key-reuse", path, call.lineno,
                f"key `{key}` {where} is passed to jax.random.{fn} — "
                f"split or fold_in first"))
        else:
            scope.consumed[key] = call.lineno


def _scan_block(stmts, scope: _Scope, path: str):
    for stmt in stmts:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue                       # own scope, analyzed separately
        if isinstance(stmt, ast.If):
            _scan_expr(stmt.test, scope, path)
            a, b = scope.copy(), scope.copy()
            _scan_block(stmt.body, a, path)
            _scan_block(stmt.orelse, b, path)
            scope.consumed = {**a.consumed, **b.consumed}
            continue
        if isinstance(stmt, (ast.For, ast.While)):
            if isinstance(stmt, ast.For):
                _scan_expr(stmt.iter, scope, path)
            else:
                _scan_expr(stmt.test, scope, path)
            # two passes: pass 1 (findings discarded) computes the
            # consumed-set surviving one iteration; pass 2 reports — so a
            # key consumed each iteration without re-derivation is caught
            first = _Scope(scope.consumed)
            _scan_block(stmt.body, first, path)
            second = _Scope(first.consumed)
            second.findings = scope.findings
            _scan_block(stmt.body, second, path)
            scope.consumed = second.consumed
            _scan_block(stmt.orelse, scope, path)
            continue
        if isinstance(stmt, (ast.With,)):
            for item in stmt.items:
                _scan_expr(item.context_expr, scope, path)
            _scan_block(stmt.body, scope, path)
            continue
        if isinstance(stmt, ast.Try):
            _scan_block(stmt.body, scope, path)
            for h in stmt.handlers:
                _scan_block(h.body, scope.copy(), path)
            _scan_block(stmt.finalbody, scope, path)
            continue
        # simple statement: consumption scan, then reassignment resets
        _scan_expr(stmt, scope, path)
        for name, _value in assign_targets(stmt):
            scope.reset_name(name)
    return scope


@register("key-reuse",
          "a PRNG key consumed by split()/a sampler is never passed to "
          "another sampler without an intervening split/fold_in")
def check_key_reuse(sf: SourceFile) -> List[Finding]:
    scope = _Scope()
    for node in ast.walk(sf.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fn_scope = _Scope()
            fn_scope.findings = scope.findings
            _scan_block(node.body, fn_scope, sf.path)
    # deduplicate (nested walks can visit a function twice)
    uniq, seen = [], set()
    for f in scope.findings:
        k = (f.line, f.message)
        if k not in seen:
            seen.add(k)
            uniq.append(f)
    return uniq
