"""Serving demo: one-shot batched decode plus the continuous-batching
slot engine (per-slot KV caches, admit/evict between jitted scans).

  PYTHONPATH=src python examples/serve_lm.py --arch gemma3-27b-smoke
      [--batch 4] [--prompt-len 16] [--new 24] [--temperature 0.7]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.api import build_model
from repro.serve.engine import Request, SlotEngine, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    bundle = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = bundle.init_params(key)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    extra = {}
    if cfg.family == "vlm":
        extra["patches"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.n_prefix, cfg.d_model))
    toks, stats = generate(bundle, params, prompts, args.new,
                           temperature=args.temperature, key=key,
                           extra_inputs=extra)
    print(f"arch={cfg.name}: generated {toks.shape} tokens")
    print(f"prefill {stats.prefill_s*1e3:.1f} ms "
          f"({stats.prompt_tokens}+{stats.prefill_tokens} tok), decode "
          f"{stats.decode_s*1e3:.1f} ms over {stats.decode_steps} steps — "
          f"{stats.decode_tokens} live tokens, {stats.tokens_per_s:.1f} "
          f"tok/s (CPU smoke — production rates come from the TPU roofline)")
    print("sample:", toks[0][:12].tolist())

    if cfg.family == "vlm":
        return  # the slot engine serves LM and RNN-T families
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    inputs={"tokens": rng.integers(
                        0, cfg.vocab_size,
                        (int(rng.integers(4, args.prompt_len + 1)),)
                    ).astype(np.int32)},
                    max_new_tokens=args.new)
            for i in range(2 * args.batch)]
    eng = SlotEngine(bundle, params, n_slots=args.batch,
                     max_new_tokens=args.new,
                     max_prompt_len=args.prompt_len,
                     temperature=args.temperature)
    t0 = time.time()
    comps = eng.run(reqs)
    wall = time.time() - t0
    print(f"slot engine: {len(comps)} requests over {eng.n_slots} slots in "
          f"{wall*1e3:.0f} ms ({eng.n_decode_dispatches} decode dispatches)")


if __name__ == "__main__":
    main()
