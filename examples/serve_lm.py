"""Batched serving demo: prefill + token-by-token decode with KV caches
(ring caches for sliding-window layers, recurrent states for SSM/hybrid).

  PYTHONPATH=src python examples/serve_lm.py --arch gemma3-27b-smoke
      [--batch 4] [--prompt-len 16] [--new 24] [--temperature 0.7]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import jax

from repro.configs import get_config
from repro.models.api import build_model
from repro.serve.engine import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    bundle = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = bundle.init_params(key)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    extra = {}
    if cfg.family == "vlm":
        extra["patches"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.n_prefix, cfg.d_model))
    toks, stats = generate(bundle, params, prompts, args.new,
                           temperature=args.temperature, key=key,
                           extra_inputs=extra)
    print(f"arch={cfg.name}: generated {toks.shape} tokens")
    print(f"prefill {stats.prefill_s*1e3:.1f} ms, decode "
          f"{stats.decode_s*1e3:.1f} ms, {stats.tokens_per_s:.1f} tok/s "
          f"(CPU smoke — production rates come from the TPU roofline)")
    print("sample:", toks[0][:12].tolist())


if __name__ == "__main__":
    main()
