"""Quickstart: PGM data-subset selection on a tiny LM, <1 min on CPU.

  PYTHONPATH=src python examples/quickstart.py

Walks the full paper loop once: build a corpus with easy/hard structure,
compute per-unit last-layer gradient *sketches*, run partitioned gradient
matching (Algorithm 1/2), and train on the weighted subset — comparing
against Random-Subset and full-data training.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_config
from repro.configs.base import PGMConfig, TrainConfig
from repro.data.pipeline import lm_units
from repro.data.synthetic import make_lm_corpus
from repro.models.api import build_model
from repro.train.loop import train_with_selection


def main():
    cfg = get_config("starcoder2-3b-smoke")       # reduced same-family config
    bundle = build_model(cfg)
    corpus = make_lm_corpus(seed=0, n_examples=64, seq_len=16,
                            vocab_size=cfg.vocab_size, hard_fraction=0.4)
    units = lm_units(corpus, unit_size=4)
    val = lm_units(make_lm_corpus(9, 16, 16, cfg.vocab_size), unit_size=4)

    tc = TrainConfig(
        lr=0.5, optimizer="sgd", epochs=5,
        pgm=PGMConfig(subset_fraction=0.3, n_partitions=4, select_every=2,
                      warm_start_epochs=1, sketch_dim_h=32, sketch_dim_v=32))

    results = {}
    for method in ("pgm", "random", "full"):
        h = train_with_selection(bundle, units, tc, method=method,
                                 val_units=val,
                                 log_fn=lambda s: print(f"  [{method}] {s}"))
        results[method] = h
        print(f"{method:7s}: final val loss {h.val_loss[-1]:.4f}, "
              f"cost {h.cost_units:.2f} full-epoch units")

    sp = results["full"].cost_units / results["pgm"].cost_units
    print(f"\nPGM speedup vs full training: {sp:.2f}x "
          f"(paper reports 2.6-6.3x at production scale)")


if __name__ == "__main__":
    main()
