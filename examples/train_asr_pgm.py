"""End-to-end driver (deliverable b): the paper's actual setting — train a
CRDNN RNN-Transducer on synthetic speech with PGM subset selection,
noisy-robust validation matching, newbob annealing, checkpointing, and a
final greedy-decode WER report.

  PYTHONPATH=src python examples/train_asr_pgm.py [--method pgm|random|full]
      [--noise 0.2] [--snr-db 10] [--subset 0.3] [--epochs 8] [--n 64]
      [--epoch-chunk 2] [--ckpt DIR]

``--noise F`` corrupts a fraction F of the training utterances with
additive feature noise at ``--snr-db`` dB (the paper's
Librispeech-noise setting); validation stays clean and PGM matches
against its gradient (Val=True).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import PGMConfig, TrainConfig
from repro.data.pipeline import asr_units
from repro.data.synthetic import make_asr_corpus
from repro.models import rnnt as rnnt_mod
from repro.models.api import build_model


def greedy_decode(bundle, params, feats, feat_lens, max_symbols=20):
    """Greedy transducer search (time-synchronous, one symbol per frame)."""
    cfg = bundle.cfg
    r = cfg.rnnt
    enc = rnnt_mod.encode(params, cfg, feats)            # (B,T',De)
    B, T, _ = enc.shape
    hyp = np.zeros((B, max_symbols), np.int32)
    n_sym = np.zeros((B,), np.int32)
    g = np.zeros((B, r.pred_hidden), np.float32)
    emb_w = np.asarray(params["pred_embed"]["w"])
    g_state = jnp.zeros((B, r.pred_hidden))
    last_tok = np.zeros((B,), np.int32)
    for t in range(T):
        z = rnnt_mod.joint_hidden(
            params, enc[:, t:t + 1], np.asarray(g_state)[:, None])
        logits = rnnt_mod.joint_logits(params, z)[:, 0, 0]
        tok = np.asarray(jnp.argmax(logits, -1))
        emit = (tok != 0) & (n_sym < max_symbols)
        for b in np.where(emit)[0]:
            hyp[b, n_sym[b]] = tok[b]
            n_sym[b] += 1
        if emit.any():
            x_t = jnp.asarray(emb_w[tok])
            g_new, _ = rnnt_mod.gru_step(params["pred_gru"], x_t, g_state)
            g_state = jnp.where(jnp.asarray(emit)[:, None], g_new, g_state)
    return hyp, n_sym


def token_error_rate(hyp, n_sym, refs, ref_lens):
    """Levenshtein distance per reference token (the WER analogue)."""
    total_err = total_ref = 0
    for b in range(hyp.shape[0]):
        h = list(hyp[b, : n_sym[b]])
        r = list(refs[b, : ref_lens[b]])
        d = np.zeros((len(h) + 1, len(r) + 1), np.int32)
        d[:, 0] = np.arange(len(h) + 1)
        d[0, :] = np.arange(len(r) + 1)
        for i in range(1, len(h) + 1):
            for j in range(1, len(r) + 1):
                d[i, j] = min(d[i - 1, j] + 1, d[i, j - 1] + 1,
                              d[i - 1, j - 1] + (h[i - 1] != r[j - 1]))
        total_err += d[-1, -1]
        total_ref += len(r)
    return total_err / max(total_ref, 1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="pgm")
    ap.add_argument("--noise", type=float, default=0.2,
                    help="fraction of corrupted training utterances")
    ap.add_argument("--snr-db", type=float, default=10.0,
                    help="SNR (dB) of the injected feature noise")
    ap.add_argument("--subset", type=float, default=0.3)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--engine", default="scan", choices=["scan", "host"])
    ap.add_argument("--epoch-chunk", type=int, default=1,
                    help="fold N epochs into one scan dispatch")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config("rnnt-crdnn-smoke")
    bundle = build_model(cfg)
    corpus = make_asr_corpus(0, args.n, n_feats=cfg.rnnt.n_feats,
                             vocab_size=cfg.rnnt.vocab_size,
                             noise_fraction=args.noise, snr_db=args.snr_db)
    print(f"train corpus: {int(corpus.noisy.sum())}/{args.n} utterances "
          f"corrupted at {args.snr_db:.0f} dB SNR")
    units = asr_units(corpus, 4)
    val_c = make_asr_corpus(31, 16, n_feats=cfg.rnnt.n_feats,
                            vocab_size=cfg.rnnt.vocab_size)
    val = asr_units(val_c, 4)

    tc = TrainConfig(
        lr=0.05, optimizer="adamw", epochs=args.epochs,
        pgm=PGMConfig(subset_fraction=args.subset, n_partitions=4,
                      select_every=2, warm_start_epochs=2,
                      sketch_dim_h=32, sketch_dim_v=32,
                      val_matching=args.noise > 0))
    from repro.train.loop import train_with_selection
    h = train_with_selection(bundle, units, tc, method=args.method,
                             val_units=val, ckpt_dir=args.ckpt,
                             engine=args.engine,
                             epoch_chunk=args.epoch_chunk, log_fn=print)

    hyp, n_sym = greedy_decode(bundle, h.final_params,
                               jnp.asarray(val_c.feats),
                               jnp.asarray(val_c.feat_lens))
    ter = token_error_rate(hyp, n_sym, val_c.tokens, val_c.token_lens)
    print(f"\nmethod={args.method}: token error rate {ter:.3f}, "
          f"val loss {h.val_loss[-1]:.4f}, "
          f"training cost {h.cost_units:.2f} full-epoch units")


if __name__ == "__main__":
    main()
