"""Train a decoder LM with PGM subset selection — the technique transferred
to the assigned LM-architecture pool (any ``--arch`` works; smoke variants
run on CPU, full configs are for real accelerators).

  PYTHONPATH=src python examples/train_lm_pgm.py --arch starcoder2-3b-smoke
      [--method pgm] [--subset 0.3] [--epochs 6] [--n 96] [--noise 0.0]
      [--engine scan|host] [--ckpt DIR] [--resume]

``--engine scan`` (default) runs each epoch as one device-resident
jitted lax.scan over the precomputed batch plan; ``--engine host`` is
the legacy one-jit-call-per-batch loop kept as the parity oracle.

Use ``--arch minitron-8b`` (etc.) unchanged on a TPU slice; the launcher
(`repro.launch.train`) applies the production mesh + sharding policies.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from repro.configs import get_config
from repro.configs.base import PGMConfig, TrainConfig
from repro.data.pipeline import lm_units
from repro.data.synthetic import make_lm_corpus
from repro.models.api import build_model
from repro.train.loop import train_with_selection


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b-smoke")
    ap.add_argument("--method", default="pgm",
                    choices=["pgm", "random", "large_only", "large_small",
                             "gradmatch_pb", "full"])
    ap.add_argument("--subset", type=float, default=0.3)
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--n", type=int, default=96)
    ap.add_argument("--seq", type=int, default=24)
    ap.add_argument("--noise", type=float, default=0.0)
    ap.add_argument("--engine", default="scan", choices=["scan", "host"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    bundle = build_model(cfg)
    corpus = make_lm_corpus(0, args.n, args.seq, cfg.vocab_size,
                            hard_fraction=0.4, noise_fraction=args.noise)
    units = lm_units(corpus, unit_size=4)
    val = lm_units(make_lm_corpus(99, max(args.n // 4, 8), args.seq,
                                  cfg.vocab_size), unit_size=4)
    tc = TrainConfig(
        lr=0.5, optimizer="sgd", epochs=args.epochs,
        pgm=PGMConfig(subset_fraction=args.subset, n_partitions=4,
                      select_every=2, warm_start_epochs=1,
                      sketch_dim_h=32, sketch_dim_v=32,
                      val_matching=args.noise > 0))
    h = train_with_selection(bundle, units, tc, method=args.method,
                             val_units=val, ckpt_dir=args.ckpt,
                             resume=args.resume, engine=args.engine,
                             log_fn=print)
    if h.val_loss:
        print(f"\nfinal: val loss {h.val_loss[-1]:.4f}, cost "
              f"{h.cost_units:.2f} full-epoch units, "
              f"{len(h.selections)} selection rounds")


if __name__ == "__main__":
    main()
