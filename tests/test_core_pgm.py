"""PGM (Algorithm 1) properties: partition locality, budget, the
Appendix-A upper bound vs GRAD-MATCHPB, sketched-vs-exact selection
agreement, validation matching, and the shard_map distribution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import PGMConfig
from repro.core import gm
from repro.core.baselines import gradmatch_pb, large_only, large_small, random_subset
from repro.core.lastlayer import make_proj_for
from repro.core.pgm import gather_selected, partitioned_gm, pgm_select
from repro.models.api import build_model


def _rand_units(n=40, D=64, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, D)), jnp.float32)


def test_partition_locality_and_budget():
    G = _rand_units()
    sel = partitioned_gm(G, 4, 3, lam=1e-3)
    idx = [int(i) for i in sel.indices]
    assert len(idx) == 12
    for p in range(4):
        part = [i for i in idx[p * 3:(p + 1) * 3] if i >= 0]
        assert len(part) == len(set(part))
        for i in part:
            assert p * 10 <= i < (p + 1) * 10


def test_appendix_a_bound():
    """Paper Appendix A: for the same weighted selection, the sum of
    per-partition objectives upper-bounds the unpartitioned objective
    (triangle inequality)."""
    G = _rand_units(n=32, D=48, seed=1)
    D_parts = 4
    per = 32 // D_parts
    sel = partitioned_gm(G, D_parts, 4, lam=0.1)
    w_full = np.zeros(32, np.float32)
    for i, w in zip(np.asarray(sel.indices), np.asarray(sel.weights)):
        if i >= 0:
            w_full[i] = w
    lam = 0.1
    # per-partition objectives (as PGM computes them)
    part_err = 0.0
    for p in range(D_parts):
        gp = np.asarray(G[p * per:(p + 1) * per])
        wp = w_full[p * per:(p + 1) * per]
        r = wp @ gp - gp.sum(0)
        part_err += lam * (wp ** 2).sum() + (r ** 2).sum() ** 0.5
    # unpartitioned objective with the same weights
    r_full = w_full @ np.asarray(G) - np.asarray(G).sum(0)
    full_err = lam * (w_full ** 2).sum() + (r_full ** 2).sum() ** 0.5
    assert part_err >= full_err - 1e-4


@pytest.mark.slow
def test_sketched_selection_agrees_with_exact():
    cfg = get_config("minitron-8b-smoke")
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init_params(key)
    units = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[m.make_batch(jax.random.PRNGKey(i), 2, 16) for i in range(16)])
    proj = make_proj_for(m, key, 48, 48)
    pc_s = PGMConfig(subset_fraction=0.5, n_partitions=4, use_sketch=True)
    pc_e = PGMConfig(subset_fraction=0.5, n_partitions=4, use_sketch=False)
    sel_s = pgm_select(m, params, units, pc_s, proj)
    sel_e = pgm_select(m, params, units, pc_e)
    a = {int(i) for i in sel_s.indices if i >= 0}
    b = {int(i) for i in sel_e.indices if i >= 0}
    assert len(a & b) >= int(0.6 * len(b)), (a, b)


@pytest.mark.slow
def test_val_matching_runs_and_differs():
    cfg = get_config("minitron-8b-smoke")
    m = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = m.init_params(key)
    units = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[m.make_batch(jax.random.PRNGKey(i), 2, 16) for i in range(8)])
    vunits = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[m.make_batch(jax.random.PRNGKey(100 + i), 2, 16) for i in range(4)])
    proj = make_proj_for(m, key, 32, 32)
    pc = PGMConfig(subset_fraction=0.5, n_partitions=2, use_sketch=True,
                   val_matching=True)
    sel = pgm_select(m, params, units, pc, proj, val_units=vunits)
    assert int(sel.n_selected) >= 2


def test_baselines():
    key = jax.random.PRNGKey(0)
    sel = random_subset(key, 20, 5)
    assert len({int(i) for i in sel.indices}) == 5
    dur = jnp.asarray(np.arange(20, dtype=np.float32))
    lo = large_only(dur, 4)
    assert sorted(int(i) for i in lo.indices) == [16, 17, 18, 19]
    ls = large_small(dur, 4)
    assert sorted(int(i) for i in ls.indices) == [0, 1, 18, 19]
    G = _rand_units(20, 32, 2)
    gp = gradmatch_pb(G, 6, lam=1e-3)
    assert int(gp.n_selected) <= 6


def test_gather_selected_applies_weights():
    units = {"tokens": jnp.arange(40).reshape(10, 4),
             "weights": jnp.ones((10, 4))}
    from repro.core.pgm import Selection
    sel = Selection(jnp.asarray([2, 5, -1]), jnp.asarray([2.0, 0.5, 0.0]),
                    jnp.asarray(2), jnp.zeros(1))
    sub = gather_selected(units, sel)
    assert sub["tokens"].shape == (3, 4)
    assert float(sub["weights"][0, 0]) == 2.0
    assert float(sub["weights"][2, 0]) == 0.0  # padded slot zeroed
