"""PlanPrefetcher lifecycle: exception propagation, worker join, reuse."""
import threading
import time

import pytest

from repro.data.plan_prefetch import PlanPrefetcher


def _worker_threads():
    return [t for t in threading.enumerate()
            if t.name.startswith("plan-prefetch")]


def test_hit_and_miss_counters():
    with PlanPrefetcher(max_pending=2) as pf:
        assert pf.schedule("a", lambda: 1)
        assert pf.get("a", lambda: -1) == 1           # prefetched
        assert pf.get("b", lambda: 2) == 2            # synchronous fallback
        assert (pf.hits, pf.misses) == (1, 1)


def test_builder_exception_propagates_to_get():
    """A worker-thread failure must surface at the consumer, not strand
    it; the slot is freed so a retry falls back to a synchronous build."""
    with PlanPrefetcher() as pf:
        pf.schedule("k", lambda: 1 / 0)
        with pytest.raises(ZeroDivisionError):
            pf.get("k", lambda: None)
        # slot freed: same key now builds synchronously
        assert pf.get("k", lambda: 42) == 42


def test_orphaned_failed_build_does_not_block_close():
    """A failed build whose key is never fetched (e.g. superseded by a
    selection round) must not wedge invalidate()/close()."""
    pf = PlanPrefetcher()
    pf.schedule("orphan", lambda: 1 / 0)
    time.sleep(0.05)                   # let the worker run (and fail)
    pf.invalidate()
    pf.close()
    assert not _worker_threads()


def test_close_joins_worker_and_is_idempotent():
    pf = PlanPrefetcher()
    pf.schedule("a", lambda: time.sleep(0.02) or "plan")
    pf.close()
    assert not _worker_threads()
    pf.close()                                        # idempotent
    # closed prefetcher degrades to synchronous builds
    assert not pf.schedule("b", lambda: 1)
    assert pf.get("b", lambda: "sync") == "sync"


def test_del_releases_worker():
    pf = PlanPrefetcher()
    pf.schedule("a", lambda: 1)
    pf.__del__()
    assert not _worker_threads()


def test_max_pending_bounds_buffer():
    ev = threading.Event()
    with PlanPrefetcher(max_pending=2) as pf:
        assert pf.schedule("a", ev.wait)
        assert pf.schedule("b", lambda: 2)
        assert pf.schedule("a", lambda: -1)           # idempotent re-key
        assert not pf.schedule("c", lambda: 3)        # buffer full
        ev.set()
    assert not _worker_threads()
