"""PlanPrefetcher lifecycle: exception propagation, worker join, reuse."""
import threading
import time

import pytest

from repro.data.plan_prefetch import PlanPrefetcher


def _worker_threads():
    return [t for t in threading.enumerate()
            if t.name.startswith("plan-prefetch")]


def test_hit_and_miss_counters():
    with PlanPrefetcher(max_pending=2) as pf:
        assert pf.schedule("a", lambda: 1)
        assert pf.get("a", lambda: -1) == 1           # prefetched
        assert pf.get("b", lambda: 2) == 2            # synchronous fallback
        assert (pf.hits, pf.misses) == (1, 1)


def test_builder_exception_propagates_to_get():
    """A worker-thread failure must surface at the consumer, not strand
    it; the slot is freed so a retry falls back to a synchronous build."""
    with PlanPrefetcher() as pf:
        pf.schedule("k", lambda: 1 / 0)
        with pytest.raises(ZeroDivisionError):
            pf.get("k", lambda: None)
        # slot freed: same key now builds synchronously
        assert pf.get("k", lambda: 42) == 42


def test_orphaned_failed_build_does_not_block_close():
    """A failed build whose key is never fetched (e.g. superseded by a
    selection round) must not wedge invalidate()/close()."""
    pf = PlanPrefetcher()
    pf.schedule("orphan", lambda: 1 / 0)
    time.sleep(0.05)                   # let the worker run (and fail)
    pf.invalidate()
    pf.close()
    assert not _worker_threads()


def test_close_joins_worker_and_is_idempotent():
    pf = PlanPrefetcher()
    pf.schedule("a", lambda: time.sleep(0.02) or "plan")
    pf.close()
    assert not _worker_threads()
    pf.close()                                        # idempotent
    # closed prefetcher degrades to synchronous builds
    assert not pf.schedule("b", lambda: 1)
    assert pf.get("b", lambda: "sync") == "sync"


def test_del_releases_worker():
    pf = PlanPrefetcher()
    pf.schedule("a", lambda: 1)
    pf.__del__()
    assert not _worker_threads()


def _flaky(fail_times, value):
    """Builder failing ``fail_times`` times before succeeding."""
    calls = {"n": 0}

    def build():
        calls["n"] += 1
        if calls["n"] <= fail_times:
            raise RuntimeError(f"transient #{calls['n']}")
        return value
    return build, calls


def test_transient_failure_retried_on_worker_path():
    """A builder that fails then recovers is retried in place on the
    worker thread — the consumer sees only the successful result."""
    with PlanPrefetcher(retries=2, backoff_s=0.001) as pf:
        build, calls = _flaky(2, "plan")
        pf.schedule("k", build)
        assert pf.get("k", lambda: None) == "plan"
        assert calls["n"] == 3
        assert pf.retried == 2


def test_transient_failure_retried_on_miss_path():
    """The synchronous ``get()`` fallback degrades identically: same
    retry policy as the worker path."""
    with PlanPrefetcher(retries=2, backoff_s=0.001) as pf:
        build, calls = _flaky(1, 42)
        assert pf.get("unscheduled", build) == 42
        assert calls["n"] == 2
        assert (pf.retried, pf.misses) == (1, 1)


def test_permanent_failure_still_raises_after_retries():
    """Retries are capped: a deterministic failure propagates to the
    consumer once the budget is exhausted (no infinite retry loop)."""
    with PlanPrefetcher(retries=2, backoff_s=0.001) as pf:
        build, calls = _flaky(99, None)
        pf.schedule("k", build)
        with pytest.raises(RuntimeError, match="transient #3"):
            pf.get("k", lambda: None)
        assert calls["n"] == 3           # retries + 1 attempts, then give up
        assert pf.retried == 2


def test_max_pending_bounds_buffer():
    ev = threading.Event()
    with PlanPrefetcher(max_pending=2) as pf:
        assert pf.schedule("a", ev.wait)
        assert pf.schedule("b", lambda: 2)
        assert pf.schedule("a", lambda: -1)           # idempotent re-key
        assert not pf.schedule("c", lambda: 3)        # buffer full
        ev.set()
    assert not _worker_threads()
