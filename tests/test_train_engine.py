"""Scanned epoch engine (train/engine.py): parity against the legacy
host loop on both an LM-smoke and the RNN-T-smoke config, plus fast
micro-properties — batch-plan determinism across resume, weighted-batch
weight expansion, and donation not retaining stale buffers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import PGMConfig, TrainConfig
from repro.data.pipeline import (
    asr_units,
    epoch_plan,
    lm_units,
    subset_epoch_plan,
    subset_iterator,
)
from repro.data.synthetic import make_asr_corpus, make_lm_corpus
from repro.models.api import build_model
from repro.train.engine import EpochEngine
from repro.train.loop import train_with_selection


def _lm_setup(n=32, seq=12, epochs=4):
    cfg = get_config("starcoder2-3b-smoke")
    m = build_model(cfg)
    units = lm_units(make_lm_corpus(0, n, seq, cfg.vocab_size,
                                    hard_fraction=0.4), unit_size=4)
    val = lm_units(make_lm_corpus(7, 16, seq, cfg.vocab_size), unit_size=4)
    tc = TrainConfig(
        lr=0.5, optimizer="sgd", epochs=epochs,
        pgm=PGMConfig(subset_fraction=0.5, n_partitions=2, select_every=2,
                      warm_start_epochs=1, sketch_dim_h=24, sketch_dim_v=24))
    return m, units, val, tc


def _rnnt_setup(n=16, epochs=3):
    cfg = get_config("rnnt-crdnn-smoke")
    m = build_model(cfg)
    r = cfg.rnnt
    units = asr_units(make_asr_corpus(0, n, n_feats=r.n_feats,
                                      vocab_size=r.vocab_size,
                                      noise_fraction=0.2), 4)
    val = asr_units(make_asr_corpus(5, 8, n_feats=r.n_feats,
                                    vocab_size=r.vocab_size), 4)
    tc = TrainConfig(
        lr=0.05, optimizer="adamw", epochs=epochs,
        pgm=PGMConfig(subset_fraction=0.5, n_partitions=2, select_every=2,
                      warm_start_epochs=1, sketch_dim_h=16, sketch_dim_v=16,
                      val_matching=True))
    return m, units, val, tc


# ---------------------------------------------------------------------------
# Parity: identical seeds => the scanned engine reproduces the legacy
# host loop's per-epoch losses and selected indices
# ---------------------------------------------------------------------------

def _assert_history_parity(h_host, h_scan, atol):
    assert np.allclose(h_host.train_loss, h_scan.train_loss, atol=atol), \
        (h_host.train_loss, h_scan.train_loss)
    assert np.allclose(h_host.val_loss, h_scan.val_loss, atol=atol), \
        (h_host.val_loss, h_scan.val_loss)
    assert len(h_host.selections) == len(h_scan.selections)
    for sh, ss in zip(h_host.selections, h_scan.selections):
        assert sh["epoch"] == ss["epoch"]
        assert sh["indices"] == ss["indices"], (sh, ss)
        assert np.allclose(sh["weights"], ss["weights"], atol=atol)
    assert h_host.cost_units == pytest.approx(h_scan.cost_units)


def test_scan_engine_matches_host_loop_lm():
    m, units, val, tc = _lm_setup()
    h_host = train_with_selection(m, units, tc, method="pgm", val_units=val,
                                  engine="host")
    h_scan = train_with_selection(m, units, tc, method="pgm", val_units=val,
                                  engine="scan")
    _assert_history_parity(h_host, h_scan, atol=1e-3)


@pytest.mark.slow
def test_scan_engine_matches_host_loop_rnnt():
    m, units, val, tc = _rnnt_setup()
    h_host = train_with_selection(m, units, tc, method="pgm", val_units=val,
                                  engine="host")
    h_scan = train_with_selection(m, units, tc, method="pgm", val_units=val,
                                  engine="scan")
    _assert_history_parity(h_host, h_scan, atol=1e-3)


# ---------------------------------------------------------------------------
# Micro-properties (fast tier)
# ---------------------------------------------------------------------------

def test_epoch_plan_determinism_across_resume():
    """The (seed, epoch) keying makes the schedule a pure function — a
    resumed run rebuilds byte-identical plans for the remaining epochs."""
    for epoch in (0, 3):
        a = epoch_plan(12, seed=5, epoch=epoch, batch_units=2)
        b = epoch_plan(12, seed=5, epoch=epoch, batch_units=2)
        assert a.shape == (6, 2) and np.array_equal(a, b)
        assert sorted(a.reshape(-1).tolist()) == list(range(12))
    assert not np.array_equal(epoch_plan(12, 5, 0), epoch_plan(12, 5, 1))
    assert not np.array_equal(epoch_plan(12, 5, 0), epoch_plan(12, 6, 0))

    idx = np.asarray([3, 7, -1, 1, 5, -1], np.int32)
    w = np.asarray([1.0, 2.0, 0.0, 0.5, 1.5, 0.0], np.float32)
    pi1, pw1 = subset_epoch_plan(idx, w, seed=5, epoch=2, batch_units=2)
    pi2, pw2 = subset_epoch_plan(idx, w, seed=5, epoch=2, batch_units=2)
    assert np.array_equal(pi1, pi2) and np.array_equal(pw1, pw2)
    assert pi1.shape == (2, 2)                       # -1 dropped, 4//2 steps
    assert set(pi1.reshape(-1).tolist()) <= {3, 7, 1, 5}
    # weights travel with their indices through the shuffle
    by_idx = dict(zip(idx.tolist(), w.tolist()))
    for i, ww in zip(pi1.reshape(-1), pw1.reshape(-1)):
        assert by_idx[int(i)] == float(ww)


def test_subset_iterator_matches_plan():
    """The host iterator is a view over the same plan (order parity by
    construction)."""
    units = {"tokens": np.arange(48, dtype=np.int32).reshape(12, 4),
             "weights": np.ones((12, 4), np.float32)}
    idx = np.asarray([0, 2, 4, 6, 8, 10], np.int32)
    w = np.linspace(0.5, 3.0, 6).astype(np.float32)
    pi, pw = subset_epoch_plan(idx, w, seed=1, epoch=0, batch_units=2)
    batches = list(subset_iterator(units, idx, w, seed=1, epoch=0,
                                   batch_units=2))
    assert len(batches) == pi.shape[0]
    for (sel, ww), b in zip(zip(pi, pw), batches):
        assert np.array_equal(b["tokens"],
                              units["tokens"][sel].reshape(-1))
        assert np.allclose(b["weights"], np.repeat(ww, 4))


def test_weighted_batch_weights_reach_the_loss():
    """Per-unit OMP weights must scale the per-example loss weights inside
    the scanned batch exactly like the host iterator does."""
    m, units, _, tc = _lm_setup(n=16, epochs=1)
    eng = EpochEngine(m, tc, units, batch_units=2)
    idx = np.asarray([0, 1, 2, 3], np.int32)
    w = np.asarray([2.0, 0.5, 1.0, 3.0], np.float32)
    plan_idx, plan_w = eng.subset_plan(idx, w, epoch=0)
    # reconstruct the first scanned batch by hand
    sel, ww = np.asarray(plan_idx)[0], np.asarray(plan_w)[0]
    want = units["weights"][sel].reshape(-1) * np.repeat(ww, eng.unit_size)
    got = np.asarray(eng.units["weights"])[sel].reshape(-1) \
        * np.repeat(ww, eng.unit_size)
    assert np.allclose(got, want)
    # and a weight-2x selection changes the loss vs weight-1x
    params = m.init_params(jax.random.PRNGKey(0))
    opt0 = {"step": jnp.zeros((), jnp.int32)}
    p1, o1, losses_w = eng.run_epoch(params, opt0, 0.0,
                                     (plan_idx, plan_w))
    params2 = m.init_params(jax.random.PRNGKey(0))
    ones = jnp.ones_like(plan_w)
    p2, o2, losses_1 = eng.run_epoch(params2, {"step": jnp.zeros((), jnp.int32)},
                                     0.0, (plan_idx, ones))
    assert losses_w.shape == losses_1.shape == (2,)
    assert not np.allclose(np.asarray(losses_w), np.asarray(losses_1))


def test_donation_does_not_retain_stale_buffers():
    """run_epoch donates (params, opt_state): the inputs' buffers are
    consumed (deleted when the backend supports donation) and the engine
    keeps working from the returned state — nothing stale is retained."""
    m, units, _, tc = _lm_setup(n=16, epochs=1)
    eng = EpochEngine(m, tc, units, batch_units=2)
    params = m.init_params(jax.random.PRNGKey(0))
    opt_state = {"step": jnp.zeros((), jnp.int32)}
    in_leaf = jax.tree.leaves(params)[0]
    p1, o1, l1 = eng.run_epoch(params, opt_state, tc.lr,
                               eng.full_plan(epoch=0))
    assert in_leaf.is_deleted(), "donated params buffer was retained"
    # chaining from the returned state works (nothing references the old
    # buffers), and the second epoch is a cache hit on the same executable
    p2, o2, l2 = eng.run_epoch(p1, o1, tc.lr, eng.full_plan(epoch=1))
    assert np.isfinite(np.asarray(l2)).all()
    assert int(o2["step"]) == 2 * l1.shape[0]