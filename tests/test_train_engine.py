"""Scanned epoch engine (train/engine.py): parity against the legacy
host loop on both an LM-smoke and the RNN-T-smoke config, plus fast
micro-properties — batch-plan determinism across resume, weighted-batch
weight expansion, and donation not retaining stale buffers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import PGMConfig, TrainConfig
from repro.data.pipeline import (
    asr_units,
    epoch_plan,
    lm_units,
    subset_epoch_plan,
    subset_iterator,
)
from repro.data.synthetic import make_asr_corpus, make_lm_corpus
from repro.models.api import build_model
from repro.train.engine import EpochEngine
from repro.train.loop import train_with_selection


def _lm_setup(n=32, seq=12, epochs=4):
    cfg = get_config("starcoder2-3b-smoke")
    m = build_model(cfg)
    units = lm_units(make_lm_corpus(0, n, seq, cfg.vocab_size,
                                    hard_fraction=0.4), unit_size=4)
    val = lm_units(make_lm_corpus(7, 16, seq, cfg.vocab_size), unit_size=4)
    tc = TrainConfig(
        lr=0.5, optimizer="sgd", epochs=epochs,
        pgm=PGMConfig(subset_fraction=0.5, n_partitions=2, select_every=2,
                      warm_start_epochs=1, sketch_dim_h=24, sketch_dim_v=24))
    return m, units, val, tc


def _rnnt_setup(n=16, epochs=3):
    cfg = get_config("rnnt-crdnn-smoke")
    m = build_model(cfg)
    r = cfg.rnnt
    units = asr_units(make_asr_corpus(0, n, n_feats=r.n_feats,
                                      vocab_size=r.vocab_size,
                                      noise_fraction=0.2), 4)
    val = asr_units(make_asr_corpus(5, 8, n_feats=r.n_feats,
                                    vocab_size=r.vocab_size), 4)
    tc = TrainConfig(
        lr=0.05, optimizer="adamw", epochs=epochs,
        pgm=PGMConfig(subset_fraction=0.5, n_partitions=2, select_every=2,
                      warm_start_epochs=1, sketch_dim_h=16, sketch_dim_v=16,
                      val_matching=True))
    return m, units, val, tc


# ---------------------------------------------------------------------------
# Parity: identical seeds => the scanned engine reproduces the legacy
# host loop's per-epoch losses and selected indices
# ---------------------------------------------------------------------------

def _assert_history_parity(h_host, h_scan, atol):
    assert np.allclose(h_host.train_loss, h_scan.train_loss, atol=atol), \
        (h_host.train_loss, h_scan.train_loss)
    assert np.allclose(h_host.val_loss, h_scan.val_loss, atol=atol), \
        (h_host.val_loss, h_scan.val_loss)
    assert len(h_host.selections) == len(h_scan.selections)
    for sh, ss in zip(h_host.selections, h_scan.selections):
        assert sh["epoch"] == ss["epoch"]
        assert sh["indices"] == ss["indices"], (sh, ss)
        assert np.allclose(sh["weights"], ss["weights"], atol=atol)
    assert h_host.cost_units == pytest.approx(h_scan.cost_units)


def test_scan_engine_matches_host_loop_lm():
    m, units, val, tc = _lm_setup()
    h_host = train_with_selection(m, units, tc, method="pgm", val_units=val,
                                  engine="host")
    h_scan = train_with_selection(m, units, tc, method="pgm", val_units=val,
                                  engine="scan")
    _assert_history_parity(h_host, h_scan, atol=1e-3)


@pytest.mark.slow
def test_scan_engine_matches_host_loop_rnnt():
    m, units, val, tc = _rnnt_setup()
    h_host = train_with_selection(m, units, tc, method="pgm", val_units=val,
                                  engine="host")
    h_scan = train_with_selection(m, units, tc, method="pgm", val_units=val,
                                  engine="scan")
    _assert_history_parity(h_host, h_scan, atol=1e-3)


# ---------------------------------------------------------------------------
# Micro-properties (fast tier)
# ---------------------------------------------------------------------------

def test_epoch_plan_determinism_across_resume():
    """The (seed, epoch) keying makes the schedule a pure function — a
    resumed run rebuilds byte-identical plans for the remaining epochs."""
    for epoch in (0, 3):
        a = epoch_plan(12, seed=5, epoch=epoch, batch_units=2)
        b = epoch_plan(12, seed=5, epoch=epoch, batch_units=2)
        assert a.shape == (6, 2) and np.array_equal(a, b)
        assert sorted(a.reshape(-1).tolist()) == list(range(12))
    assert not np.array_equal(epoch_plan(12, 5, 0), epoch_plan(12, 5, 1))
    assert not np.array_equal(epoch_plan(12, 5, 0), epoch_plan(12, 6, 0))

    idx = np.asarray([3, 7, -1, 1, 5, -1], np.int32)
    w = np.asarray([1.0, 2.0, 0.0, 0.5, 1.5, 0.0], np.float32)
    pi1, pw1 = subset_epoch_plan(idx, w, seed=5, epoch=2, batch_units=2)
    pi2, pw2 = subset_epoch_plan(idx, w, seed=5, epoch=2, batch_units=2)
    assert np.array_equal(pi1, pi2) and np.array_equal(pw1, pw2)
    assert pi1.shape == (2, 2)                       # -1 dropped, 4//2 steps
    assert set(pi1.reshape(-1).tolist()) <= {3, 7, 1, 5}
    # weights travel with their indices through the shuffle
    by_idx = dict(zip(idx.tolist(), w.tolist()))
    for i, ww in zip(pi1.reshape(-1), pw1.reshape(-1)):
        assert by_idx[int(i)] == float(ww)


def test_subset_iterator_matches_plan():
    """The host iterator is a view over the same plan (order parity by
    construction)."""
    units = {"tokens": np.arange(48, dtype=np.int32).reshape(12, 4),
             "weights": np.ones((12, 4), np.float32)}
    idx = np.asarray([0, 2, 4, 6, 8, 10], np.int32)
    w = np.linspace(0.5, 3.0, 6).astype(np.float32)
    pi, pw = subset_epoch_plan(idx, w, seed=1, epoch=0, batch_units=2)
    batches = list(subset_iterator(units, idx, w, seed=1, epoch=0,
                                   batch_units=2))
    assert len(batches) == pi.shape[0]
    for (sel, ww), b in zip(zip(pi, pw), batches):
        assert np.array_equal(b["tokens"],
                              units["tokens"][sel].reshape(-1))
        assert np.allclose(b["weights"], np.repeat(ww, 4))


def test_weighted_batch_weights_reach_the_loss():
    """Per-unit OMP weights must scale the per-example loss weights inside
    the scanned batch exactly like the host iterator does."""
    m, units, _, tc = _lm_setup(n=16, epochs=1)
    eng = EpochEngine(m, tc, units, batch_units=2)
    idx = np.asarray([0, 1, 2, 3], np.int32)
    w = np.asarray([2.0, 0.5, 1.0, 3.0], np.float32)
    plan_idx, plan_w = eng.subset_plan(idx, w, epoch=0)
    # reconstruct the first scanned batch by hand
    sel, ww = np.asarray(plan_idx)[0], np.asarray(plan_w)[0]
    want = units["weights"][sel].reshape(-1) * np.repeat(ww, eng.unit_size)
    got = np.asarray(eng.units["weights"])[sel].reshape(-1) \
        * np.repeat(ww, eng.unit_size)
    assert np.allclose(got, want)
    # and a weight-2x selection changes the loss vs weight-1x
    params = m.init_params(jax.random.PRNGKey(0))
    opt0 = {"step": jnp.zeros((), jnp.int32)}
    p1, o1, losses_w = eng.run_epoch(params, opt0, 0.0,
                                     (plan_idx, plan_w))
    params2 = m.init_params(jax.random.PRNGKey(0))
    ones = jnp.ones_like(plan_w)
    p2, o2, losses_1 = eng.run_epoch(params2, {"step": jnp.zeros((), jnp.int32)},
                                     0.0, (plan_idx, ones))
    assert losses_w.shape == losses_1.shape == (2,)
    assert not np.allclose(np.asarray(losses_w), np.asarray(losses_1))


# ---------------------------------------------------------------------------
# Recurrent-state carries through the epoch scan (DESIGN.md §8): the
# RWKV6 / RecurrentGemma time recurrences zero-init per utterance, so
# the scan-of-scan must carry no hidden state across steps, resume
# bit-exact, and treat padding steps as bit-exact no-ops.
# ---------------------------------------------------------------------------

RECURRENT = ["rwkv6-3b",
             pytest.param("recurrentgemma-9b", marks=pytest.mark.slow)]


def _recurrent_setup(arch, n=16, seq=10, epochs=4):
    cfg = get_config(arch + "-smoke")
    m = build_model(cfg)
    units = lm_units(make_lm_corpus(0, n, seq, cfg.vocab_size,
                                    hard_fraction=0.4), unit_size=2)
    val = lm_units(make_lm_corpus(7, 8, seq, cfg.vocab_size), unit_size=2)
    tc = TrainConfig(
        lr=0.2, optimizer="sgd", epochs=epochs,
        pgm=PGMConfig(subset_fraction=0.5, n_partitions=2, select_every=2,
                      warm_start_epochs=1, sketch_dim_h=16, sketch_dim_v=16))
    return m, units, val, tc


@pytest.mark.parametrize("arch", RECURRENT)
def test_recurrent_state_resets_per_utterance(arch):
    """The recurrence is per-utterance: an example's loss is identical
    whether it shares a batch with others or is evaluated alone, and
    repeating a step at lr=0 reproduces the loss bitwise — no recurrent
    state survives between utterances or between scan steps."""
    m, units, _, tc = _recurrent_setup(arch)
    params = m.init_params(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v[0]) for k, v in units.items()}
    pe = m.per_example_loss(params, batch)
    for i in range(int(pe.shape[0])):
        alone = m.per_example_loss(
            params, {k: v[i:i + 1] for k, v in batch.items()})
        assert np.allclose(np.asarray(alone[0]), np.asarray(pe[i]),
                           rtol=1e-5, atol=1e-6), (arch, i)
    # same unit scheduled twice in one scanned epoch at lr=0: both steps
    # see identical params AND identical (fresh) recurrent state
    eng = EpochEngine(m, tc, units, batch_units=2)
    plan = (jnp.zeros((2, 2), jnp.int32), jnp.ones((2, 2), jnp.float32))
    opt0 = {"step": jnp.zeros((), jnp.int32)}
    _, _, losses = eng.run_epoch(params, opt0, 0.0, plan)
    l = np.asarray(losses)
    assert l[0] == l[1], (arch, l)


@pytest.mark.parametrize("arch", RECURRENT)
def test_recurrent_padding_steps_are_bitwise_noops(arch):
    """An all-padding plan (weight-0 gated steps) leaves params and opt
    state bit-identical on the recurrent substrates — the gate must hold
    through the scan-of-scan exactly as on dense LMs."""
    m, units, _, tc = _recurrent_setup(arch)
    eng = EpochEngine(m, tc, units, batch_units=2)
    from repro.train.optim import make_update_for
    opt_init, _ = make_update_for(tc)
    params = m.init_params(jax.random.PRNGKey(0))
    opt = opt_init(params)
    params, opt, _ = eng.run_epoch(params, opt, tc.lr, eng.full_plan(0))
    before = (jax.tree.map(np.asarray, params), jax.tree.map(np.asarray, opt))
    pad_plan = (jnp.full((2, 2), -1, jnp.int32),
                jnp.zeros((2, 2), jnp.float32))
    params, opt, losses = eng.run_epoch(params, opt, tc.lr, pad_plan)
    assert np.asarray(losses).tolist() == [0.0, 0.0]
    for b, a in zip(before, (params, opt)):
        assert all(np.array_equal(np.asarray(x), np.asarray(y))
                   for x, y in zip(jax.tree.leaves(b), jax.tree.leaves(a)))


@pytest.mark.parametrize("arch", RECURRENT)
def test_recurrent_resume_bit_exact(arch, tmp_path):
    """Interrupt a selection run mid-way and resume from checkpoint: the
    remaining epochs reproduce the uninterrupted run exactly — the
    recurrent substrates carry nothing outside (params, opt, plan
    state), so resume is bit-exact like the dense case."""
    m, units, val, tc = _recurrent_setup(arch, epochs=4)
    h_full = train_with_selection(
        m, units, tc, method="pgm", val_units=val, engine="scan",
        ckpt_dir=str(tmp_path / "full"))
    import dataclasses
    tc2 = dataclasses.replace(tc, epochs=2)
    train_with_selection(
        m, units, tc2, method="pgm", val_units=val, engine="scan",
        ckpt_dir=str(tmp_path / "cut"))
    h_res = train_with_selection(
        m, units, tc, method="pgm", val_units=val, engine="scan",
        ckpt_dir=str(tmp_path / "cut"), resume=True)
    assert h_res.train_loss == h_full.train_loss[2:], \
        (arch, h_res.train_loss, h_full.train_loss)
    assert h_res.val_loss == h_full.val_loss[2:]


def test_guard_composes_with_padding_gate_bitwise():
    """The non-finite guard folds into the same ``step_on`` gate as the
    weight-0 padding rows (DESIGN.md §10): on a plan mixing real and
    padding rows, guard-on must be bit-identical to guard-off, padding
    rows must not count as skipped, and a poisoned real row must gate
    off exactly like a padding row."""
    import dataclasses
    m, units, _, tc = _lm_setup(n=16, epochs=1)
    from repro.train.optim import make_update_for
    opt_init, _ = make_update_for(tc)
    # subset plan with trailing padding (2 real units into 2-unit batches,
    # padded to 2 steps by construction below)
    idx = np.asarray([[0, 1], [-1, -1]], np.int32)
    w = np.asarray([[1.0, 1.0], [0.0, 0.0]], np.float32)
    outs = {}
    for guard in (False, True):
        eng = EpochEngine(m, dataclasses.replace(tc, nonfinite_guard=guard),
                          units, batch_units=2)
        p = m.init_params(jax.random.PRNGKey(0))
        o = opt_init(p)
        outs[guard] = eng.run_epoch(p, o, tc.lr,
                                    (jnp.asarray(idx), jnp.asarray(w)))
        if guard:
            # padding is gated, not "skipped": the guard metric only
            # reports suppressed *live* steps
            assert int(eng.last_n_skipped) == 0
            assert np.asarray(eng.last_skipped).tolist() == [0.0, 0.0]
    for a, b in zip(outs[False], outs[True]):
        assert all(np.array_equal(np.asarray(x), np.asarray(y))
                   for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
    # poisoned real row == padding row, bit for bit (carry incl. opt step)
    eng = EpochEngine(m, dataclasses.replace(tc, nonfinite_guard=True),
                      units, batch_units=2)
    w_nan = np.asarray([[np.nan, np.nan], [0.0, 0.0]], np.float32)
    p = m.init_params(jax.random.PRNGKey(0))
    p2, o2, losses = eng.run_epoch(p, opt_init(p), tc.lr,
                                   (jnp.asarray(idx), jnp.asarray(w_nan)))
    assert int(eng.last_n_skipped) == 1
    assert np.asarray(losses).tolist() == [0.0, 0.0]
    pad_only = (jnp.full((2, 2), -1, jnp.int32),
                jnp.zeros((2, 2), jnp.float32))
    p3 = m.init_params(jax.random.PRNGKey(0))
    p4, o4, _ = eng.run_epoch(p3, opt_init(p3), tc.lr, pad_only)
    for a, b in zip((p2, o2), (p4, o4)):
        assert all(np.array_equal(np.asarray(x), np.asarray(y))
                   for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_emergency_checkpoint_resume_bit_exact_mid_chunk(tmp_path):
    """A preemption landing mid-run on a chunked dispatch checkpoints at
    the chunk boundary and resumes bit-exactly onto the uninterrupted
    trajectory — the guard's skip counters and the chunked newbob state
    all travel through the manifest."""
    import dataclasses
    from repro.train import faults
    m, units, val, tc = _lm_setup(epochs=4)
    tc = dataclasses.replace(tc, nonfinite_guard=True)
    d = str(tmp_path / "ck")
    h_full = train_with_selection(m, units, tc, method="pgm",
                                  val_units=val, engine="scan",
                                  epoch_chunk=2)
    # warm start is 1 epoch, so the chunks are [0], [1,2], [3]: a SIGTERM
    # requested after epoch 1 lands mid-chunk — epoch 2 still runs (the
    # in-flight dispatch completes) and the checkpoint is cut at epoch 2
    h_cut = train_with_selection(
        m, units, tc, method="pgm", val_units=val, engine="scan",
        epoch_chunk=2, ckpt_dir=d,
        fault_plan=faults.FaultPlan(preempt_after_epoch=1))
    assert h_cut.preempted and len(h_cut.val_loss) == 3
    h_res = train_with_selection(m, units, tc, method="pgm",
                                 val_units=val, engine="scan",
                                 epoch_chunk=2, ckpt_dir=d, resume=True)
    assert h_cut.val_loss + h_res.val_loss == h_full.val_loss
    assert h_cut.train_loss + h_res.train_loss == h_full.train_loss


def test_donation_does_not_retain_stale_buffers():
    """run_epoch donates (params, opt_state): the inputs' buffers are
    consumed (deleted when the backend supports donation) and the engine
    keeps working from the returned state — nothing stale is retained."""
    m, units, _, tc = _lm_setup(n=16, epochs=1)
    eng = EpochEngine(m, tc, units, batch_units=2)
    params = m.init_params(jax.random.PRNGKey(0))
    opt_state = {"step": jnp.zeros((), jnp.int32)}
    in_leaf = jax.tree.leaves(params)[0]
    p1, o1, l1 = eng.run_epoch(params, opt_state, tc.lr,
                               eng.full_plan(epoch=0))
    assert in_leaf.is_deleted(), "donated params buffer was retained"
    # chaining from the returned state works (nothing references the old
    # buffers), and the second epoch is a cache hit on the same executable
    p2, o2, l2 = eng.run_epoch(p1, o1, tc.lr, eng.full_plan(epoch=1))
    assert np.isfinite(np.asarray(l2)).all()
    assert int(o2["step"]) == 2 * l1.shape[0]