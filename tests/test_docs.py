"""Docs integrity (fast tier; also ``make docs-check``): every file path
referenced in README.md / docs/DESIGN.md / ROADMAP.md must exist, every
``make <target>`` named in those docs must be defined in the Makefile,
and every ``DESIGN.md §N`` citation in the source tree must resolve to a
section of docs/DESIGN.md (the reference style used across ``src/``)."""
import re
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOCS = ["README.md", "docs/DESIGN.md", "ROADMAP.md"]

# directories a doc-relative reference may be rooted at
ROOTS = ["", "src/", "src/repro/", "docs/"]
EXTS = (".py", ".md", ".json", ".ini", ".txt", ".yaml", ".toml")
# backtick tokens containing these are code/CLI snippets, not paths
NON_PATH_CHARS = set(" ()<>{}*=,|§\"'")


def _path_tokens(text: str):
    """Path-like tokens from inline-backtick spans: keep `a/b.py`-style
    references, drop identifiers, CLI flags and code snippets."""
    for tok in re.findall(r"`([^`\n]+)`", text):
        tok = tok.split(":")[0].rstrip("/")          # strip :member anchors
        if not tok or tok.startswith("-") or set(tok) & NON_PATH_CHARS:
            continue
        if "/" in tok or tok.endswith(EXTS):
            yield tok


def _resolves(tok: str) -> bool:
    cands = {tok}
    # module-attr form `pkg/mod.attr` -> pkg/mod.py
    base, dot, _ = tok.rpartition(".")
    if dot and "/" in tok and not tok.endswith(EXTS):
        cands |= {base, base + ".py"}
    for cand in cands:
        for root in ROOTS:
            if (REPO / root / cand).exists():
                return True
    # bare filename cited without its directory (e.g. `ref.py`)
    if "/" not in tok and tok.endswith(EXTS):
        return any(REPO.rglob(tok))
    return False


def _make_targets():
    text = (REPO / "Makefile").read_text()
    return set(re.findall(r"^([A-Za-z0-9_.-]+):", text, flags=re.M))


def test_doc_file_references_exist():
    missing = []
    for doc in DOCS:
        text = (REPO / doc).read_text()
        for tok in _path_tokens(text):
            if not _resolves(tok):
                missing.append(f"{doc}: `{tok}`")
    assert not missing, "dangling file references:\n" + "\n".join(missing)


def test_doc_make_targets_are_defined():
    targets = _make_targets()
    missing = []
    for doc in DOCS:
        text = (REPO / doc).read_text()
        for t in re.findall(r"\bmake ([a-z][a-z0-9_-]*)", text):
            if t not in targets:
                missing.append(f"{doc}: make {t}")
    assert not missing, "undefined make targets:\n" + "\n".join(missing)


def test_design_section_citations_resolve():
    """`DESIGN.md §N` citations across the tree (including the
    core/pgm.py §5 distribution citation) must name a real section."""
    design = (REPO / "docs/DESIGN.md").read_text()
    sections = set(re.findall(r"§(\w+)", design))
    assert sections >= {"1", "2", "3", "4", "5", "6", "7"}
    bad = []
    for py in list(REPO.glob("src/**/*.py")) + list(REPO.glob("tests/*.py")) \
            + list(REPO.glob("benchmarks/*.py")):
        for n in re.findall(r"DESIGN\.md §(\w+)", py.read_text()):
            if n not in sections:
                bad.append(f"{py.relative_to(REPO)}: §{n}")
    assert not bad, "dangling DESIGN.md § citations:\n" + "\n".join(bad)
    # the historically-dangling citation must specifically resolve now
    pgm = (REPO / "src/repro/core/pgm.py").read_text()
    assert "DESIGN.md §5" in pgm and "5" in sections


def test_design_11_rule_catalog_matches_registry():
    """DESIGN.md §11's lint-rule table and the live registry
    (`repro.analysis.all_rules`) must list exactly the same rules —
    adding a rule without documenting it (or documenting a rule that
    was removed) fails here."""
    import sys
    sys.path.insert(0, str(REPO / "src"))
    from repro.analysis import all_rules

    design = (REPO / "docs/DESIGN.md").read_text()
    m = re.search(r"^## §11 .*?(?=^## )", design, flags=re.M | re.S)
    assert m, "DESIGN.md has no §11 section"
    documented = set(re.findall(r"^\| `([a-z][a-z0-9-]*)` \|", m.group(0),
                                flags=re.M))
    registered = set(all_rules())
    assert documented == registered, (
        f"DESIGN.md §11 catalog out of sync with the rule registry: "
        f"undocumented={sorted(registered - documented)}, "
        f"stale={sorted(documented - registered)}")
