"""Mesh-native scanned training (train/engine.py + train/loop.py):

* fast tier — ``run_epochs`` chunking is bit-for-bit identical to
  per-epoch ``run_epoch`` dispatches (and to sequential one-epoch
  chunks when validation/newbob run on device), the chunked training
  loop matches the per-epoch loop, and ``PlanPrefetcher`` returns
  bit-identical plans to synchronous building (including across a
  simulated resume);
* slow tier — subprocess runs on a forced 4-device host platform
  (alongside ``tests/test_sharding.py``) proving the sharded scanned
  epoch is bit-close to the single-device engine on the LM and RNN-T
  smoke configs, and that the sharded + chunked path still compiles
  one epoch executable across selection rounds (asserted through the
  ``analysis.contracts`` retrace contract).
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.analysis.contracts import assert_retrace_free
from repro.configs import get_config
from repro.configs.base import PGMConfig, TrainConfig
from repro.data.pipeline import lm_units
from repro.data.plan_prefetch import PlanPrefetcher
from repro.data.synthetic import make_lm_corpus
from repro.models.api import build_model
from repro.train.engine import EpochEngine, HostEngine, make_engine
from repro.train.loop import train_with_selection
from repro.train.optim import make_update_for

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _lm_setup(n=32, seq=12, epochs=4, optimizer="sgd"):
    cfg = get_config("starcoder2-3b-smoke")
    m = build_model(cfg)
    units = lm_units(make_lm_corpus(0, n, seq, cfg.vocab_size,
                                    hard_fraction=0.4), unit_size=4)
    val = lm_units(make_lm_corpus(7, 16, seq, cfg.vocab_size), unit_size=4)
    tc = TrainConfig(
        lr=0.5, optimizer=optimizer, epochs=epochs,
        pgm=PGMConfig(subset_fraction=0.5, n_partitions=2, select_every=2,
                      warm_start_epochs=1, sketch_dim_h=24, sketch_dim_v=24))
    return m, units, val, tc


def _bitwise_equal(tree_a, tree_b):
    return all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(tree_a),
                               jax.tree.leaves(tree_b)))


# ---------------------------------------------------------------------------
# Chunked dispatch == per-epoch dispatch, bit for bit (fast tier)
# ---------------------------------------------------------------------------

def test_run_epochs_matches_run_epoch_bit_for_bit():
    """One run_epochs chunk must produce exactly the params/opt_state/
    losses of the equivalent sequence of run_epoch dispatches (same lr:
    no validation, so newbob never fires)."""
    m, units, _, tc = _lm_setup()
    opt_init, _ = make_update_for(tc)

    eng_a = EpochEngine(m, tc, units, batch_units=2)
    p_a = m.init_params(jax.random.PRNGKey(0))
    o_a = opt_init(p_a)
    losses_a = []
    for e in range(3):
        p_a, o_a, l = eng_a.run_epoch(p_a, o_a, tc.lr, eng_a.full_plan(e))
        losses_a.append(np.asarray(l))

    eng_b = EpochEngine(m, tc, units, batch_units=2)
    p_b = m.init_params(jax.random.PRNGKey(0))
    o_b = opt_init(p_b)
    plans = [eng_b.full_plan(e) for e in range(3)]
    p_b, o_b, losses_b, vls, lrs, lr_out, prev = eng_b.run_epochs(
        p_b, o_b, tc.lr, float("inf"), plans)

    assert _bitwise_equal((p_a, o_a), (p_b, o_b)), \
        "chunked scan diverged from per-epoch dispatches"
    for i, l in enumerate(losses_a):
        assert np.array_equal(l, np.asarray(losses_b)[i])
    # no validation set: val losses are NaN and lr never anneals
    assert np.isnan(np.asarray(vls)).all()
    assert np.asarray(lrs).tolist() == [tc.lr] * 3
    assert float(lr_out) == tc.lr
    # the whole chunk is one executable: a second chunk of same-shape
    # plans must dispatch with zero fresh XLA compilations
    plans2 = [eng_b.full_plan(e) for e in range(3, 6)]
    with assert_retrace_free("second run_epochs chunk"):
        eng_b.run_epochs(p_b, o_b, tc.lr, float("inf"), plans2)


def test_run_epochs_device_newbob_matches_sequential_chunks():
    """Validation + newbob inside the chunk must match running the same
    epochs as size-1 chunks (lr/prev_loss round-trip through the host
    between them) — chunking changes dispatch, not math."""
    m, units, val, tc = _lm_setup(optimizer="adamw")
    opt_init, _ = make_update_for(tc)

    eng_a = EpochEngine(m, tc, units, val_units=val, batch_units=2)
    p_a = m.init_params(jax.random.PRNGKey(0))
    o_a = opt_init(p_a)
    lr, prev = tc.lr, float("inf")
    seq_vls, seq_lrs = [], []
    for e in range(3):
        p_a, o_a, _, v, ls, lr, prev = eng_a.run_epochs(
            p_a, o_a, lr, prev, [eng_a.full_plan(e)])
        seq_vls.append(float(v[0]))
        seq_lrs.append(float(ls[0]))
        lr, prev = float(lr), float(prev)

    eng_b = EpochEngine(m, tc, units, val_units=val, batch_units=2)
    p_b = m.init_params(jax.random.PRNGKey(0))
    o_b = opt_init(p_b)
    p_b, o_b, _, vls, lrs, _, _ = eng_b.run_epochs(
        p_b, o_b, tc.lr, float("inf"),
        [eng_b.full_plan(e) for e in range(3)])

    assert np.asarray(vls).tolist() == pytest.approx(seq_vls, abs=0)
    assert np.asarray(lrs).tolist() == pytest.approx(seq_lrs, abs=0)
    assert _bitwise_equal((p_a, o_a), (p_b, o_b))
    # annealing must actually have fired at this smoke scale, or the
    # lr comparison above proves nothing
    assert seq_lrs[-1] < tc.lr


def test_chunked_loop_matches_per_epoch_loop():
    """train_with_selection(epoch_chunk=4) must reproduce the per-epoch
    loop: same selections, losses to engine tolerance (the chunked path
    runs newbob in fp32 on device, the per-epoch path in python)."""
    m, units, val, tc = _lm_setup()
    h1 = train_with_selection(m, units, tc, method="pgm", val_units=val,
                              engine="scan")
    h2 = train_with_selection(m, units, tc, method="pgm", val_units=val,
                              engine="scan", epoch_chunk=4)
    assert np.allclose(h1.train_loss, h2.train_loss, atol=1e-3)
    assert np.allclose(h1.val_loss, h2.val_loss, atol=1e-3)
    assert np.allclose(h1.lr, h2.lr, atol=1e-6)
    for sa, sb in zip(h1.selections, h2.selections):
        assert sa["indices"] == sb["indices"]
    assert h1.cost_units == pytest.approx(h2.cost_units)


# ---------------------------------------------------------------------------
# Plan prefetch (fast tier)
# ---------------------------------------------------------------------------

def test_plan_prefetch_is_deterministic_and_bounded():
    m, units, _, tc = _lm_setup()
    eng = EpochEngine(m, tc, units, batch_units=2)
    idx = np.arange(6, dtype=np.int32)
    w = np.linspace(0.5, 2.0, 6).astype(np.float32)

    pf = PlanPrefetcher(max_pending=2)
    assert pf.schedule(("full", 0), lambda: eng.full_plan(0))
    assert pf.schedule(("subset", 0, 1),
                       lambda: eng.subset_plan(idx, w, 1))
    # buffer full: a third schedule is refused, not queued unboundedly
    assert not pf.schedule(("full", 2), lambda: eng.full_plan(2))
    got_full = pf.get(("full", 0), lambda: eng.full_plan(0))
    got_sub = pf.get(("subset", 0, 1), lambda: eng.subset_plan(idx, w, 1))
    # unscheduled key falls back to the synchronous builder
    got_miss = pf.get(("full", 2), lambda: eng.full_plan(2))
    pf.close()
    assert pf.hits == 2 and pf.misses == 1

    for got, want in [(got_full, eng.full_plan(0)),
                      (got_sub, eng.subset_plan(idx, w, 1)),
                      (got_miss, eng.full_plan(2))]:
        assert np.array_equal(np.asarray(got[0]), np.asarray(want[0]))
        assert np.array_equal(np.asarray(got[1]), np.asarray(want[1]))

    # closed prefetcher refuses work instead of leaking a thread
    assert not pf.schedule(("full", 3), lambda: eng.full_plan(3))

    # invalidate frees slots held by keys that will never be fetched
    # (re-keying on a selection round), and re-scheduling a pending key
    # is an idempotent success, not a refusal
    pf2 = PlanPrefetcher(max_pending=1)
    assert pf2.schedule(("subset", 0, 1), lambda: eng.full_plan(1))
    assert pf2.schedule(("subset", 0, 1), lambda: eng.full_plan(1))
    assert not pf2.schedule(("subset", 1, 1), lambda: eng.full_plan(1))
    pf2.invalidate()
    assert pf2.schedule(("subset", 1, 1), lambda: eng.full_plan(1))
    got = pf2.get(("subset", 1, 1), lambda: eng.full_plan(1))
    assert np.array_equal(np.asarray(got[0]),
                          np.asarray(eng.full_plan(1)[0]))
    pf2.close()


def test_plan_prefetch_deterministic_across_resume():
    """A resumed run starts with an empty prefetch buffer; because plan
    builders are pure functions of (seed, epoch, selection), the
    prefetched and freshly-built plans are bit-identical — proven
    end-to-end: prefetch on vs off, and interrupted+resumed vs
    uninterrupted, all produce the same history."""
    import tempfile

    m, units, val, tc = _lm_setup(epochs=6)
    h_on = train_with_selection(m, units, tc, method="pgm", val_units=val,
                                engine="scan", epoch_chunk=2)
    h_off = train_with_selection(m, units, tc, method="pgm", val_units=val,
                                 engine="scan", epoch_chunk=2,
                                 plan_prefetch=False)
    assert h_on.train_loss == h_off.train_loss
    assert h_on.val_loss == h_off.val_loss

    with tempfile.TemporaryDirectory() as d:
        tc4 = TrainConfig(lr=tc.lr, optimizer=tc.optimizer, epochs=4,
                          pgm=tc.pgm)
        train_with_selection(m, units, tc4, method="pgm", val_units=val,
                             engine="scan", epoch_chunk=2, ckpt_dir=d)
        h_res = train_with_selection(m, units, tc, method="pgm",
                                     val_units=val, engine="scan",
                                     epoch_chunk=2, ckpt_dir=d, resume=True)
    assert h_res.train_loss == h_on.train_loss[4:]
    assert h_res.val_loss == h_on.val_loss[4:]


# ---------------------------------------------------------------------------
# Unified engine interface (fast tier)
# ---------------------------------------------------------------------------

def test_make_engine_dispatch_and_host_interface():
    m, units, val, tc = _lm_setup()
    scan = make_engine("scan", m, tc, units, val_units=val, batch_units=2)
    host = make_engine("host", m, tc, units, val_units=val, batch_units=2)
    assert isinstance(scan, EpochEngine) and isinstance(host, HostEngine)
    with pytest.raises(ValueError):
        make_engine("nope", m, tc, units)
    # host plans are the unpadded views over the same schedules
    idx = np.arange(5, dtype=np.int32)
    w = np.ones(5, np.float32)
    hp = host.subset_plan(idx, w, epoch=0)
    sp = scan.subset_plan(idx, w, epoch=0)
    live = scan.plan_live_steps(sp)
    assert np.array_equal(np.asarray(sp[0])[live], hp[0])
    # cost semantics: host charges the paper-style selected fraction
    # (8 units), scan charges the bucketed steps it executes (2 of the
    # 4 full-data steps at batch_units=2)
    assert host.epoch_cost(hp, n_selected=5) == pytest.approx(5 / 8)
    assert sp[0].shape == (2, 2)
    assert scan.epoch_cost(sp) == pytest.approx(0.5)
    # shard_state/restore_sharding are identity/None without a mesh
    p = {"w": np.zeros((2, 2), np.float32)}
    rp, ro = scan.shard_state(p, p)
    assert rp is p and ro is p
    assert scan.restore_sharding(".w", p["w"]) is None
    assert host.restore_sharding(".w", p["w"]) is None


# ---------------------------------------------------------------------------
# Sharded parity (slow tier; forced 4-device subprocess like
# tests/test_sharding.py)
# ---------------------------------------------------------------------------

def _run(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert p.returncode == 0, p.stderr[-3000:]
    return p.stdout


@pytest.mark.slow
def test_sharded_epoch_matches_single_device_lm():
    """The mesh-native scanned epoch (FSDP/TP carry + data-sharded
    batches on a 2x2 mesh) must be bit-close to the single-device scan
    engine — same tolerance family as the host/scan parity tests; rtol
    covers cross-device reduction reordering at loss scale ~15."""
    out = _run(textwrap.dedent("""
        import numpy as np, jax
        from repro.configs import get_config
        from repro.configs.base import PGMConfig, TrainConfig
        from repro.data.pipeline import lm_units
        from repro.data.synthetic import make_lm_corpus
        from repro.models.api import build_model
        from repro.train.loop import train_with_selection
        assert jax.device_count() == 4
        cfg = get_config("starcoder2-3b-smoke")
        m = build_model(cfg)
        units = lm_units(make_lm_corpus(0, 32, 12, cfg.vocab_size,
                                        hard_fraction=0.4), 4)
        val = lm_units(make_lm_corpus(7, 16, 12, cfg.vocab_size), 4)
        tc = TrainConfig(lr=0.5, optimizer="sgd", epochs=4,
                         pgm=PGMConfig(subset_fraction=0.5, n_partitions=2,
                                       select_every=2, warm_start_epochs=1,
                                       sketch_dim_h=24, sketch_dim_v=24))
        h1 = train_with_selection(m, units, tc, method="pgm",
                                  val_units=val, engine="scan")
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        h2 = train_with_selection(m, units, tc, method="pgm",
                                  val_units=val, engine="scan", mesh=mesh)
        assert np.allclose(h1.train_loss, h2.train_loss,
                           rtol=1e-3, atol=1e-3), \\
            (h1.train_loss, h2.train_loss)
        assert np.allclose(h1.val_loss, h2.val_loss,
                           rtol=1e-3, atol=1e-3), (h1.val_loss, h2.val_loss)
        for sa, sb in zip(h1.selections, h2.selections):
            assert sa["indices"] == sb["indices"], (sa, sb)
        assert h1.cost_units == h2.cost_units
        # chunked + sharded stays on the same trajectory
        h3 = train_with_selection(m, units, tc, method="pgm",
                                  val_units=val, engine="scan", mesh=mesh,
                                  epoch_chunk=4)
        assert np.allclose(h2.train_loss, h3.train_loss, atol=1e-3)
        print("SHARDED-LM-OK")
    """))
    assert "SHARDED-LM-OK" in out


@pytest.mark.slow
def test_sharded_epoch_matches_single_device_rnnt():
    out = _run(textwrap.dedent("""
        import numpy as np, jax
        from repro.configs import get_config
        from repro.configs.base import PGMConfig, TrainConfig
        from repro.data.pipeline import asr_units
        from repro.data.synthetic import make_asr_corpus
        from repro.models.api import build_model
        from repro.train.loop import train_with_selection
        cfg = get_config("rnnt-crdnn-smoke")
        m = build_model(cfg)
        r = cfg.rnnt
        units = asr_units(make_asr_corpus(0, 16, n_feats=r.n_feats,
                                          vocab_size=r.vocab_size,
                                          noise_fraction=0.2, snr_db=5.0), 4)
        val = asr_units(make_asr_corpus(5, 8, n_feats=r.n_feats,
                                        vocab_size=r.vocab_size), 4)
        tc = TrainConfig(lr=0.05, optimizer="adamw", epochs=3,
                         pgm=PGMConfig(subset_fraction=0.5, n_partitions=2,
                                       select_every=2, warm_start_epochs=1,
                                       sketch_dim_h=16, sketch_dim_v=16,
                                       val_matching=True))
        h1 = train_with_selection(m, units, tc, method="pgm",
                                  val_units=val, engine="scan")
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        h2 = train_with_selection(m, units, tc, method="pgm",
                                  val_units=val, engine="scan", mesh=mesh,
                                  epoch_chunk=2)
        assert np.allclose(h1.train_loss, h2.train_loss,
                           rtol=1e-3, atol=1e-3), \\
            (h1.train_loss, h2.train_loss)
        assert np.allclose(h1.val_loss, h2.val_loss, rtol=1e-3, atol=1e-3)
        for sa, sb in zip(h1.selections, h2.selections):
            assert sa["indices"] == sb["indices"]
        print("SHARDED-RNNT-OK")
    """))
    assert "SHARDED-RNNT-OK" in out


@pytest.mark.slow
def test_sharded_chunked_path_compiles_one_epoch_executable():
    """Retrace-freedom survives the mesh + chunking: selection rounds
    with different n_selected inside one padding bucket share one
    chunked executable (the full warm-start chunk has its own)."""
    out = _run(textwrap.dedent("""
        import numpy as np, jax
        from repro.analysis.contracts import assert_retrace_free
        from repro.configs import get_config
        from repro.configs.base import PGMConfig, TrainConfig
        from repro.data.pipeline import lm_units
        from repro.data.synthetic import make_lm_corpus
        from repro.models.api import build_model
        from repro.train.engine import EpochEngine
        from repro.train.optim import make_update_for
        cfg = get_config("starcoder2-3b-smoke")
        m = build_model(cfg)
        units = lm_units(make_lm_corpus(0, 128, 12, cfg.vocab_size,
                                        hard_fraction=0.4), 4)
        tc = TrainConfig(lr=0.5, optimizer="sgd", epochs=1,
                         pgm=PGMConfig())
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        eng = EpochEngine(m, tc, units, batch_units=1, mesh=mesh)
        assert eng.steps_per_epoch_max == 32 and eng.plan_granule == 4
        opt_init, _ = make_update_for(tc)
        p = m.init_params(jax.random.PRNGKey(0))
        o = opt_init(p)
        p, o = eng.shard_state(p, o)
        # warm-start: a chunk of 2 full epochs
        p, o, *_ = eng.run_epochs(p, o, tc.lr, float("inf"),
                                  [eng.full_plan(0), eng.full_plan(1)])
        # 3 selection rounds, n_selected all in one bucket, chunks of 2;
        # round 1 compiles the bucket-shape executable, rounds 2-3 must
        # dispatch with zero fresh XLA compilations
        rounds = []
        for rnd, n_sel in enumerate((13, 14, 16)):
            idx = np.arange(n_sel, dtype=np.int32)
            w = np.linspace(0.5, 2.0, n_sel).astype(np.float32)
            plans = [eng.subset_plan(idx, w, epoch=2 * rnd + e)
                     for e in range(2)]
            assert plans[0][0].shape == (16, 1)
            rounds.append(plans)
        p, o, losses, *_ = eng.run_epochs(p, o, tc.lr, float("inf"),
                                          rounds[0])
        with assert_retrace_free("sharded chunked subset rounds"):
            for plans in rounds[1:]:
                p, o, losses, *_ = eng.run_epochs(p, o, tc.lr,
                                                  float("inf"), plans)
                assert np.isfinite(np.asarray(losses)).all()
        print("TRACES-OK")
    """))
    assert "TRACES-OK" in out
