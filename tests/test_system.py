"""End-to-end behaviour tests for the paper's system (Algorithm 1 around a
real model): selection-driven training runs, costs less than full
training, resumes from checkpoints, and the serving engine generates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# full Algorithm-1 training runs (minutes in aggregate) — slow tier; the
# fast tier covers the engine via tests/test_train_engine.py
pytestmark = pytest.mark.slow

from repro.configs import get_config
from repro.configs.base import PGMConfig, TrainConfig
from repro.core.metrics import (
    noise_overlap_index,
    overlap_index,
    relative_test_error,
    speedup,
    training_cost_units,
)
from repro.data.pipeline import lm_units
from repro.data.synthetic import make_lm_corpus
from repro.models.api import build_model
from repro.train.loop import train_with_selection


def _setup(n=48, seq=16, epochs=4):
    cfg = get_config("starcoder2-3b-smoke")
    m = build_model(cfg)
    corpus = make_lm_corpus(0, n, seq, cfg.vocab_size, hard_fraction=0.4)
    units = lm_units(corpus, unit_size=4)
    val = lm_units(make_lm_corpus(7, 16, seq, cfg.vocab_size), unit_size=4)
    tc = TrainConfig(
        lr=0.5, optimizer="sgd", epochs=epochs,
        pgm=PGMConfig(subset_fraction=0.3, n_partitions=4, select_every=2,
                      warm_start_epochs=1, sketch_dim_h=24, sketch_dim_v=24))
    return m, units, val, tc


def test_pgm_training_runs_and_is_cheaper_than_full():
    m, units, val, tc = _setup()
    h_pgm = train_with_selection(m, units, tc, method="pgm", val_units=val)
    h_full = train_with_selection(m, units, tc, method="full", val_units=val)
    assert len(h_pgm.train_loss) == tc.epochs
    assert np.isfinite(h_pgm.val_loss).all()
    assert h_pgm.cost_units < 0.75 * h_full.cost_units
    assert h_pgm.selections, "no selection rounds recorded"
    assert speedup(h_full.cost_units, h_pgm.cost_units) > 1.3


@pytest.mark.parametrize("method", ["random", "large_only", "large_small",
                                    "gradmatch_pb"])
def test_baseline_methods_run(method):
    m, units, val, tc = _setup(epochs=3)
    h = train_with_selection(m, units, tc, method=method, val_units=val)
    assert np.isfinite(h.train_loss[-1])


def test_checkpoint_resume_mid_training(tmp_path):
    m, units, val, tc = _setup(epochs=4)
    d = str(tmp_path / "ck")
    h1 = train_with_selection(m, units, tc, method="pgm", val_units=val,
                              ckpt_dir=d)
    # crash-resume: restart from the latest checkpoint; remaining epochs
    # are strictly fewer than the full run's
    h2 = train_with_selection(m, units, tc, method="pgm", val_units=val,
                              ckpt_dir=d, resume=True)
    assert len(h2.train_loss) < len(h1.train_loss)


def test_selection_recorded_overlap_metrics():
    m, units, val, tc = _setup(epochs=5)
    h = train_with_selection(m, units, tc, method="pgm", val_units=val)
    assert len(h.selections) >= 2
    oi = h.selections[1]["overlap_index"]
    assert 0.0 <= oi <= 1.0
    # metric helpers
    assert overlap_index([1, 2, 3], [2, 3, 4]) == pytest.approx(2 / 3)
    assert noise_overlap_index([0, 1], [True, False, True, False]) == 0.5
    assert relative_test_error(5.5, 5.0) == pytest.approx(10.0)
    assert training_cost_units(30, 2, 0.3, 5, 1 / 3) == pytest.approx(
        2 + 28 * 0.3 + 5 / 3)


def test_serve_engine_generates():
    from repro.serve.engine import generate
    cfg = get_config("starcoder2-3b-smoke")
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    toks, stats = generate(m, params, prompts, max_new_tokens=6)
    assert toks.shape == (2, 6)
    assert (toks >= 0).all() and (toks < cfg.vocab_size).all()
    assert stats.tokens_per_s > 0
    # temperature sampling path
    toks2, _ = generate(m, params, prompts, max_new_tokens=4,
                        temperature=0.8, key=jax.random.PRNGKey(3))
    assert toks2.shape == (2, 4)


def test_pgm_prefers_informative_units_on_rigged_corpus():
    """Rig: half the units are pure padding (mask ~ 0 tokens) — PGM must
    avoid selecting more than a small number of them."""
    cfg = get_config("starcoder2-3b-smoke")
    m = build_model(cfg)
    corpus = make_lm_corpus(3, 32, 16, cfg.vocab_size)
    units = lm_units(corpus, 4)
    # near-zero the loss masks of units 0..7 -> near-zero gradients
    units["loss_mask"][:8] *= 0.0
    units["loss_mask"][:8] += 1e-9
    from repro.core.lastlayer import make_proj_for
    from repro.core.pgm import pgm_select
    params = m.init_params(jax.random.PRNGKey(0))
    pc = PGMConfig(subset_fraction=0.5, n_partitions=1, sketch_dim_h=24,
                   sketch_dim_v=24)
    proj = make_proj_for(m, jax.random.PRNGKey(1), 24, 24)
    sel = pgm_select(m, params, {k: jnp.asarray(v) for k, v in units.items()},
                     pc, proj)
    chosen = [int(i) for i in sel.indices if i >= 0]
    n_empty = sum(1 for i in chosen if i < 8)
    assert n_empty <= 1, (chosen,)
