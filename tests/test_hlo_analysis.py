"""The multiplicity-corrected HLO analyzer vs hand-computed programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze, parse_hlo


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_plain_matmul_flops_exact():
    m, n, k = 64, 96, 128
    a = analyze(_hlo(lambda a, b: a @ b, jnp.zeros((m, k)), jnp.zeros((k, n))))
    assert abs(a.flops - 2 * m * n * k) / (2 * m * n * k) < 0.01


def test_scan_multiplicity():
    T, M = 10, 32

    def scanned(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    a = analyze(_hlo(scanned, jnp.zeros((M, M)), jnp.zeros((T, M, M))))
    want = T * 2 * M ** 3
    assert abs(a.flops - want) / want < 0.05


def test_nested_scan_multiplicity():
    T, M, O = 10, 32, 5

    def nested(x, ws):
        def outer(c, _):
            return jax.lax.scan(lambda ci, w: (ci @ w, None), c, ws)[0], None
        return jax.lax.scan(outer, x, None, length=O)[0]

    a = analyze(_hlo(nested, jnp.zeros((M, M)), jnp.zeros((T, M, M))))
    want = O * T * 2 * M ** 3
    assert abs(a.flops - want) / want < 0.05


def test_bytes_accounting_positive_and_scales():
    M = 64
    a1 = analyze(_hlo(lambda x: x + 1.0, jnp.zeros((M, M))))
    a2 = analyze(_hlo(lambda x: x + 1.0, jnp.zeros((4 * M, 4 * M))))
    assert a2.bytes > a1.bytes > 0


def test_grad_of_scan_counts_backward_loops():
    T, M = 8, 16

    def f(x, ws):
        y = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)[0]
        return jnp.sum(y)

    a = analyze(_hlo(jax.grad(f, argnums=1), jnp.ones((M, M)),
                     jnp.ones((T, M, M))))
    # fwd T matmuls + bwd 2T matmuls (dx and dw), allow fusion slack
    want_min = 2.5 * T * 2 * M ** 3
    assert a.flops >= want_min, a.flops


def test_parse_handles_tuple_types_with_comments():
    txt = """
HloModule m

%body (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4,4]{1,0} get-tuple-element(%p), index=1
  %d = f32[4,4]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %c1 = s32[] constant(1)
  %ip = s32[] add(%i, %c1)
  ROOT %t = (s32[], f32[4,4]) tuple(%ip, %d)
}

%cond (p: (s32[], f32[4,4])) -> pred[] {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[4,4]) -> f32[4,4] {
  %x = f32[4,4]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[4,4]) tuple(%z, %x)
  %w = (s32[], /*index=1*/f32[4,4]{1,0}) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[4,4]{1,0} get-tuple-element(%w), index=1
}
"""
    a = analyze(txt)
    assert a.flops == 7 * 2 * 4 ** 3, a.flops


def test_collective_wire_models():
    txt = """
HloModule m

ENTRY %main (x: f32[1024]) -> f32[1024] {
  %x = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%x), replica_groups=[1,8]<=[8], to_apply=%add
  ROOT %ag = f32[1024]{0} all-gather(%ar), replica_groups={{0,1,2,3}}, dimensions={0}
}
"""
    a = analyze(txt)
    ar = a.collectives["all-reduce"]
    ag = a.collectives["all-gather"]
    assert ar["count"] == 1 and ag["count"] == 1
    assert abs(ar["wire_bytes"] - 2 * 4096 * 7 / 8) < 1
    assert abs(ag["wire_bytes"] - 4096 * 3 / 4) < 1
