"""Serving-path tests (DESIGN.md §4): the three seed `generate` bug
regressions (first-token eos, live-token accounting, k-step termination
sync), sampling/determinism contracts, continuous-batching slot-reuse
parity against one-shot `generate`, and RNN-T streaming greedy decode
against the non-streaming reference on the CRDNN smoke."""
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.contracts import (assert_donated,
                                      assert_no_host_transfers,
                                      assert_retrace_free)
from repro.configs import get_config
from repro.models.api import build_model
from repro.serve.engine import (Request, SlotEngine, generate,
                                rnnt_greedy_reference)


@pytest.fixture(scope="module")
def lm():
    cfg = get_config("starcoder2-3b-smoke")
    bundle = build_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    return cfg, bundle, params


@pytest.fixture(scope="module")
def rnnt():
    cfg = get_config("rnnt-crdnn-smoke")
    bundle = build_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    return cfg, bundle, params


def _prompts(cfg, B=3, Sp=10, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), (B, Sp), 0,
                              cfg.vocab_size).astype(jnp.int32)


def _trim(row, eos):
    row = [int(t) for t in row]
    return row[: row.index(eos) + 1] if eos in row else row


# ---------------------------------------------------------------------------
# seed-bug regressions
# ---------------------------------------------------------------------------

def test_first_token_eos_stops_decode(lm):
    """Seed bug: `done` ignored the token sampled from prefill logits, so
    a prompt whose first greedy token is eos still decoded max_new
    steps."""
    cfg, bundle, params = lm
    prompts = _prompts(cfg, B=2)
    free_run, _ = generate(bundle, params, prompts, 6, eos_id=None)
    eos = int(free_run[0, 0])
    toks, stats = generate(bundle, params, prompts[:1], 6, eos_id=eos)
    assert stats.decode_steps == 0
    assert stats.decode_tokens == 0
    assert toks.shape == (1, 1) and int(toks[0, 0]) == eos


def test_stats_count_live_decode_tokens_only(lm):
    """Seed bug: `tokens_out = int(tokens.size)` billed the prefill-
    sampled token and post-eos eos padding to decode-phase tok/s."""
    cfg, bundle, params = lm
    B, new = 3, 7
    toks, stats = generate(bundle, params, _prompts(cfg, B=B), new,
                           eos_id=None)
    assert toks.shape == (B, new)
    assert stats.prefill_tokens == B                # prefill's token
    assert stats.decode_tokens == B * (new - 1)     # not B * new
    assert stats.decode_steps == new - 1
    assert stats.prompt_tokens == B * 10
    assert stats.tokens_per_s > 0

    # with eos: tokens emitted after an example finishes are not billed
    eos = int(toks[0, 2])
    toks_e, stats_e = generate(bundle, params, _prompts(cfg, B=B), new,
                               eos_id=eos, sync_every=1)
    live = 0
    for row in np.asarray(toks_e):
        done_at = _trim(row, eos)
        live += len(done_at) - 1            # first token is prefill's
    assert stats_e.decode_tokens <= live    # never counts beyond eos
    assert stats_e.decode_tokens < B * (new - 1)


def test_k_step_sync_greedy_outputs_unchanged(lm):
    """Seed bug: `bool(done.all())` forced a host sync every token.  The
    k-step check must leave greedy outputs unchanged up to eos."""
    cfg, bundle, params = lm
    prompts = _prompts(cfg, B=3)
    base, _ = generate(bundle, params, prompts, 8, eos_id=None)
    eos = int(base[1, 3])                   # some mid-stream token
    per_step, _ = generate(bundle, params, prompts, 8, eos_id=eos,
                           sync_every=1)
    k_step, _ = generate(bundle, params, prompts, 8, eos_id=eos,
                         sync_every=4)
    for a, b in zip(np.asarray(per_step), np.asarray(k_step)):
        assert _trim(a, eos) == _trim(b, eos)


# ---------------------------------------------------------------------------
# generate contracts
# ---------------------------------------------------------------------------

def test_greedy_determinism(lm):
    cfg, bundle, params = lm
    prompts = _prompts(cfg)
    a, _ = generate(bundle, params, prompts, 6)
    b, _ = generate(bundle, params, prompts, 6)
    assert (np.asarray(a) == np.asarray(b)).all()


def test_temperature_sampling_shape_dtype(lm):
    cfg, bundle, params = lm
    toks, _ = generate(bundle, params, _prompts(cfg, B=2), 5,
                       temperature=0.8, key=jax.random.PRNGKey(7))
    assert toks.shape == (2, 5)
    assert toks.dtype == jnp.int32
    assert (np.asarray(toks) >= 0).all()
    assert (np.asarray(toks) < cfg.vocab_size).all()


def test_generate_rejects_rnnt(rnnt):
    cfg, bundle, params = rnnt
    with pytest.raises(ValueError, match="RNN-T"):
        generate(bundle, params, jnp.zeros((1, 4), jnp.int32), 4)


# ---------------------------------------------------------------------------
# continuous batching: slot reuse parity vs one-shot generate
# ---------------------------------------------------------------------------

def test_slot_engine_lm_parity_with_oneshot(lm):
    """More requests than slots, mixed prompt lengths across buckets,
    eos terminations: every completion must equal the one-shot greedy
    decode of the same prompt, trimmed at eos."""
    cfg, bundle, params = lm
    rng = np.random.default_rng(0)
    lens = [5, 9, 12, 17, 7, 3]
    eos = 7
    reqs = [Request(uid=i,
                    inputs={"tokens": rng.integers(
                        0, cfg.vocab_size, (L,)).astype(np.int32)},
                    max_new_tokens=10)
            for i, L in enumerate(lens)]
    eng = SlotEngine(bundle, params, n_slots=2, max_new_tokens=10,
                     max_prompt_len=24, eos_id=eos, sync_every=4)
    comps = eng.run(reqs)
    assert len(comps) == len(reqs)
    assert eng.n_admits == len(reqs)        # slots were reused
    got = {c.uid: c.tokens for c in comps}
    for r in reqs:
        toks, _ = generate(bundle, params,
                           jnp.asarray(r.inputs["tokens"])[None], 10,
                           eos_id=eos, sync_every=1)
        assert got[r.uid] == _trim(np.asarray(toks)[0], eos), r.uid


def test_slot_engine_steady_state_is_recompile_free(lm):
    """The continuous-batching zero-recompile claim (DESIGN §4),
    asserted through the shared ``analysis.contracts`` retrace
    contract: after one request has warmed the admit/decode
    executables for a bucket, serving a full house of same-bucket
    requests — admissions into previously untouched slots, evictions,
    slot reuse — must dispatch zero new XLA compilations.  (The
    eviction sweep's old per-slot ``out[slot]`` device fetch is guarded
    separately, by the ``host-sync-loop`` lint.)"""
    cfg, bundle, params = lm
    rng = np.random.default_rng(5)

    def reqs(uids):
        return [Request(uid=u,
                        inputs={"tokens": rng.integers(
                            0, cfg.vocab_size, (6,)).astype(np.int32)},
                        max_new_tokens=4) for u in uids]

    eng = SlotEngine(bundle, params, n_slots=4, max_new_tokens=4,
                     max_prompt_len=8, eos_id=None, sync_every=2)
    # warm-up touches only one slot (slots are handed out LIFO), so any
    # per-slot executable would still be cold for the other three
    eng.run(reqs([0]))
    with assert_retrace_free("slot-engine steady state"):
        comps = eng.run(reqs(range(1, 9)))
    assert sorted(c.uid for c in comps) == list(range(1, 9))
    assert all(len(c.tokens) == 4 for c in comps)


def test_slot_engine_decode_donates_pool_and_stays_resident(lm):
    """Level-2 contracts on the decode-scan executable: the slot-state
    pool (the engine's carry) is donated back into itself, and the
    scanned body contains no host transfers — the one sync per scan
    happens outside the executable, in the host loop."""
    cfg, bundle, params = lm
    eng = SlotEngine(bundle, params, n_slots=2, max_new_tokens=4,
                     max_prompt_len=8, eos_id=None, sync_every=2)
    low = eng._decode_jit.lower(params, eng._state,
                                jax.random.PRNGKey(0))
    assert_donated(low, eng._state, skip=params)
    assert_no_host_transfers(low, low.compile().as_text())


def test_slot_engine_respects_budget_and_bounds(lm):
    cfg, bundle, params = lm
    rng = np.random.default_rng(2)
    reqs = [Request(uid=i,
                    inputs={"tokens": rng.integers(
                        0, cfg.vocab_size, (6,)).astype(np.int32)},
                    max_new_tokens=b)
            for i, b in enumerate([1, 3, 5])]
    eng = SlotEngine(bundle, params, n_slots=3, max_new_tokens=8,
                     max_prompt_len=16, eos_id=None)
    got = {c.uid: c.tokens for c in eng.run(reqs)}
    assert [len(got[i]) for i in range(3)] == [1, 3, 5]
    too_long = Request(uid=9, inputs={"tokens": np.zeros(99, np.int32)},
                       max_new_tokens=4)
    with pytest.raises(ValueError, match="exceeds"):
        eng.run([too_long])


# ---------------------------------------------------------------------------
# bounded queue + deadlines (DESIGN.md §10 graceful degradation)
# ---------------------------------------------------------------------------

class _StepClock:
    """Deterministic clock: every read advances time by ``dt`` — the
    engine's own call pattern becomes the (repeatable) passage of time,
    so deadline tests need no sleeps and no wall-clock."""

    def __init__(self, dt=1.0):
        self.t = 0.0
        self.dt = dt

    def __call__(self):
        self.t += self.dt
        return self.t


def _req(cfg, uid, *, rng, max_new=6, arrival=0.0, deadline=None, L=6):
    return Request(uid=uid,
                   inputs={"tokens": rng.integers(
                       0, cfg.vocab_size, (L,)).astype(np.int32)},
                   max_new_tokens=max_new, arrival_s=arrival,
                   deadline_s=deadline)


def test_bounded_queue_rejects_overflow_with_backpressure(lm):
    """With ``max_queue`` set, arrivals beyond the bound are rejected
    immediately (empty completion, ``status="rejected"``) instead of
    growing host memory; everything admitted still completes."""
    cfg, bundle, params = lm
    rng = np.random.default_rng(3)
    reqs = [_req(cfg, i, rng=rng, max_new=4) for i in range(6)]
    eng = SlotEngine(bundle, params, n_slots=1, max_new_tokens=4,
                     max_prompt_len=16, eos_id=None, max_queue=2)
    comps = {c.uid: c for c in eng.run(reqs)}
    assert len(comps) == len(reqs)
    rejected = [c for c in comps.values() if c.status == "rejected"]
    served = [c for c in comps.values() if c.status == "ok"]
    # all six arrive in one sweep before any admission: the queue keeps
    # the first 2, the other 4 are rejected on arrival
    assert eng.n_rejected == len(rejected) == 4
    assert sorted(c.uid for c in served) == [0, 1]
    for c in rejected:
        assert c.tokens == [] and np.isnan(c.admit_s)
    for c in served:
        assert len(c.tokens) == 4 and np.isfinite(c.admit_s)


def test_unbounded_queue_is_legacy_default(lm):
    cfg, bundle, params = lm
    rng = np.random.default_rng(4)
    reqs = [_req(cfg, i, rng=rng, max_new=2) for i in range(5)]
    eng = SlotEngine(bundle, params, n_slots=1, max_new_tokens=2,
                     max_prompt_len=16, eos_id=None)
    comps = eng.run(reqs)
    assert eng.n_rejected == 0
    assert all(c.status == "ok" for c in comps)


def test_queued_deadline_expires_without_taking_a_slot(lm):
    """A request whose deadline passes while it waits in the queue is
    dropped with ``status="expired"`` and zero tokens — it never holds a
    decode slot — while patient requests behind it still complete."""
    cfg, bundle, params = lm
    rng = np.random.default_rng(5)
    clock = _StepClock(dt=1.0)
    # uid 0 occupies the only slot for many scans; uid 1's deadline is
    # far shorter than uid 0's decode; uid 2 waits without a deadline
    reqs = [_req(cfg, 0, rng=rng, max_new=32),
            _req(cfg, 1, rng=rng, max_new=4, deadline=3.0),
            _req(cfg, 2, rng=rng, max_new=4)]
    eng = SlotEngine(bundle, params, n_slots=1, max_new_tokens=32,
                     max_prompt_len=16, eos_id=None, clock=clock)
    comps = {c.uid: c for c in eng.run(reqs)}
    assert comps[1].status == "expired" and comps[1].tokens == []
    assert np.isnan(comps[1].admit_s)
    assert comps[0].status == "ok" and len(comps[0].tokens) == 32
    assert comps[2].status == "ok" and len(comps[2].tokens) == 4
    assert eng.n_expired == 1


def test_mid_decode_deadline_evicts_dead_slot_and_frees_it(lm):
    """A request that expires mid-decode is killed on device (live mask
    cleared — a dead-slot no-op, no retrace), read out with its partial
    tokens, and its slot is immediately reusable."""
    cfg, bundle, params = lm
    rng = np.random.default_rng(6)
    clock = _StepClock(dt=1.0)
    # sync_every=1: one token per scan, several clock ticks per scan ->
    # uid 0's deadline hits after at least one emission, well before its
    # 64-token budget; uid 1 then reuses the freed slot
    reqs = [_req(cfg, 0, rng=rng, max_new=64, deadline=40.0),
            _req(cfg, 1, rng=rng, max_new=3)]
    eng = SlotEngine(bundle, params, n_slots=1, max_new_tokens=64,
                     max_prompt_len=16, eos_id=None, sync_every=1,
                     clock=clock)
    comps = {c.uid: c for c in eng.run(reqs)}
    assert comps[0].status == "expired"
    assert 0 < len(comps[0].tokens) < 64        # partial output survives
    assert np.isfinite(comps[0].admit_s)        # it DID hold a slot
    assert eng.n_expired == 1
    assert comps[1].status == "ok" and len(comps[1].tokens) == 3
    assert eng.n_admits == 2                    # slot was reused


def test_deadline_output_prefix_matches_unexpired_run(lm):
    """Expiry must not corrupt decoding: the partial tokens of an
    expired request are a prefix of what the same prompt produces
    without a deadline."""
    cfg, bundle, params = lm
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)

    def run_one(deadline, clock):
        eng = SlotEngine(bundle, params, n_slots=1, max_new_tokens=32,
                         max_prompt_len=16, eos_id=None, sync_every=1,
                         clock=clock)
        (c,) = eng.run([Request(uid=0, inputs={"tokens": prompt},
                                max_new_tokens=32, deadline_s=deadline)])
        return c

    full = run_one(None, time.time)
    cut = run_one(30.0, _StepClock(dt=1.0))
    assert cut.status == "expired" and full.status == "ok"
    assert 0 < len(cut.tokens) < len(full.tokens)
    assert full.tokens[: len(cut.tokens)] == cut.tokens


# ---------------------------------------------------------------------------
# RNN-T streaming decode
# ---------------------------------------------------------------------------

def test_pred_step_matches_predict(rnnt):
    """Token-by-token prediction-network stepping must reproduce the
    batch `predict` rows exactly (same GRU, same blank-start state)."""
    from repro.models import rnnt as rnnt_mod
    cfg, bundle, params = rnnt
    toks = jnp.asarray([[3, 9, 1, 14]], jnp.int32)
    ref = rnnt_mod.predict(params, cfg, toks)
    g, h = rnnt_mod.pred_start(params, cfg, 1)
    rows = [g]
    for u in range(toks.shape[1]):
        g, h = rnnt_mod.pred_step(params, cfg, toks[:, u], h)
        rows.append(g)
    assert np.array_equal(np.asarray(ref), np.asarray(jnp.stack(rows, 1)))


def test_rnnt_streaming_matches_reference(rnnt):
    """Slot-engine streaming greedy transducer decode must match the
    textbook per-frame host loop token for token.  The reference sees
    the same bucket-padded feats the engine prefills (the bi-LSTM
    encoder is bidirectional, so padding participates — exactly as in
    padded training batches)."""
    cfg, bundle, params = rnnt
    F = cfg.rnnt.n_feats
    rng = np.random.default_rng(1)
    lens = [40, 25, 48, 33]
    reqs = [Request(uid=i, inputs={"feats": rng.normal(
                size=(L, F)).astype(np.float32)}, max_new_tokens=128)
            for i, L in enumerate(lens)]
    eng = SlotEngine(bundle, params, n_slots=2, max_new_tokens=128,
                     max_prompt_len=64, sync_every=4, max_symbols=8)
    got = {c.uid: c.tokens for c in eng.run(reqs)}
    for r in reqs:
        L = r.inputs["feats"].shape[0]
        bucket = eng.bucket_for(r)
        feats = np.zeros((1, bucket, F), np.float32)
        feats[0, :L] = r.inputs["feats"]
        ref = rnnt_greedy_reference(bundle, params, feats,
                                    np.asarray([L]), max_symbols=8)[0]
        assert got[r.uid] == ref, r.uid
