"""Transducer loss vs brute-force lattice DP + gradient sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import rand_cases
from repro.core.rnnt_loss import rnnt_loss_from_logits


def _ref(logits, labels, t_len, u_len, blank=0):
    lp = np.array(jax.nn.log_softmax(jnp.asarray(logits), axis=-1))
    U = u_len
    alpha = np.full((t_len, U + 1), -np.inf)
    alpha[0, 0] = 0.0
    for t in range(t_len):
        for u in range(U + 1):
            if t == 0 and u == 0:
                continue
            c = []
            if t > 0:
                c.append(alpha[t - 1, u] + lp[t - 1, u, blank])
            if u > 0:
                c.append(alpha[t, u - 1] + lp[t, u - 1, labels[u - 1]])
            alpha[t, u] = np.logaddexp.reduce(c)
    return -(alpha[t_len - 1, U] + lp[t_len - 1, U, blank])


@pytest.mark.slow
@pytest.mark.parametrize("seed,T,U,V",
                         rand_cases(6, 7, seed=range(50), T=[4, 7, 11],
                                    U=[2, 4, 6], V=[5, 13]))
def test_rnnt_loss_matches_bruteforce(seed, T, U, V):
    rng = np.random.default_rng(seed)
    B = 3
    logits = rng.normal(size=(B, T, U + 1, V)).astype(np.float32)
    labels = rng.integers(1, V, size=(B, U)).astype(np.int32)
    t_lens = rng.integers(max(U, 2), T + 1, B).astype(np.int32)
    u_lens = rng.integers(1, U + 1, B).astype(np.int32)
    got = np.asarray(rnnt_loss_from_logits(
        jnp.asarray(logits), jnp.asarray(labels), jnp.asarray(t_lens),
        jnp.asarray(u_lens)))
    want = np.array([_ref(logits[b], labels[b], int(t_lens[b]),
                          int(u_lens[b])) for b in range(B)])
    assert np.allclose(got, want, atol=1e-4), np.abs(got - want).max()


@pytest.mark.slow
def test_rnnt_loss_grad_finite_and_nonzero():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(2, 6, 4, 5)), jnp.float32)
    labels = jnp.asarray(rng.integers(1, 5, (2, 3)), jnp.int32)
    g = jax.grad(lambda lg: rnnt_loss_from_logits(
        lg, labels, jnp.asarray([6, 5]), jnp.asarray([3, 2])).sum())(logits)
    assert jnp.isfinite(g).all()
    assert float(jnp.abs(g).sum()) > 0
    # positions outside the (t_len, u_len) lattice get zero gradient
    assert float(jnp.abs(g[1, 5]).sum()) == 0.0


def test_rnnt_loss_perfect_model_low_loss():
    """Logits that put all mass on the correct alignment => small NLL."""
    B, T, U, V = 1, 4, 2, 4
    labels = jnp.asarray([[1, 2]], jnp.int32)
    logits = np.full((B, T, U + 1, V), -20.0, np.float32)
    # alignment: emit 1 at (0,0), 2 at (0,1), blanks down the rest
    logits[0, 0, 0, 1] = 20.0
    logits[0, 0, 1, 2] = 20.0
    for t in range(T):
        logits[0, t, 2, 0] = 20.0
    nll = rnnt_loss_from_logits(jnp.asarray(logits), labels,
                                jnp.asarray([T]), jnp.asarray([U]))
    assert float(nll[0]) < 1e-2, float(nll[0])
