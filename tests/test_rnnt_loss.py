"""Transducer loss vs brute-force lattice DP + gradient sanity, and the
fused custom_vjp path vs the dense autodiff oracle (values, gradients,
finite differences, compiled peak memory)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import rand_cases
from repro.core.rnnt_loss import rnnt_loss_from_logits, rnnt_loss_fused


def _ref(logits, labels, t_len, u_len, blank=0):
    lp = np.array(jax.nn.log_softmax(jnp.asarray(logits), axis=-1))
    U = u_len
    alpha = np.full((t_len, U + 1), -np.inf)
    alpha[0, 0] = 0.0
    for t in range(t_len):
        for u in range(U + 1):
            if t == 0 and u == 0:
                continue
            c = []
            if t > 0:
                c.append(alpha[t - 1, u] + lp[t - 1, u, blank])
            if u > 0:
                c.append(alpha[t, u - 1] + lp[t, u - 1, labels[u - 1]])
            alpha[t, u] = np.logaddexp.reduce(c)
    return -(alpha[t_len - 1, U] + lp[t_len - 1, U, blank])


@pytest.mark.slow
@pytest.mark.parametrize("seed,T,U,V",
                         rand_cases(6, 7, seed=range(50), T=[4, 7, 11],
                                    U=[2, 4, 6], V=[5, 13]))
def test_rnnt_loss_matches_bruteforce(seed, T, U, V):
    rng = np.random.default_rng(seed)
    B = 3
    logits = rng.normal(size=(B, T, U + 1, V)).astype(np.float32)
    labels = rng.integers(1, V, size=(B, U)).astype(np.int32)
    t_lens = rng.integers(max(U, 2), T + 1, B).astype(np.int32)
    u_lens = rng.integers(1, U + 1, B).astype(np.int32)
    got = np.asarray(rnnt_loss_from_logits(
        jnp.asarray(logits), jnp.asarray(labels), jnp.asarray(t_lens),
        jnp.asarray(u_lens)))
    want = np.array([_ref(logits[b], labels[b], int(t_lens[b]),
                          int(u_lens[b])) for b in range(B)])
    assert np.allclose(got, want, atol=1e-4), np.abs(got - want).max()


@pytest.mark.slow
def test_rnnt_loss_grad_finite_and_nonzero():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(2, 6, 4, 5)), jnp.float32)
    labels = jnp.asarray(rng.integers(1, 5, (2, 3)), jnp.int32)
    g = jax.grad(lambda lg: rnnt_loss_from_logits(
        lg, labels, jnp.asarray([6, 5]), jnp.asarray([3, 2])).sum())(logits)
    assert jnp.isfinite(g).all()
    assert float(jnp.abs(g).sum()) > 0
    # positions outside the (t_len, u_len) lattice get zero gradient
    assert float(jnp.abs(g[1, 5]).sum()) == 0.0


def test_rnnt_loss_perfect_model_low_loss():
    """Logits that put all mass on the correct alignment => small NLL."""
    B, T, U, V = 1, 4, 2, 4
    labels = jnp.asarray([[1, 2]], jnp.int32)
    logits = np.full((B, T, U + 1, V), -20.0, np.float32)
    # alignment: emit 1 at (0,0), 2 at (0,1), blanks down the rest
    logits[0, 0, 0, 1] = 20.0
    logits[0, 0, 1, 2] = 20.0
    for t in range(T):
        logits[0, t, 2, 0] = 20.0
    nll = rnnt_loss_from_logits(jnp.asarray(logits), labels,
                                jnp.asarray([T]), jnp.asarray([U]))
    assert float(nll[0]) < 1e-2, float(nll[0])


# ---------------------------------------------------------------------------
# Fused custom_vjp path vs the dense oracle
# ---------------------------------------------------------------------------

def _factors(seed, B, T, U, J, V):
    rng = np.random.default_rng(seed)
    ze = jnp.asarray(rng.normal(size=(B, T, J)), jnp.float32)
    zp = jnp.asarray(rng.normal(size=(B, U + 1, J)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(J, V)) * 0.5, jnp.float32)
    labels = jnp.asarray(rng.integers(1, V, (B, U)), jnp.int32)
    return ze, zp, w, labels


def _dense_nll(ze, zp, w, labels, t_lens, u_lens):
    logits = jnp.tanh(ze[:, :, None, :] + zp[:, None, :, :]) @ w
    return rnnt_loss_from_logits(logits, labels, t_lens, u_lens)


# edge lengths: t_lens == 1, u_lens == 0, u_lens == U, and ragged rows
_EDGE_LENS = [
    ("full", [7, 7, 7], [4, 4, 4]),
    ("t_len_1", [1, 7, 1], [4, 2, 0]),
    ("u_len_0", [7, 5, 3], [0, 0, 0]),
    ("u_len_U", [7, 6, 5], [4, 4, 4]),
    ("ragged", [7, 1, 4], [4, 0, 2]),
]


@pytest.mark.parametrize("name,t_lens,u_lens", _EDGE_LENS,
                         ids=[e[0] for e in _EDGE_LENS])
@pytest.mark.parametrize("vocab_chunk", [0, 5])
def test_fused_matches_dense_values(name, t_lens, u_lens, vocab_chunk):
    B, T, U, J, V = 3, 7, 4, 6, 13
    ze, zp, w, labels = _factors(0, B, T, U, J, V)
    t_lens = jnp.asarray(t_lens, jnp.int32)
    u_lens = jnp.asarray(u_lens, jnp.int32)
    want = _dense_nll(ze, zp, w, labels, t_lens, u_lens)
    got = rnnt_loss_fused(ze, zp, w, labels, t_lens, u_lens,
                          vocab_chunk=vocab_chunk, lattice_impl="ref")
    assert jnp.allclose(got, want, atol=1e-5), \
        float(jnp.abs(got - want).max())


@pytest.mark.parametrize("name,t_lens,u_lens", _EDGE_LENS,
                         ids=[e[0] for e in _EDGE_LENS])
@pytest.mark.parametrize("vocab_chunk", [0, 5])
def test_fused_grads_match_dense_autodiff(name, t_lens, u_lens, vocab_chunk):
    """custom_vjp analytic gradients vs plain autodiff through the
    materialized lattice, for every factor, at rtol 1e-4."""
    B, T, U, J, V = 3, 7, 4, 6, 13
    ze, zp, w, labels = _factors(1, B, T, U, J, V)
    t_lens = jnp.asarray(t_lens, jnp.int32)
    u_lens = jnp.asarray(u_lens, jnp.int32)
    # non-uniform per-example cotangent exercises the (B,) pullback
    wgt = jnp.asarray(np.random.default_rng(2).uniform(0.5, 1.5, B),
                      jnp.float32)
    gd = jax.grad(lambda ze, zp, w: jnp.sum(
        _dense_nll(ze, zp, w, labels, t_lens, u_lens) * wgt),
        argnums=(0, 1, 2))(ze, zp, w)
    gf = jax.grad(lambda ze, zp, w: jnp.sum(
        rnnt_loss_fused(ze, zp, w, labels, t_lens, u_lens,
                        vocab_chunk=vocab_chunk, lattice_impl="ref") * wgt),
        argnums=(0, 1, 2))(ze, zp, w)
    for name_g, a, b in zip(("dze", "dzp", "dw_out"), gd, gf):
        rel = float(jnp.abs(a - b).max() / (jnp.abs(a).max() + 1e-9))
        assert rel < 1e-4, (name_g, rel)


def test_fused_grad_finite_difference_spot_check():
    B, T, U, J, V = 2, 5, 3, 4, 9
    ze, zp, w, labels = _factors(4, B, T, U, J, V)
    t_lens = jnp.asarray([5, 3], jnp.int32)
    u_lens = jnp.asarray([3, 1], jnp.int32)
    f = lambda w: float(rnnt_loss_fused(ze, zp, w, labels, t_lens, u_lens,
                                        lattice_impl="ref").sum())
    g = jax.grad(lambda w: rnnt_loss_fused(
        ze, zp, w, labels, t_lens, u_lens, lattice_impl="ref").sum())(w)
    eps = 1e-3
    for (i, j) in [(0, 0), (2, 5), (3, 8)]:
        fd = (f(w.at[i, j].add(eps)) - f(w.at[i, j].add(-eps))) / (2 * eps)
        assert abs(fd - float(g[i, j])) < 5e-3, ((i, j), fd, float(g[i, j]))


def test_fused_vocab_chunking_invariant():
    B, T, U, J, V = 2, 6, 3, 5, 17
    ze, zp, w, labels = _factors(5, B, T, U, J, V)
    t_lens = jnp.asarray([6, 4], jnp.int32)
    u_lens = jnp.asarray([3, 2], jnp.int32)
    outs = [rnnt_loss_fused(ze, zp, w, labels, t_lens, u_lens,
                            vocab_chunk=c, lattice_impl="ref")
            for c in (0, 4, 17, 64)]
    for o in outs[1:]:
        assert jnp.allclose(outs[0], o, atol=1e-5)


def test_shared_vocab_chunk_layout():
    """The pad/reshape/validity layout is one shared helper
    (``core/chunking.py``) consumed by both the fused loss's
    ``_vocab_chunks`` and ``core/lastlayer.py:streamed_er2`` — asserted
    here against the layout spec so the convention cannot drift."""
    from repro.core.chunking import (chunk_vocab_axis, resolve_vocab_chunk,
                                     vocab_chunk_mask, vocab_chunks)
    from repro.core.rnnt_loss import _vocab_chunks

    rng = np.random.default_rng(0)
    J, V, chunk = 5, 17, 4
    w = jnp.asarray(rng.normal(size=(J, V)), jnp.float32)

    wp, valid = _vocab_chunks(w, chunk)
    nc = -(-V // chunk)
    assert wp.shape == (nc, J, chunk) and valid.shape == (nc, chunk)
    # reassembling the chunks (dropping padded columns) recovers the head
    back = np.moveaxis(np.asarray(wp), 0, 1).reshape(J, nc * chunk)[:, :V]
    assert np.array_equal(back, np.asarray(w))
    # padded tail columns are zero-filled and masked invalid
    assert np.asarray(wp)[-1, :, V % chunk:].sum() == 0.0
    want_valid = (np.arange(nc * chunk).reshape(nc, chunk) < V)
    assert np.array_equal(np.asarray(valid), want_valid)

    # the streamed_er2 orientation: vocab on axis 0 of the projection
    rv = jnp.asarray(rng.normal(size=(V, 3)), jnp.float32)
    rvc = chunk_vocab_axis(rv, chunk, axis=0)
    assert rvc.shape == (nc, chunk, 3)
    assert np.array_equal(np.asarray(rvc).reshape(nc * chunk, 3)[:V],
                          np.asarray(rv))

    # chunk resolution: <=0 means one whole-vocab chunk, oversize is
    # capped (no padding past the vocabulary)
    assert resolve_vocab_chunk(V, 0) == V
    assert resolve_vocab_chunk(V, -3) == V
    assert resolve_vocab_chunk(V, 1000) == V
    assert resolve_vocab_chunk(V, 4) == 4
    wp1, valid1 = vocab_chunks(w, V, axis=1)
    assert wp1.shape == (1, J, V) and bool(valid1.all())
    assert np.array_equal(np.asarray(vocab_chunk_mask(V, V)),
                          np.ones((1, V), bool))

    # both consumers produce identical values through the shared layout:
    # the fused loss is chunk-invariant and streamed_er2 matches its
    # dense equivalent at this chunking
    from repro.core.lastlayer import streamed_er2
    N, d = 6, J
    h = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    targets = jnp.asarray(rng.integers(0, V, N), jnp.int32)
    scale = jnp.asarray(rng.uniform(0.5, 1.5, N), jnp.float32)
    got = streamed_er2(h, w, targets, scale, rv, chunk=chunk)
    p = jax.nn.softmax(h @ w, axis=-1)
    e = (p - jax.nn.one_hot(targets, V)) * scale[:, None]
    assert np.allclose(np.asarray(got), np.asarray(e @ rv), atol=1e-5)


def test_fused_grad_zero_outside_lattice():
    """Frames past t_len contribute nothing — matching the dense oracle's
    masking semantics on the encoder-side factor."""
    B, T, U, J, V = 2, 6, 3, 4, 9
    ze, zp, w, labels = _factors(6, B, T, U, J, V)
    t_lens = jnp.asarray([6, 4], jnp.int32)
    u_lens = jnp.asarray([3, 2], jnp.int32)
    g = jax.grad(lambda ze: rnnt_loss_fused(
        ze, zp, w, labels, t_lens, u_lens, lattice_impl="ref").sum())(ze)
    assert jnp.isfinite(g).all()
    assert float(jnp.abs(g[1, 4:]).sum()) == 0.0
    assert float(jnp.abs(g[0]).sum()) > 0


def test_fused_grad_step_peak_memory_below_joint_tensor():
    """The acceptance bar for the fused path: the compiled grad step's
    temp memory stays below one (B, T, U+1, V) joint tensor, while the
    dense oracle's is necessarily above it (it materializes the joint
    plus autodiff residuals)."""
    B, T, U, J, V = 2, 40, 8, 12, 512
    ze, zp, w, labels = _factors(7, B, T, U, J, V)
    t_lens = jnp.full((B,), T, jnp.int32)
    u_lens = jnp.full((B,), U, jnp.int32)
    joint_bytes = 4 * B * T * (U + 1) * V

    def temp_bytes(loss):
        f = jax.jit(jax.grad(
            lambda ze, zp, w: loss(ze, zp, w).sum(), argnums=(0, 1, 2)))
        ma = f.lower(ze, zp, w).compile().memory_analysis()
        return int(ma.temp_size_in_bytes)

    fused_t = temp_bytes(lambda ze, zp, w: rnnt_loss_fused(
        ze, zp, w, labels, t_lens, u_lens, lattice_impl="ref"))
    dense_t = temp_bytes(lambda ze, zp, w: _dense_nll(
        ze, zp, w, labels, t_lens, u_lens))
    assert fused_t < joint_bytes, (fused_t, joint_bytes)
    assert dense_t > joint_bytes, (dense_t, joint_bytes)
