"""Sharding/distribution tests on an 8-host-device mesh (subprocess so the
main test process keeps its single device).  Exercises: SpecBuilder rules,
shard_map PGM stage B, compressed psum, and a reduced-config train-step
lower+compile per policy."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

# every test here lowers+compiles in an 8-device subprocess — slow tier
pytestmark = pytest.mark.slow

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert p.returncode == 0, p.stderr[-3000:]
    return p.stdout


def test_spec_builder_rules():
    out = _run(textwrap.dedent("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.sharding.specs import SpecBuilder
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        sb = SpecBuilder(mesh)
        # wq: (d, q_dim) -> (fsdp, tp)
        assert sb.param_spec(".blocks.attn.wq", (64, 64)) == P("data", "model")
        # indivisible dims are left unsharded
        assert sb.param_spec(".blocks.attn.wq", (63, 64)) == P(None, "model")
        # stacked group params get a leading None
        assert sb.param_spec(".groups.attn.wq", (4, 64, 64)) == \
            P(None, "data", "model")
        # embed: vocab over model in tp mode
        assert sb.param_spec(".embed.w", (80, 64)) == P("model", "data")
        # moe experts over model when divisible
        s = sb.param_spec(".moe.w_in", (8, 64, 64))
        assert s == P("model", "data", None), s
        # fsdp_sp mode: no tp; params over all axes
        sb2 = SpecBuilder(mesh, mode="fsdp_sp")
        assert sb2.param_spec(".blocks.mlp.w_in", (64, 64)) == \
            P(("data", "model"), None)
        assert sb2.batch_spec("tokens", (16, 32)) == P("data", "model")
        print("SPECS-OK")
    """))
    assert "SPECS-OK" in out


def test_spec_builder_expert_mode():
    """Expert-axis mode (DESIGN.md §8): expert banks shard their leading
    E dim over ``expert`` (or fall back to fsdp without that axis),
    routers replicate, indivisible expert banks raise a ValueError that
    names the arch, and the pod axis never leaks into param specs."""
    out = _run(textwrap.dedent("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.sharding.specs import SpecBuilder
        mesh = jax.make_mesh((2, 2, 2), ("data", "expert", "model"))
        sb = SpecBuilder(mesh, mode="expert", arch="mixtral-8x7b")
        # stacked expert banks: E over 'expert', d over fsdp, dff over tp
        s = sb.param_spec(".groups.moe.w_in", (2, 4, 64, 32))
        assert s == P(None, "expert", "data", "model"), s
        s = sb.param_spec(".moe.w_out", (4, 32, 64))
        assert s == P("expert", "model", "data"), s
        # routers replicate in expert mode
        assert sb.param_spec(".moe.router", (64, 4)) == P(None, None)
        # dense params keep the tp rules ('expert' never carries them)
        assert sb.param_spec(".blocks.attn.wq", (64, 64)) == \\
            P("data", "model")
        # indivisible expert banks fail loudly, naming the arch
        try:
            sb.param_spec(".moe.w_in", (3, 64, 32))
            raise SystemExit("expected ValueError")
        except ValueError as e:
            assert "mixtral-8x7b" in str(e) and "expert" in str(e), e
        # no expert axis on the mesh: E falls back to the fsdp axis and
        # the d dim is left alone (never shard one axis twice)
        mesh2 = jax.make_mesh((4, 2), ("data", "model"))
        sb2 = SpecBuilder(mesh2, mode="expert", arch="olmoe-1b-7b")
        s = sb2.param_spec(".moe.w_in", (4, 64, 32))
        assert s == P("data", None, "model"), s
        # the pod axis is excluded from both fsdp and expert fallback
        mesh3 = jax.make_mesh((2, 2, 2), ("data", "expert", "pod"))
        sb3 = SpecBuilder(mesh3, mode="expert", pod_axis="pod", arch="x")
        for name, shape in ((".moe.w_in", (2, 64, 32)),
                            (".blocks.attn.wq", (64, 64)),
                            (".embed.w", (80, 64))):
            spec = sb3.param_spec(name, shape)
            flat = jax.tree_util.tree_leaves(tuple(spec))
            assert "pod" not in flat, (name, spec)
        print("EXPERT-SPECS-OK")
    """))
    assert "EXPERT-SPECS-OK" in out


def test_expert_specs_round_trip_shard_state_restore():
    """Engine round-trip on a ``data x expert`` mesh: every leaf that
    ``shard_state`` places must come back with the identical sharding
    from ``restore_sharding`` given its checkpoint key path — elastic
    restore cannot silently change the expert placement."""
    out = _run(textwrap.dedent("""
        import numpy as np, jax
        import jax.tree_util as jtu
        from repro.configs import get_config
        from repro.configs.base import PGMConfig, TrainConfig
        from repro.data.pipeline import lm_units
        from repro.data.synthetic import make_lm_corpus
        from repro.models.api import build_model
        from repro.train.engine import EpochEngine
        from repro.train.optim import make_update_for
        cfg = get_config("mixtral-8x7b-smoke")
        m = build_model(cfg)
        units = lm_units(make_lm_corpus(0, 8, 10, cfg.vocab_size), 2)
        tc = TrainConfig(lr=0.2, optimizer="sgd", epochs=1,
                         pgm=PGMConfig())
        mesh = jax.make_mesh((2, 2), ("data", "expert"))
        eng = EpochEngine(m, tc, units, batch_units=2, mesh=mesh,
                          spec_mode="expert")
        opt_init, _ = make_update_for(tc)
        p = m.init_params(jax.random.PRNGKey(0))
        o = opt_init(p)
        p, o = eng.shard_state(p, o)
        n = 0
        for tree, ck in ((p, "params"), (o, "opt")):
            for path, leaf in jtu.tree_flatten_with_path(tree)[0]:
                got = eng.restore_sharding(
                    f"['{ck}']" + jtu.keystr(path), np.asarray(leaf))
                assert got.spec == leaf.sharding.spec, \\
                    (ck, jtu.keystr(path), got.spec, leaf.sharding.spec)
                n += 1
        assert n > 10, n
        print("EXPERT-ROUNDTRIP-OK")
    """))
    assert "EXPERT-ROUNDTRIP-OK" in out


def test_pgm_stage_b_shard_map_matches_single_device():
    out = _run(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import PGMConfig
        from repro.core.pgm import partitioned_gm, pgm_select_sharded
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
        pc = PGMConfig(subset_fraction=0.25, n_partitions=8)
        ref = partitioned_gm(g, 8, 1, pc.lam, pc.eps, pc.nonneg_weights)
        got = pgm_select_sharded(mesh, "data", g, pc)
        ri = sorted(int(i) for i in ref.indices if i >= 0)
        gi = sorted(int(i) for i in got.indices if i >= 0)
        assert ri == gi, (ri, gi)
        assert int(got.n_selected) == int(ref.n_selected)
        print("PGM-SHARDMAP-OK")
    """))
    assert "PGM-SHARDMAP-OK" in out


def test_compressed_psum_modes():
    out = _run(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.train.compress import compressed_psum, init_error_state
        mesh = jax.make_mesh((8,), ("pod",))
        g = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        err = init_error_state({"w": jnp.zeros((8,))})
        def f(gl):
            red, _ = compressed_psum(gl, "pod", mode="bf16")
            return red
        out = shard_map(f, mesh=mesh, in_specs=({"w": P("pod")},),
                        out_specs={"w": P("pod")})(g)
        # the collective reduces AT bf16 width (cast before the pmean, so
        # the wire moves half the bytes): mean computed in bf16, then
        # upcast
        want = jnp.broadcast_to(
            g["w"].astype(jnp.bfloat16).mean(0).astype(jnp.float32), (8, 8))
        assert jnp.allclose(out["w"], want, atol=0.5), (out["w"][0], want[0])
        assert out["w"].dtype == jnp.float32
        print("PSUM-OK")
    """))
    assert "PSUM-OK" in out


@pytest.mark.parametrize("arch,policy", [
    ("minitron-8b", None),            # fsdp_sp auto
    ("mixtral-8x7b", None),           # tp/EP auto
    ("rwkv6-3b", None),               # fsdp_batch auto
])
def test_reduced_train_step_compiles_on_mesh(arch, policy):
    """Lower+compile the real train step with smoke-sized configs on a
    (2,4) mesh — fast proxy for the 512-device dry-run cells."""
    out = _run(textwrap.dedent(f"""
        import jax
        import repro.launch.dryrun as dr
        import repro.configs as C
        orig = C.get_config
        dr.get_config = lambda name: orig(name + "-smoke")
        import repro.launch.roofline as rf
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        fn, args = dr.build_step({arch!r}, "train_4k", mesh,
                                 policy={policy!r})
        compiled = fn.lower(*args).compile()
        assert compiled.as_text()
        print("COMPILE-OK")
    """))
    assert "COMPILE-OK" in out


def test_decode_step_compiles_on_mesh():
    out = _run(textwrap.dedent("""
        import jax
        import repro.launch.dryrun as dr
        import repro.configs as C
        orig = C.get_config
        dr.get_config = lambda name: orig(name + "-smoke")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        fn, args = dr.build_step("gemma3-27b", "decode_32k", mesh)
        compiled = fn.lower(*args).compile()
        print("DECODE-COMPILE-OK")
    """))
    assert "DECODE-COMPILE-OK" in out
