"""Properties of Gradient Matching (Algorithm 2) and the OMP solver."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import rand_cases
from repro.core.gm import gm_select, gram, gram_omp


@pytest.mark.parametrize("seed,n,D", rand_cases(6, 0, seed=range(100),
                                                n=[16, 32, 64],
                                                D=[32, 64, 128]))
def test_omp_recovers_planted_sparse_combination(seed, n, D):
    rng = np.random.default_rng(seed)
    G = jnp.asarray(rng.normal(size=(n, D)), jnp.float32)
    idx = rng.choice(n, 3, replace=False)
    w = np.zeros(n, np.float32)
    w[idx] = [2.0, 1.5, 1.0]
    g_t = jnp.asarray(w) @ G
    res = gm_select(G, g_t, budget=5, lam=1e-4)
    got = {int(i) for i in res.indices if i >= 0}
    assert set(int(i) for i in idx) <= got


@pytest.mark.parametrize("seed", range(4))
def test_omp_error_monotone_in_budget(seed):
    rng = np.random.default_rng(seed)
    G = jnp.asarray(rng.normal(size=(40, 64)), jnp.float32)
    g_t = jnp.asarray(rng.normal(size=(64,)), jnp.float32) * 3
    errs = [float(gm_select(G, g_t, budget=b, lam=1e-3).error)
            for b in (1, 2, 4, 8, 16)]
    for a, b in zip(errs, errs[1:]):
        assert b <= a + 1e-4, errs


def test_omp_no_duplicate_selection():
    rng = np.random.default_rng(3)
    G = jnp.asarray(rng.normal(size=(12, 32)), jnp.float32)
    g_t = G.sum(axis=0)
    res = gm_select(G, g_t, budget=12, lam=1e-3)
    sel = [int(i) for i in res.indices if i >= 0]
    assert len(sel) == len(set(sel)), sel


def test_omp_respects_budget_and_padding():
    rng = np.random.default_rng(4)
    G = jnp.asarray(rng.normal(size=(20, 16)), jnp.float32)
    res = gm_select(G, G[3] * 2.0, budget=4, lam=1e-6)
    assert int(res.n_selected) <= 4
    # padded slots carry -1 / weight 0
    for i, w in zip(res.indices, res.weights):
        if int(i) < 0:
            assert float(w) == 0.0


def test_omp_nonneg_weights():
    rng = np.random.default_rng(5)
    G = jnp.asarray(rng.normal(size=(30, 48)), jnp.float32)
    g_t = jnp.abs(jnp.asarray(rng.normal(size=(48,))))
    res = gm_select(G, g_t, budget=10, lam=1e-3, nonneg=True)
    assert float(res.weights.min()) >= 0.0


def test_omp_eps_early_stop():
    """If one atom matches the target exactly, OMP stops after one pick."""
    rng = np.random.default_rng(6)
    G = jnp.asarray(rng.normal(size=(10, 32)), jnp.float32)
    res = gm_select(G, G[7], budget=8, lam=1e-8, eps=1e-3)
    assert int(res.n_selected) <= 2
    assert 7 in [int(i) for i in res.indices if i >= 0]


def test_gram_matches_kernel_oracle():
    from repro.kernels.omp_gram.ops import omp_gram_op
    rng = np.random.default_rng(7)
    g = jnp.asarray(rng.normal(size=(33, 70)), jnp.float32)
    a = gram(g)
    b = omp_gram_op(g, use_pallas=True, interpret=True)
    assert jnp.allclose(a, b, atol=1e-3)
