import os
import sys

# smoke tests and benches must see the single real CPU device; ONLY the
# dry-run (its own subprocess) forces 512 host devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
