"""Chaos harness (DESIGN.md §10): every deterministic fault injector in
``train/faults.py`` must recover along its documented path.

The guard's exactness contract anchors the suite: a non-finite step
gated off in-scan is bit-identical to training the same schedule with
that batch as a padding row (``FaultPlan(drop_step=...)`` builds exactly
that fault-free reference run), so the faulted LM-smoke run's final val
loss matches its fault-free reference to 0.0 — well within the 1e-3
acceptance tolerance.  The transparent faults (prefetch crash,
preemption + resume, corrupt-checkpoint fallback, kernel fallback)
reproduce the *unfaulted* trajectory outright.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.contracts import assert_retrace_free
from repro.configs import get_config
from repro.configs.base import PGMConfig, TrainConfig
from repro.core.lastlayer import make_proj_for
from repro.core.pgm import ResidentSelector
from repro.data.pipeline import lm_units
from repro.data.synthetic import make_lm_corpus
from repro.models.api import build_model
from repro.train import checkpoint as ckpt_mod
from repro.train import faults
from repro.train.engine import EpochEngine
from repro.train.loop import train_with_selection
from repro.train.optim import make_update_for

pytestmark = pytest.mark.chaos


@pytest.fixture(scope="module")
def lm():
    cfg = get_config("starcoder2-3b-smoke")
    bundle = build_model(cfg)
    units = lm_units(make_lm_corpus(0, 32, 10, cfg.vocab_size,
                                    hard_fraction=0.4), unit_size=4)
    val = lm_units(make_lm_corpus(7, 8, 10, cfg.vocab_size), unit_size=4)
    return bundle, units, val


def _tc(**kw):
    base = dict(lr=0.5, optimizer="sgd", epochs=6, seed=0,
                nonfinite_guard=True,
                pgm=PGMConfig(subset_fraction=0.75, n_partitions=2,
                              select_every=2, warm_start_epochs=2))
    base.update(kw)
    return TrainConfig(**base)


def _run(lm, tc, fault_plan=None, *, ckpt_dir=None, resume=False,
         log_fn=None, epoch_chunk=2):
    bundle, units, val = lm
    return train_with_selection(
        bundle, units, tc, method="pgm", val_units=val, engine="scan",
        epoch_chunk=epoch_chunk, fault_plan=fault_plan, ckpt_dir=ckpt_dir,
        resume=resume, log_fn=log_fn or (lambda s: None))


def _bitwise_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# in-scan non-finite guard: exactness + retrace-freedom (engine level)
# ---------------------------------------------------------------------------

def test_guard_on_finite_data_is_bitwise_and_never_retraces(lm):
    """Guard-on over all-finite data must be bitwise identical to
    guard-off (the gate selects the new state everywhere), and a
    poisoned epoch must reuse the same executable — non-finiteness is
    traced data, not a trace constant."""
    bundle, units, _ = lm
    opt_init, _ = make_update_for(_tc())
    runs = {}
    for guard in (False, True):
        tc = _tc(nonfinite_guard=guard)
        eng = EpochEngine(bundle, tc, units, batch_units=2)
        p = bundle.init_params(jax.random.PRNGKey(0))
        o = opt_init(p)
        p, o, losses = eng.run_epoch(p, o, tc.lr, eng.full_plan(0))
        runs[guard] = (p, o, losses, eng)
    for a, b in zip(runs[False][:3], runs[True][:3]):
        assert _bitwise_equal(a, b)
    eng = runs[True][3]
    assert int(eng.last_n_skipped) == 0
    # poisoned epoch on the SAME engine: one step skipped, no retrace —
    # non-finiteness is traced data, so the warm executable must serve it
    idx, w = eng.full_plan(1)
    w = np.array(w, np.float32)
    w[1] = np.nan
    w = jnp.asarray(w)
    with assert_retrace_free("guarded epoch on a poisoned plan"):
        p, o, losses = eng.run_epoch(*runs[True][:2], _tc().lr, (idx, w))
    assert int(eng.last_n_skipped) == 1
    assert np.asarray(eng.last_skipped).tolist() == [0.0, 1.0, 0.0, 0.0]
    assert float(losses[1]) == 0.0          # skipped step reports 0
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree.leaves(p))


def test_skipped_step_equals_padding_row_bitwise(lm):
    """The documented skip semantics: a guarded-off NaN step leaves the
    carry (params, opt state — step counter included) bit-identical to
    running the same plan with that row as padding."""
    bundle, units, _ = lm
    tc = _tc()
    eng = EpochEngine(bundle, tc, units, batch_units=2)
    opt_init, _ = make_update_for(tc)
    idx, w = (np.asarray(eng.full_plan(0)[0]),
              np.asarray(eng.full_plan(0)[1], np.float32))
    poisoned_w = w.copy()
    poisoned_w[2] = np.nan
    padded_idx, padded_w = idx.copy(), w.copy()
    padded_idx[2], padded_w[2] = -1, 0.0
    outs = []
    for pi, pw in ((idx, poisoned_w), (padded_idx, padded_w)):
        p = bundle.init_params(jax.random.PRNGKey(0))
        o = opt_init(p)
        outs.append(eng.run_epoch(p, o, tc.lr,
                                  (jnp.asarray(pi), jnp.asarray(pw)))[:2])
    assert _bitwise_equal(outs[0], outs[1])


# ---------------------------------------------------------------------------
# end-to-end fault runs (loop level)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_nan_and_inf_step_faults_recover_exactly(lm):
    """A NaN (and an Inf) batch mid-run is skipped once and the run
    completes on the trajectory of its fault-free reference — the run
    that trained the same schedule without that batch — with the final
    val loss matching to well under 1e-3 (it is bitwise equal)."""
    tc = _tc()
    h_ref = _run(lm, tc, faults.FaultPlan(drop_step=(1, 2)))
    h_nan = _run(lm, tc, faults.FaultPlan(nan_step=(1, 2)))
    h_inf = _run(lm, tc, faults.FaultPlan(inf_step=(1, 2)))
    for h in (h_nan, h_inf):
        assert len(h.val_loss) == tc.epochs       # the run completed
        assert h.skipped_steps == 1
        assert h.rollbacks == 0
        assert np.isfinite(h.val_loss).all()
        assert abs(h.val_loss[-1] - h_ref.val_loss[-1]) < 1e-3
        assert _bitwise_equal(h.final_params, h_ref.final_params)
    assert h_ref.skipped_steps == 0               # reference ran fault-free


@pytest.mark.slow
def test_nan_epoch_trips_watchdog_rollback(lm, tmp_path):
    """An epoch of consecutive skips >= max_skipped_steps rolls back to
    the last good checkpoint with a re-keyed plan; the fire-once fault
    is gone on replay, so the run finishes finite with one rollback."""
    tc = _tc(epochs=4, max_skipped_steps=4)
    logs = []
    h = _run(lm, tc, faults.FaultPlan(nan_epoch=2),
             ckpt_dir=str(tmp_path / "ck"), log_fn=logs.append,
             epoch_chunk=1)
    assert h.rollbacks == 1
    assert h.skipped_steps >= tc.max_skipped_steps
    assert any("watchdog" in l and "rolling back" in l for l in logs)
    assert any("rolled back to epoch" in l for l in logs)
    assert len(h.val_loss) == tc.epochs
    assert np.isfinite(h.val_loss).all()
    assert np.isfinite(h.train_loss).all()


@pytest.mark.slow
def test_corrupt_checkpoint_falls_back_to_previous_intact(lm, tmp_path):
    """Byte-flipping the newest checkpoint must degrade resume to the
    previous intact step — and from there the rebuilt plans reproduce
    the uninterrupted run's tail exactly."""
    tc = _tc(epochs=4)
    d = str(tmp_path / "ck")
    h_full = _run(lm, tc, ckpt_dir=d, epoch_chunk=1)
    latest = ckpt_mod.latest_step(d)
    faults.corrupt_checkpoint(d)
    logs = []
    _, manifest = ckpt_mod.restore_latest_intact(d, log_fn=logs.append)
    assert manifest["step"] < latest
    assert any(f"step_{latest} unusable" in l for l in logs)
    # resume re-runs the epochs after the intact step on the same plans
    h_res = _run(lm, tc, ckpt_dir=d, resume=True, epoch_chunk=1)
    start = manifest["step"] + 1
    assert h_res.val_loss == h_full.val_loss[start:]
    assert h_res.train_loss == h_full.train_loss[start:]


def test_tampered_arrays_reports_every_corrupted_key(tmp_path):
    """A checksum failure must name ALL corrupted arrays, not die on the
    first — the operator needs the blast radius in one message."""
    d = str(tmp_path / "ck")
    tree = {"a": np.arange(6, dtype=np.float32),
            "b": np.ones((2, 3), np.float32),
            "c": np.zeros(4, np.int32)}
    ckpt_mod.save(d, 0, tree)
    targets = faults.tamper_arrays(d, keys=["['a']", "['c']"])
    with pytest.raises(IOError, match="2 array"):
        ckpt_mod.restore(d)
    try:
        ckpt_mod.restore(d)
    except IOError as e:
        for k in targets:
            assert k in str(e), (k, str(e))
    # verify=False still loads (escape hatch), intact keys are usable
    arrays, _ = ckpt_mod.restore(d, verify=False)
    assert np.array_equal(arrays["['b']"], tree["b"])


@pytest.mark.slow
def test_preemption_writes_resumable_checkpoint(lm, tmp_path):
    """SIGTERM finishes the in-flight chunk, writes an emergency
    checkpoint with a ``preempted`` manifest marker and exits; resuming
    continues on the uninterrupted run's exact trajectory."""
    tc = _tc()
    d = str(tmp_path / "ck")
    h_full = _run(lm, tc)
    logs = []
    h_cut = _run(lm, tc, faults.FaultPlan(preempt_after_epoch=1),
                 ckpt_dir=d, log_fn=logs.append)
    assert h_cut.preempted
    assert len(h_cut.val_loss) < tc.epochs
    assert any("emergency checkpoint" in l for l in logs)
    manifest = ckpt_mod.read_manifest(d)
    assert manifest["extra"].get("preempted") is True
    h_res = _run(lm, tc, ckpt_dir=d, resume=True)
    start = manifest["extra"]["epoch"] + 1
    assert h_cut.val_loss + h_res.val_loss == h_full.val_loss
    assert h_res.val_loss == h_full.val_loss[start:]


@pytest.mark.slow
def test_prefetch_worker_crash_is_transparent(lm):
    """A transient plan-builder failure is retried in place; because
    builders are pure, the recovered run is bit-identical to the
    fault-free one."""
    tc = _tc()
    h_clean = _run(lm, tc)
    fp = faults.FaultPlan(prefetch_fail_epochs=(1, 3))
    h_fault = _run(lm, tc, fp)
    assert ("prefetch", 1) in fp._fired and ("prefetch", 3) in fp._fired
    assert h_fault.train_loss == h_clean.train_loss
    assert h_fault.val_loss == h_clean.val_loss
    assert h_fault.skipped_steps == 0


# ---------------------------------------------------------------------------
# selection degradation ladder (pallas -> xla -> soft-random)
# ---------------------------------------------------------------------------

def _selector_setup(lm, **pgm_kw):
    bundle, units, _ = lm
    pc = dataclasses.replace(_tc().pgm, **pgm_kw)
    proj = make_proj_for(bundle, jax.random.PRNGKey(17),
                         pc.sketch_dim_h, pc.sketch_dim_v)
    units_dev = {k: jnp.asarray(v) for k, v in units.items()}
    params = bundle.init_params(jax.random.PRNGKey(0))
    return bundle, pc, proj, units_dev, params


def test_kernel_failure_falls_back_to_bit_identical_xla(lm):
    """A failing Pallas selection round warns once, re-jits stage A on
    the XLA path and returns exactly what a pure-XLA selector returns."""
    bundle, pc, proj, units_dev, params = _selector_setup(
        lm, kernel_impl="pallas")
    ref = ResidentSelector(
        bundle, dataclasses.replace(pc, kernel_impl="xla"), proj
    )(params, units_dev)
    logs = []
    with faults.failing_selection_kernels(("pallas",)):
        rs = ResidentSelector(bundle, pc, proj, log_fn=logs.append)
        sel = rs(params, units_dev)
        sel2 = rs(params, units_dev)      # later rounds stay on XLA
    assert rs.kernel_impl == "xla"
    assert rs.degraded_rounds == 0        # fallback is NOT degradation
    assert np.array_equal(np.asarray(sel.indices), np.asarray(ref.indices))
    assert np.allclose(np.asarray(sel.weights), np.asarray(ref.weights))
    assert np.array_equal(np.asarray(sel2.indices),
                          np.asarray(ref.indices))
    assert sum("falling back" in l for l in logs) == 1   # warn-once


def test_total_scorer_failure_degrades_to_soft_random(lm):
    """Both kernel backends failing degrades the round to a soft-random
    subset of the right budget (training continues) and counts it; the
    fail-fast policy raises instead."""
    bundle, pc, proj, units_dev, params = _selector_setup(
        lm, kernel_impl="pallas")
    n_units = units_dev["tokens"].shape[0]
    budget = max(int(pc.subset_fraction * n_units), 1)
    logs = []
    with faults.failing_selection_kernels(("all",)):
        rs = ResidentSelector(bundle, pc, proj, log_fn=logs.append)
        sel = rs(params, units_dev)
    assert rs.degraded_rounds == 1
    assert int(sel.n_selected) == budget
    idx = np.asarray(sel.indices)
    live = idx[idx >= 0]
    assert len(set(live.tolist())) == budget        # distinct real units
    assert np.allclose(np.asarray(sel.weights)[idx >= 0], 1.0)
    assert any("soft-random" in l for l in logs)
    with faults.failing_selection_kernels(("all",)):
        rs2 = ResidentSelector(bundle, pc, proj, on_failure="raise")
        with pytest.raises(RuntimeError, match="injected kernel failure"):
            rs2(params, units_dev)


@pytest.mark.slow
def test_training_survives_total_scorer_failure(lm):
    """End-to-end: resident selection with every backend failing still
    trains to a finite final loss on the soft-random baseline."""
    bundle, units, val = lm
    tc = _tc(epochs=4)
    with faults.failing_selection_kernels(("all",)):
        h = train_with_selection(
            bundle, units, tc, method="pgm", val_units=val, engine="scan",
            resident_selection=True, log_fn=lambda s: None)
    assert len(h.val_loss) == tc.epochs
    assert np.isfinite(h.val_loss).all()
    assert h.selections                      # rounds still recorded
