"""Minimal property-based testing helper (hypothesis is not installed in
this offline environment — recorded in DESIGN.md §2).

``sweep(cases)(fn)`` runs fn over explicit + seeded-random cases;
``rand_cases`` generates shape/seed tuples deterministically so failures
reproduce exactly by seed."""
from __future__ import annotations

import functools
import itertools
from typing import Callable, Iterable, List, Sequence

import numpy as np
import pytest


def rand_cases(n_cases: int, rng_seed: int, /, **dims: Sequence):
    """Deterministic random combinations of the given dimension choices."""
    rng = np.random.default_rng(rng_seed)
    keys = list(dims)
    out = []
    for i in range(n_cases):
        out.append(tuple(dims[k][rng.integers(len(dims[k]))] for k in keys))
    return out


def sweep(cases: Iterable):
    cases = [c if isinstance(c, tuple) else (c,) for c in cases]
    ids = ["-".join(str(x) for x in c) for c in cases]

    def deco(fn: Callable):
        # a plain wrapper (not functools.wraps): pytest must see the
        # single 'case' parameter, but needs the original test name
        def runner(case):
            fn(*case)

        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return pytest.mark.parametrize("case", cases, ids=ids)(runner)

    return deco
