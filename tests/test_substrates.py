"""Substrate tests: optimizer, newbob, checkpoint (atomic/async/corruption/
elastic restore), gradient compression, data pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import (
    asr_units,
    full_iterator,
    lm_units,
    subset_iterator,
    unit_durations,
)
from repro.data.synthetic import make_asr_corpus, make_lm_corpus
from repro.train import checkpoint as ck
from repro.train.compress import init_error_state, topk_compress
from repro.train.optim import (
    NewbobState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    sgd_init,
    sgd_update,
)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _quad_problem():
    p = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(5.0)}
    loss = lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
    return p, loss


@pytest.mark.parametrize("opt", ["sgd", "sgd_mom", "adamw"])
def test_optimizers_converge_on_quadratic(opt):
    p, loss = _quad_problem()
    if opt == "adamw":
        st = adamw_init(p)
        upd = lambda p, g, s: adamw_update(p, g, s, lr=0.3)
    else:
        mom = 0.9 if opt == "sgd_mom" else 0.0
        st = sgd_init(p, mom)
        upd = lambda p, g, s: sgd_update(p, g, s, lr=0.1, momentum=mom)
    for _ in range(100):
        g = jax.grad(loss)(p)
        p, st = upd(p, g, st)
    assert float(loss(p)) < 1e-2, float(loss(p))


def test_grad_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert float(norm) == 20.0


def test_newbob_anneals_on_plateau():
    nb = NewbobState(2.0)
    nb = nb.update(10.0)             # first epoch: no anneal
    assert nb.lr == 2.0
    nb = nb.update(5.0)              # big improvement: keep
    assert nb.lr == 2.0
    nb = nb.update(4.999)            # tiny improvement: anneal x0.8
    assert abs(nb.lr - 1.6) < 1e-9


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    t = _tree()
    ck.save(d, 3, t, extra={"epoch": 3})
    restored, manifest = ck.restore(d, template=t)
    assert manifest["step"] == 3 and manifest["extra"]["epoch"] == 3
    assert jnp.allclose(restored["params"]["w"], t["params"]["w"])
    assert int(restored["opt"]["step"]) == 7


def test_checkpoint_corruption_detected(tmp_path):
    d = str(tmp_path / "ck")
    ck.save(d, 1, _tree())
    # flip bytes in the array file
    p = os.path.join(d, "step_1", "arrays.npz")
    data = bytearray(open(p, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(p, "wb").write(bytes(data))
    with pytest.raises(Exception):
        ck.restore(d, template=_tree())


def test_checkpoint_latest_and_atomicity(tmp_path):
    d = str(tmp_path / "ck")
    for s in (1, 5, 3):
        ck.save(d, s, _tree())
    assert ck.latest_step(d) == 3          # LATEST pointer, not max
    # a stale tmp dir must not break anything
    os.makedirs(os.path.join(d, ".tmp_9"), exist_ok=True)
    ck.save(d, 9, _tree())
    assert ck.latest_step(d) == 9


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "ck")
    ac = ck.AsyncCheckpointer(d)
    for s in range(3):
        ac.submit(s, _tree(), {"epoch": s})
    ac.close()
    assert ck.latest_step(d) == 2
    _, manifest = ck.restore(d, template=_tree())
    assert manifest["extra"]["epoch"] == 2


def test_elastic_restore_resharding(tmp_path):
    """Restore with a sharding_fn placing arrays on the (single) device —
    exercises the elastic-resharding code path."""
    d = str(tmp_path / "ck")
    ck.save(d, 0, _tree())
    dev = jax.devices()[0]
    sh = jax.sharding.SingleDeviceSharding(dev)
    restored, _ = ck.restore(d, template=_tree(),
                             sharding_fn=lambda path, a: sh)
    assert restored["params"]["w"].sharding == sh


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_topk_error_feedback_preserves_mass():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)),
                          jnp.float32)}
    err = init_error_state(g)
    sent, new_err = topk_compress(g, err, k_frac=0.25)
    # sent + residual == original
    assert jnp.allclose(sent["w"] + new_err["w"], g["w"], atol=1e-6)
    nz = int((sent["w"] != 0).sum())
    assert nz <= 17  # 25% of 64 + threshold ties
    # second round: residual is re-injected
    sent2, err2 = topk_compress(g, new_err, k_frac=0.25)
    assert jnp.allclose(sent2["w"] + err2["w"], g["w"] + new_err["w"],
                        atol=1e-6)


def test_compressed_sgd_still_converges():
    """top-k + error feedback on a quadratic still reaches the optimum."""
    p = jnp.asarray(np.random.default_rng(1).normal(size=(32,)), jnp.float32)
    err = {"p": jnp.zeros_like(p)}
    loss = lambda p: 0.5 * jnp.sum(p ** 2)
    for _ in range(300):
        g = {"p": jax.grad(loss)(p)}
        sent, err = topk_compress(g, err, k_frac=0.1)
        p = p - 0.2 * sent["p"]
    assert float(loss(p)) < 1e-3, float(loss(p))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_lm_corpus_structure():
    c = make_lm_corpus(0, 64, 32, 300, hard_fraction=0.5, noise_fraction=0.2)
    assert c.tokens.shape == (64, 32)
    assert 0.4 <= c.difficulty.mean() <= 0.6
    assert int(c.noisy.sum()) == 12
    assert (c.tokens[np.arange(64), np.maximum(c.lengths - 1, 0)] > 0).all()


def test_asr_corpus_learnable():
    c = make_asr_corpus(0, 16, n_feats=8, vocab_size=10)
    assert c.feats.shape[0] == 16
    assert (c.token_lens >= 4).all()


def test_iterators_deterministic_and_weighted():
    c = make_lm_corpus(0, 32, 16, 100)
    units = lm_units(c, 4)
    a = [b["tokens"].sum() for b in full_iterator(units, seed=1, epoch=2)]
    b = [b["tokens"].sum() for b in full_iterator(units, seed=1, epoch=2)]
    assert a == b
    c2 = [x["tokens"].sum() for x in full_iterator(units, seed=1, epoch=3)]
    assert a != c2                         # reshuffled across epochs
    idx, w = np.asarray([0, 3, 5]), np.asarray([2.0, 1.0, 0.5])
    batches = list(subset_iterator(units, idx, w, seed=0, epoch=0))
    assert len(batches) == 3
    for bt in batches:
        assert set(np.unique(bt["weights"])) <= {0.5, 1.0, 2.0}
    dur = unit_durations(units)
    assert dur.shape == (8,)
