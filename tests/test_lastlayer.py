"""Last-layer gradient extraction + tensor-JL sketching properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.lastlayer import (
    lm_unit_exact,
    lm_unit_sketch,
    make_proj_for,
    rnnt_unit_exact,
    streamed_er2,
    units_gradients,
)
from repro.core.sketch import exact_from_factors, make_projections, sketch_from_factors
from repro.models.api import build_model


@pytest.mark.slow
def test_lm_exact_gradient_matches_autodiff():
    """The analytic H^T(P-Y) last-layer gradient must equal jax.grad of the
    training loss w.r.t. the head weight."""
    cfg = get_config("minitron-8b-smoke")   # untied head
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init_params(key)
    batch = m.make_batch(key, 3, 12)
    g_analytic = lm_unit_exact(m, params, batch)

    def loss_of_head(w):
        p2 = dict(params, lm_head={"w": w})
        return m.per_example_loss(p2, batch, remat=False).mean()

    g_auto = jax.grad(loss_of_head)(params["lm_head"]["w"])
    assert jnp.allclose(g_analytic, g_auto.reshape(-1), atol=1e-4), \
        float(jnp.abs(g_analytic - g_auto.reshape(-1)).max())


@pytest.mark.slow
def test_rnnt_exact_gradient_matches_autodiff():
    cfg = get_config("rnnt-crdnn-smoke")
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init_params(key)
    batch = m.make_batch(key, 2, 32)
    g = rnnt_unit_exact(m, params, batch)

    def loss_of_joint(w):
        p2 = dict(params, joint=dict(params["joint"], w_out=w))
        return m.loss_fn(p2, batch)[0]

    g_auto = jax.grad(loss_of_joint)(params["joint"]["w_out"])
    assert jnp.allclose(g, g_auto.reshape(-1), atol=1e-4), \
        float(jnp.abs(g - g_auto.reshape(-1)).max())


def test_sketch_unbiased_inner_products():
    """Tensor-JL property: sketched inner products concentrate around the
    exact gradient inner products (averaged over projections)."""
    rng = np.random.default_rng(0)
    dh, dv, n = 24, 500, 8
    Hs = [jnp.asarray(rng.normal(size=(20, dh)), jnp.float32) for _ in range(n)]
    Es = [jnp.asarray(rng.normal(size=(20, dv)) * 0.1, jnp.float32)
          for _ in range(n)]
    exact = [exact_from_factors(h, e) for h, e in zip(Hs, Es)]
    trials = []
    for t in range(6):
        proj = make_projections(jax.random.PRNGKey(t), dh, dv, 96, 96)
        sk = [sketch_from_factors(h, e, proj) for h, e in zip(Hs, Es)]
        trials.append(float(sk[0] @ sk[1]))
    want = float(exact[0] @ exact[1])
    norm = float(jnp.linalg.norm(exact[0]) * jnp.linalg.norm(exact[1]))
    err = abs(np.mean(trials) - want) / norm
    assert err < 0.15, (np.mean(trials), want, err)


def test_streamed_er2_invariant_to_chunk_size():
    rng = np.random.default_rng(1)
    h = jnp.asarray(rng.normal(size=(30, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 213)), jnp.float32)
    rv = jnp.asarray(rng.normal(size=(213, 8)), jnp.float32)
    t = jnp.asarray(rng.integers(0, 213, 30), jnp.int32)
    s = jnp.ones((30,))
    outs = [streamed_er2(h, w, t, s, rv, chunk=c) for c in (16, 64, 213, 512)]
    for o in outs[1:]:
        assert jnp.allclose(outs[0], o, atol=1e-4)


def test_units_gradients_shape_and_determinism():
    cfg = get_config("starcoder2-3b-smoke")
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init_params(key)
    units = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[m.make_batch(jax.random.PRNGKey(i), 2, 16) for i in range(5)])
    proj = make_proj_for(m, key, 16, 16)
    g1 = units_gradients(m, params, units, proj)
    g2 = units_gradients(m, params, units, proj)
    assert g1.shape == (5, 256)
    assert jnp.allclose(g1, g2)
