"""The static-contract checker checks itself (repro.analysis):

* level 1 — every lint rule fires on a minimal violation fixture and
  stays silent on the matching clean fixture; suppressions are honored
  (and bare/unknown suppressions are themselves findings); the JSON
  output schema is stable; and the full rule set runs clean on the
  repo's own ``src/`` tree (the ``make check-static`` gate);
* level 2 — the ``analysis.contracts`` checkers prove and refute:
  ``track_compiles``/``assert_retrace_free`` count real XLA compiles,
  ``assert_donated`` reads the aliasing/donor marks, the host-transfer
  checkers catch callbacks (statically) and implicit fetches (at
  runtime), and the replica-group parser handles both compiled HLO
  encodings — plus the scan engine's own epoch executable satisfies
  donation + residency.
"""
import json
import textwrap
import types
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import contracts
from repro.analysis.lint import (JSON_SCHEMA_VERSION, all_rules, main,
                                 run_lint, to_json)

pytestmark = pytest.mark.analysis

REPO_ROOT = Path(__file__).resolve().parent.parent


def _lint(tmp_path, code, rel="src/repro/train/mod.py", rules=None):
    """Lint one dedented snippet placed at ``rel`` under a tmp root."""
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(code))
    reg = all_rules()
    sel = {n: reg[n] for n in rules} if rules is not None else reg
    return run_lint(tmp_path, rules=sel, files=[p])


def _rules_of(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# per-rule violation / clean fixture pairs
# ---------------------------------------------------------------------------

def test_host_sync_jit_fires_and_clean(tmp_path):
    bad = """
        import jax

        @jax.jit
        def step(x):
            return x * float(x.mean())
    """
    clean = """
        import jax

        @jax.jit
        def step(x):
            scale = float(len(x.shape))     # shape math is static
            return x * scale
    """
    assert _rules_of(_lint(tmp_path, bad, rules=["host-sync-jit"])) == \
        {"host-sync-jit"}
    assert _lint(tmp_path, clean, rules=["host-sync-jit"]) == []


def test_host_sync_jit_sees_scan_bodies_transitively(tmp_path):
    bad = """
        import jax

        def body(carry, x):
            return carry + x, bool(x.sum())

        def epoch(xs):
            return jax.lax.scan(body, 0.0, xs)
    """
    found = _lint(tmp_path, bad, rules=["host-sync-jit"])
    assert _rules_of(found) == {"host-sync-jit"}


def test_host_sync_loop_catches_per_slot_eviction_fetch(tmp_path):
    # regression for the SlotEngine eviction sweep this PR fixed: a
    # device fetch per finished slot inside the host loop
    bad = """
        import numpy as np

        def sweep(state, finished, n_out):
            outs = []
            for slot in finished:
                toks = np.asarray(state["out"][slot])[: int(n_out[slot])]
                outs.append(toks)
            return outs
    """
    clean = """
        import numpy as np

        def sweep(state, finished, n_out):
            out_pool = np.asarray(state["out"])
            counts = np.asarray(n_out)
            outs = []
            for slot in finished:
                outs.append(out_pool[slot][: int(counts[slot])])
            return outs
    """
    rel = "src/repro/serve/mod.py"
    assert "host-sync-loop" in _rules_of(
        _lint(tmp_path, bad, rel=rel, rules=["host-sync-loop"]))
    assert _lint(tmp_path, clean, rel=rel, rules=["host-sync-loop"]) == []


def test_key_reuse_fires_and_fold_in_is_sanctioned(tmp_path):
    bad = """
        import jax

        def sample(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.normal(key, (3,))
            return a + b
    """
    loop_bad = """
        import jax

        def sample(key, n):
            out = []
            for i in range(n):
                out.append(jax.random.normal(key, (3,)))
            return out
    """
    clean = """
        import jax

        def sample(key, n):
            out = []
            for i in range(n):
                out.append(jax.random.normal(jax.random.fold_in(key, i),
                                             (3,)))
            return out
    """
    assert _rules_of(_lint(tmp_path, bad, rules=["key-reuse"])) == \
        {"key-reuse"}
    assert _rules_of(_lint(tmp_path, loop_bad, rules=["key-reuse"])) == \
        {"key-reuse"}
    assert _lint(tmp_path, clean, rules=["key-reuse"]) == []


def test_dtype_widen_fires_and_clean(tmp_path):
    bad = """
        import jax.numpy as jnp

        def widen(x):
            return x.astype("float64") + jnp.zeros(3, dtype=jnp.float64)
    """
    clean = """
        import jax.numpy as jnp
        import numpy as np

        def narrow(x):
            host = np.float64(0.5)          # host-side f64 is fine
            return x.astype(jnp.bfloat16) * jnp.float32(host)
    """
    found = _lint(tmp_path, bad, rules=["dtype-widen"])
    assert _rules_of(found) == {"dtype-widen"} and len(found) == 2
    assert _lint(tmp_path, clean, rules=["dtype-widen"]) == []


def test_collective_cast_order_fires_and_clean(tmp_path):
    bad = """
        import jax, jax.numpy as jnp

        def reduce(g):
            return jax.lax.pmean(g, "pod").astype(jnp.bfloat16)
    """
    clean = """
        import jax
        import jax.numpy as jnp

        def reduce(g):
            r = jax.lax.pmean(g.astype(jnp.bfloat16), "pod")
            return r.astype(jnp.float32)    # widening back is fine
    """
    assert _rules_of(_lint(tmp_path, bad,
                           rules=["collective-cast-order"])) == \
        {"collective-cast-order"}
    assert _lint(tmp_path, clean, rules=["collective-cast-order"]) == []


def test_pallas_blockspec_fires_and_clean(tmp_path):
    bad = """
        import jax.experimental.pallas as pl

        def op(x, block):
            scale = x * 2
            return pl.pallas_call(
                kern, grid=(4,),
                in_specs=[pl.BlockSpec((8,), lambda i: (i * scale,))],
                interpret=False)(x)
    """
    clean = """
        import jax.experimental.pallas as pl

        def op(x, block):
            n = x.shape[0] // block         # shape math: static
            return pl.pallas_call(
                kern, grid=(4,),
                in_specs=[pl.BlockSpec((8,), lambda i: (i * n,))],
                interpret=False)(x)
    """
    rel = "src/repro/kernels/toy/kernel.py"
    assert _rules_of(_lint(tmp_path, bad, rel=rel,
                           rules=["pallas-blockspec"])) == \
        {"pallas-blockspec"}
    assert _lint(tmp_path, clean, rel=rel, rules=["pallas-blockspec"]) == []


def test_pallas_interpret_fires_and_clean(tmp_path):
    bad_kernel = """
        import jax.experimental.pallas as pl

        def run(x):
            return pl.pallas_call(kern, grid=(4,))(x)
    """
    bad_ops = """
        def toy_op(x):
            return _pallas_toy(x)
    """
    clean_ops = """
        def toy_op(x, interpret=False):
            return _pallas_toy(x, interpret=interpret)
    """
    assert _rules_of(_lint(tmp_path, bad_kernel,
                           rel="src/repro/kernels/toy/kernel.py",
                           rules=["pallas-interpret"])) == \
        {"pallas-interpret"}
    found = _lint(tmp_path, bad_ops, rel="src/repro/kernels/toy/ops.py",
                  rules=["pallas-interpret"])
    assert len(found) == 2                  # missing param + dropped kwarg
    assert _lint(tmp_path, clean_ops,
                 rel="src/repro/kernels/toy/ops.py",
                 rules=["pallas-interpret"]) == []


def test_bench_docs_drift_fires_and_clean(tmp_path):
    (tmp_path / "benchmarks").mkdir(parents=True)
    (tmp_path / "benchmarks" / "bench_toy.py").write_text(
        'OUT = "BENCH_toy.json"\nKEYS = ["toy_steps_per_s"]\n')
    readme = tmp_path / "README.md"
    reg = all_rules()
    rule = reg["bench-docs-drift"]

    readme.write_text("`BENCH_toy.json` reports `bogus_steps_per_s`.\n")
    found = rule.check(tmp_path)
    assert found and all(f.rule == "bench-docs-drift" for f in found)

    readme.write_text("`BENCH_toy.json` reports `toy_steps_per_s`.\n")
    assert rule.check(tmp_path) == []


# ---------------------------------------------------------------------------
# suppression + hygiene + schema + self-check
# ---------------------------------------------------------------------------

def test_suppression_is_honored_and_hygiene_enforced(tmp_path):
    suppressed = """
        import jax

        @jax.jit
        def step(x):
            return x * float(x.mean())  # repro: noqa[host-sync-jit] -- fixture: deliberate
    """
    bare = """
        import jax

        @jax.jit
        def step(x):
            return x * float(x.mean())  # repro: noqa[host-sync-jit]
    """
    unknown = """
        x = 1  # repro: noqa[no-such-rule] -- why
    """
    assert _lint(tmp_path, suppressed,
                 rules=["host-sync-jit", "noqa-hygiene"]) == []
    found = _lint(tmp_path, bare, rules=["host-sync-jit", "noqa-hygiene"])
    # the finding is hidden but the bare suppression is itself flagged
    assert _rules_of(found) == {"noqa-hygiene"}
    assert "justification" in found[0].message
    found = _lint(tmp_path, unknown, rules=["noqa-hygiene"])
    assert any("unknown rule" in f.message for f in found)


def test_docstring_mention_of_noqa_is_not_a_suppression(tmp_path):
    code = '''
        def helper():
            """Suppression syntax is `# repro: noqa[rule]`."""
            return 1
    '''
    assert _lint(tmp_path, code, rules=["noqa-hygiene"]) == []


def test_json_schema_is_stable(tmp_path):
    bad = """
        import jax

        @jax.jit
        def step(x):
            return float(x.mean())
    """
    reg = all_rules()
    findings = _lint(tmp_path, bad, rules=["host-sync-jit"])
    blob = json.loads(json.dumps(to_json(
        findings, {"host-sync-jit": reg["host-sync-jit"]})))
    assert set(blob) == {"version", "rules", "findings", "counts"}
    assert blob["version"] == JSON_SCHEMA_VERSION
    assert blob["rules"] == ["host-sync-jit"]
    assert blob["counts"] == {"host-sync-jit": 1}
    (f,) = blob["findings"]
    assert set(f) == {"rule", "path", "line", "message"}
    assert f["path"].endswith("mod.py") and f["line"] > 1


def test_rule_set_runs_clean_on_own_src():
    """The ``make check-static`` gate: zero findings on the repo, with
    the full registry (>= 8 rules) active."""
    rules = all_rules()
    assert len(rules) >= 8
    findings = run_lint(REPO_ROOT)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_cli_list_and_exit_codes(tmp_path, capsys):
    assert main(["--list"]) == 0
    names = capsys.readouterr().out
    assert "host-sync-jit:" in names and "noqa-hygiene:" in names
    with pytest.raises(SystemExit):
        main(["--rule", "no-such-rule"])


# ---------------------------------------------------------------------------
# level 2: contracts
# ---------------------------------------------------------------------------

def test_track_compiles_counts_and_retrace_free_raises():
    @jax.jit
    def f(x):
        return x * 3 + 1

    x = jnp.ones(7)
    with contracts.track_compiles() as log:
        f(x).block_until_ready()
    assert log.count >= 1 and any("f" in n for n in log.names)
    with contracts.assert_retrace_free("warm f"):
        f(x).block_until_ready()

    @jax.jit
    def g(x):
        return x - 2

    with pytest.raises(AssertionError, match="retraced"):
        with contracts.assert_retrace_free("cold g"):
            g(x).block_until_ready()


def test_assert_donated_positive_negative_and_skip():
    def f(carry, x):
        return carry + x, carry * x

    donating = jax.jit(f, donate_argnums=(0,)).lower(jnp.ones(3),
                                                     jnp.ones(3))
    contracts.assert_donated(donating, jnp.ones(3))
    with pytest.raises(AssertionError, match="not donated"):
        contracts.assert_donated(donating, (jnp.ones(3), jnp.ones(3)))
    # skip= checks donation *after* a non-donated prefix
    tail = jax.jit(f, donate_argnums=(1,)).lower(jnp.ones(3), jnp.ones(3))
    contracts.assert_donated(tail, jnp.ones(3), skip=jnp.ones(3))
    with pytest.raises(AssertionError, match="not donated"):
        contracts.assert_donated(tail, jnp.ones(3))


def test_assert_no_host_transfers_flags_callbacks():
    def cb(x):
        jax.debug.callback(lambda v: None, x)
        return x * 2

    low = jax.jit(cb).lower(jnp.ones(3))
    with pytest.raises(AssertionError, match="host transfer"):
        contracts.assert_no_host_transfers(low)

    def pure(x):
        return x * 2

    plow = jax.jit(pure).lower(jnp.ones(3))
    contracts.assert_no_host_transfers(plow, plow.compile().as_text())


def test_no_implicit_transfers_guard():
    x = jnp.arange(4.0)
    with contracts.no_implicit_transfers():
        (x * 2).block_until_ready()         # dispatch alone is fine
    if jax.default_backend() == "cpu":
        # CPU arrays live in host memory; no D2H copy ever routes
        # through the guard (see the helper's docstring)
        pytest.skip("transfer guard is vacuous on the CPU backend")
    with pytest.raises(Exception, match="[Dd]isallow"):
        with contracts.no_implicit_transfers():
            np.asarray(x * 2)


def test_replica_group_parser_handles_both_encodings():
    lit = "all-reduce(...), replica_groups={{0,2},{1,3}}, to_apply=%x"
    assert contracts.parse_replica_groups(lit) == [[0, 2], [1, 3]]
    iota = "all-reduce(...), replica_groups=[2,2]<=[2,2]T(1,0), foo"
    assert contracts.parse_replica_groups(iota) == [[0, 2], [1, 3]]
    flat = "all-reduce(...), replica_groups=[1,4]<=[4], foo"
    assert contracts.parse_replica_groups(flat) == [[0, 1, 2, 3]]
    assert contracts.parse_replica_groups("all-reduce, no groups") is None


def test_expected_groups_from_mesh_axes():
    dev = np.array([[types.SimpleNamespace(id=0),
                     types.SimpleNamespace(id=1)],
                    [types.SimpleNamespace(id=2),
                     types.SimpleNamespace(id=3)]])
    mesh = types.SimpleNamespace(devices=dev, axis_names=("data", "pod"))
    assert contracts.expected_groups(mesh, "pod") == [[0, 1], [2, 3]]
    assert contracts.expected_groups(mesh, "data") == [[0, 2], [1, 3]]
    text = ("%ar = f32[2]{0} all-reduce(%z), channel_id=1, "
            "replica_groups={{0,1},{2,3}}, to_apply=%sum")
    contracts.assert_replica_groups(text, mesh, "pod")
    with pytest.raises(AssertionError, match="no all-reduce grouped"):
        contracts.assert_replica_groups(text, mesh, "data")


def test_scan_engine_epoch_executable_satisfies_contracts():
    """The single-device scan engine's epoch executable: (params, opt)
    carry donated, body device-resident — the fast-tier leg of the
    contract matrix (the pod/sharded legs live in the slow 4-device
    tests, the serving leg in tests/test_serve_engine.py)."""
    from repro.configs import get_config
    from repro.configs.base import PGMConfig, TrainConfig
    from repro.data.pipeline import lm_units
    from repro.data.synthetic import make_lm_corpus
    from repro.models.api import build_model
    from repro.train.engine import EpochEngine
    from repro.train.optim import make_update_for

    cfg = get_config("starcoder2-3b-smoke")
    m = build_model(cfg)
    units = lm_units(make_lm_corpus(0, 8, 10, cfg.vocab_size), 4)
    tc = TrainConfig(lr=0.5, optimizer="sgd", epochs=1, pgm=PGMConfig())
    eng = EpochEngine(m, tc, units, batch_units=2)
    opt_init, _ = make_update_for(tc)
    p = m.init_params(jax.random.PRNGKey(0))
    o = opt_init(p)
    idx, w = eng.full_plan(0)
    low = eng._run.lower(p, o, idx, w, jnp.float32(0.5))
    contracts.assert_donated(low, (p, o))
    contracts.assert_no_host_transfers(low, low.compile().as_text())
