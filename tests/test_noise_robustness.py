"""The paper's noise-robustness setting, runnable end to end: the
``noise_fraction``/``snr_db`` corruption knobs of ``data/synthetic.py``
reach the launcher (``repro.launch.train --noise --snr-db``) and the ASR
example, and PGM under corruption still selects and trains (with
``val_matching`` automatically on, matching against the clean
validation gradient)."""
import numpy as np
import pytest

from repro.configs.base import PGMConfig, TrainConfig
from repro.data.synthetic import make_asr_corpus
from repro.launch.train import launch_train


def test_snr_db_knob_controls_feature_noise_power():
    """Lower SNR must inject measurably more feature noise into the
    corrupted utterances while leaving clean ones bit-identical (two
    corpora differing only in ``snr_db`` share every rng draw, so the
    noise *vectors* match and only their scale differs)."""
    loud = make_asr_corpus(0, 32, n_feats=8, vocab_size=16,
                           noise_fraction=0.5, snr_db=0.0)
    quiet = make_asr_corpus(0, 32, n_feats=8, vocab_size=16,
                            noise_fraction=0.5, snr_db=30.0)
    assert loud.noisy.sum() == quiet.noisy.sum() == 16
    assert np.array_equal(loud.noisy, quiet.noisy)
    assert np.array_equal(loud.tokens, quiet.tokens)
    clean_rows = ~loud.noisy
    assert np.array_equal(loud.feats[clean_rows], quiet.feats[clean_rows])
    # 0 dB carries ~31.6x the noise power of 30 dB, so the two corpora
    # must diverge on every corrupted utterance
    dev = np.abs(loud.feats[loud.noisy] - quiet.feats[loud.noisy])
    assert (dev.reshape(16, -1).max(axis=1) > 0).all()
    rms_quiet = np.square(quiet.feats[quiet.noisy]).mean()
    rms_loud = np.square(loud.feats[loud.noisy]).mean()
    assert rms_loud > 1.5 * rms_quiet


def test_pgm_trains_under_lm_label_corruption():
    """Fast smoke of the robustness setting on the LM family: label
    corruption via --noise, PGM still selects a subset and the loop
    trains to finite losses."""
    tc = TrainConfig(
        lr=0.5, optimizer="sgd", epochs=3,
        pgm=PGMConfig(subset_fraction=0.5, n_partitions=2, select_every=2,
                      warm_start_epochs=1, sketch_dim_h=16, sketch_dim_v=16,
                      val_matching=True))
    h = launch_train("starcoder2-3b-smoke", tc, method="pgm", n=24, seq=12,
                     noise=0.25, log_fn=lambda s: None)
    assert len(h.selections) == 1
    assert int(sum(1 for i in h.selections[0]["indices"] if i >= 0)) >= 1
    assert np.isfinite(h.train_loss).all() and np.isfinite(h.val_loss).all()


@pytest.mark.slow
def test_pgm_selects_and_trains_under_asr_feature_noise():
    """The paper's actual robust-ASR setting: RNN-T on a corpus with 30%
    of utterances corrupted at 5 dB SNR, PGM in Val mode.  Selection
    must happen and training must improve over the warm-start loss."""
    tc = TrainConfig(
        lr=0.05, optimizer="adamw", epochs=4,
        pgm=PGMConfig(subset_fraction=0.5, n_partitions=2, select_every=2,
                      warm_start_epochs=1, sketch_dim_h=16, sketch_dim_v=16,
                      val_matching=True))
    h = launch_train("rnnt-crdnn-smoke", tc, method="pgm", n=16,
                     noise=0.3, snr_db=5.0, epoch_chunk=2,
                     log_fn=lambda s: None)
    assert len(h.selections) >= 1
    assert all(np.isfinite(v) for v in h.train_loss + h.val_loss)
    assert h.train_loss[-1] < h.train_loss[0]
    # the subset epochs charged less than full-data epochs would
    assert h.cost_units < tc.epochs + 1
