"""Per-architecture engine + selection test matrix (``make test-archs``).

Three layers, every arch family the repo carries (DESIGN.md §8):

* smoke — every assigned arch instantiates a REDUCED config, runs one
  forward and one train step, asserts shapes/finiteness; decoder archs
  additionally check prefill->decode consistency (slow tier: one compile
  per arch adds up to minutes);
* engine matrix — per-arch host-vs-scan history parity at rtol 1e-3 for
  the MoE pair (Mixtral/OLMoE) and the recurrent pair
  (RWKV6/RecurrentGemma), a 4-device subprocess sharded smoke for the
  MoE (expert-axis specs asserted on the sharded state) and one
  recurrent arch, and a resident PGM selection round per family —
  router-aware for MoE (``PGMConfig.moe_router_term``);
* dispatch regression — ``models/moe.py:_topk_dispatch`` gate-weight
  conservation at capacity 1 and exact slot occupancy under bf16 past
  256 tokens (the float-cumsum hazard).

Only the cheapest member of each family (Mixtral, RWKV6) runs in the
fast tier; the rest ride the slow tier / ``make test-archs``.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.configs.base import PGMConfig, TrainConfig
from repro.data.pipeline import lm_units
from repro.data.synthetic import make_lm_corpus
from repro.models.api import build_model
from repro.train.loop import train_with_selection
from repro.train.optim import make_optimizer, clip_by_global_norm

# the whole module is the per-arch matrix: `make test-archs` selects it
pytestmark = pytest.mark.archs

ARCHS = list_archs()
ROOT = os.path.join(os.path.dirname(__file__), "..")

# engine matrix rows: both MoE archs + both recurrent substrates; the
# cheapest member of each family stays in the fast tier, the sibling
# (same code paths, bigger smoke config) rides the slow tier
MATRIX = [
    "mixtral-8x7b",
    pytest.param("olmoe-1b-7b", marks=pytest.mark.slow),
    "rwkv6-3b",
    pytest.param("recurrentgemma-9b", marks=pytest.mark.slow),
]
RECURRENT_FAMILIES = ("ssm", "hybrid")


# ---------------------------------------------------------------------------
# Smoke layer (slow tier): every arch, one forward + one train step
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch + "-smoke")
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init_params(key)
    batch = m.make_batch(key, 2, 32)
    loss, metrics = m.loss_fn(params, batch)
    assert jnp.isfinite(loss), (arch, metrics)
    per_ex = m.per_example_loss(params, batch)
    assert per_ex.shape == (2,)
    assert jnp.isfinite(per_ex).all()

    # one SGD step decreases nothing catastrophic and keeps params finite
    opt_init, opt_update = make_optimizer("sgd")
    opt_state = opt_init(params)
    grads = jax.grad(lambda p: m.loss_fn(p, batch)[0])(params)
    grads, gnorm = clip_by_global_norm(grads, 5.0)
    assert jnp.isfinite(gnorm) and gnorm > 0
    params2, _ = opt_update(params, grads, opt_state, lr=0.1)
    loss2, _ = m.loss_fn(params2, batch)
    assert jnp.isfinite(loss2)


DECODER_ARCHS = [a for a in ARCHS
                 if get_config(a).family not in ("rnnt", "encdec", "vlm")]


@pytest.mark.slow
@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_prefill_decode_consistency(arch):
    from repro.models import transformer as T
    cfg = get_config(arch + "-smoke")
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key)
    B, S = 2, 24
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    xfull = T.embed_tokens(params, cfg, tokens)
    hfull, _, _ = T.forward_hidden(params, cfg, xfull, remat=False)
    xpre = T.embed_tokens(params, cfg, tokens[:, :S])
    _, _, cache = T.forward_hidden(params, cfg, xpre, remat=False,
                                   collect_cache=True, cache_len=S + 4)
    xt = T.embed_tokens(params, cfg, tokens[:, S:S + 1])
    hdec, _ = T.decode_step(params, cfg, xt, cache)
    err = float(jnp.max(jnp.abs(hdec[:, 0] - hfull[:, S])))
    assert err < 5e-4, (arch, err)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["seamless-m4t-medium", "paligemma-3b"])
def test_frontend_archs_serve(arch):
    cfg = get_config(arch + "-smoke")
    m = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = m.init_params(key)
    batch = m.make_batch(key, 2, 24)
    logits, cache = m.prefill(params, batch, cache_len=32)
    assert logits.shape == (2, cfg.vocab_size)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = m.decode(params, cache, tok)
    assert logits2.shape == (2, cfg.vocab_size)
    assert jnp.isfinite(logits2).all()


@pytest.mark.slow
def test_rnnt_loss_decreases_with_training_signal():
    """The RNN-T on learnable synthetic speech: a few SGD steps reduce loss."""
    from repro.data.synthetic import make_asr_corpus
    from repro.data.pipeline import asr_units
    cfg = get_config("rnnt-crdnn-smoke")
    m = build_model(cfg)
    corpus = make_asr_corpus(0, 32, n_feats=cfg.rnnt.n_feats,
                             vocab_size=cfg.rnnt.vocab_size)
    units = asr_units(corpus, 4)
    batch = {k: jnp.asarray(v[0]) for k, v in units.items()}
    params = m.init_params(jax.random.PRNGKey(0))
    opt_init, opt_update = make_optimizer("adamw")
    opt = opt_init(params)
    first = last = None
    for i in range(8):
        (l, _), g = jax.value_and_grad(
            lambda p: m.loss_fn(p, batch), has_aux=True)(params)
        g, _ = clip_by_global_norm(g, 5.0)
        params, opt = opt_update(params, g, opt, lr=3e-3)
        first = first if first is not None else float(l)
        last = float(l)
    assert last < first, (first, last)


# ---------------------------------------------------------------------------
# Engine matrix: per-arch host-vs-scan parity (rtol 1e-3)
# ---------------------------------------------------------------------------

def _matrix_setup(arch, n=16, seq=10, epochs=3):
    cfg = get_config(arch + "-smoke")
    m = build_model(cfg)
    units = lm_units(make_lm_corpus(0, n, seq, cfg.vocab_size,
                                    hard_fraction=0.4), unit_size=2)
    val = lm_units(make_lm_corpus(7, 8, seq, cfg.vocab_size), unit_size=2)
    tc = TrainConfig(
        lr=0.2, optimizer="sgd", epochs=epochs,
        pgm=PGMConfig(subset_fraction=0.5, n_partitions=2, select_every=2,
                      warm_start_epochs=1, sketch_dim_h=16, sketch_dim_v=16,
                      moe_router_term=(cfg.family == "moe")))
    return m, units, val, tc


@pytest.mark.parametrize("arch", MATRIX)
def test_engine_parity_matrix(arch):
    """Host loop and scanned engine walk the same trajectory — losses,
    selected indices and OMP weights — on every matrix arch, including
    the router-aware MoE selection term."""
    m, units, val, tc = _matrix_setup(arch)
    h_host = train_with_selection(m, units, tc, method="pgm", val_units=val,
                                  engine="host")
    h_scan = train_with_selection(m, units, tc, method="pgm", val_units=val,
                                  engine="scan")
    assert np.allclose(h_host.train_loss, h_scan.train_loss,
                       rtol=1e-3, atol=1e-3), \
        (arch, h_host.train_loss, h_scan.train_loss)
    assert np.allclose(h_host.val_loss, h_scan.val_loss,
                       rtol=1e-3, atol=1e-3), (arch,)
    assert len(h_host.selections) == len(h_scan.selections) >= 1
    for sh, ss in zip(h_host.selections, h_scan.selections):
        assert sh["indices"] == ss["indices"], (arch, sh, ss)
        assert np.allclose(sh["weights"], ss["weights"],
                           rtol=1e-3, atol=1e-3)
    assert h_host.cost_units == pytest.approx(h_scan.cost_units)


# ---------------------------------------------------------------------------
# Engine matrix: resident PGM selection round per family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", MATRIX)
def test_resident_selection_round_matrix(arch):
    """One resident selection round per family: the jitted batch-scanned
    stage A matches the host per-unit path at 1e-3, and stage B returns
    a valid weighted subset.  MoE archs run with the router-aware term
    on (DESIGN.md §8)."""
    from repro.core.lastlayer import make_proj_for, units_gradients
    from repro.core.pgm import ResidentSelector
    cfg = get_config(arch + "-smoke")
    m = build_model(cfg)
    units = lm_units(make_lm_corpus(0, 16, 10, cfg.vocab_size), unit_size=2)
    dev = {k: jnp.asarray(v) for k, v in units.items()}
    params = m.init_params(jax.random.PRNGKey(0))
    proj = make_proj_for(m, jax.random.PRNGKey(1), 16, 16)
    is_moe = cfg.family == "moe"
    pc = PGMConfig(subset_fraction=0.5, n_partitions=2,
                   sketch_dim_h=16, sketch_dim_v=16, moe_router_term=is_moe)
    sel_r = ResidentSelector(m, pc, proj)
    g_res = sel_r.stage_a(params, dev)
    g_host = units_gradients(m, params, dev, proj, router_term=is_moe)
    assert g_res.shape == g_host.shape == (8, g_host.shape[1])
    assert np.allclose(np.asarray(g_res), np.asarray(g_host),
                       rtol=1e-3, atol=1e-3)
    sel = sel_r(params, dev)
    assert int(sel.n_selected) == 4
    idx = np.asarray(sel.indices)
    assert ((idx >= -1) & (idx < 8)).all()
    live = idx >= 0
    assert np.isfinite(np.asarray(sel.weights)[live]).all()
    assert np.isfinite(np.asarray(sel.errors)).all()


@pytest.mark.parametrize("arch", ["mixtral-8x7b",
                                  pytest.param("olmoe-1b-7b",
                                               marks=pytest.mark.slow)])
def test_moe_router_term_definition(arch):
    """The router-aware MoE selection gradient (DESIGN.md §8): opt-in,
    appends one sketched block per router leaf after the head sketch —
    the default stays head-only (paper-faithful) — and the router block
    is non-degenerate (top-k dispatch + aux loss do reach the router)."""
    from repro.core.lastlayer import (make_proj_for, moe_router_grads,
                                      units_gradients)
    cfg = get_config(arch + "-smoke")
    m = build_model(cfg)
    units = lm_units(make_lm_corpus(0, 8, 10, cfg.vocab_size), unit_size=2)
    dev = {k: jnp.asarray(v) for k, v in units.items()}
    params = m.init_params(jax.random.PRNGKey(0))
    proj = make_proj_for(m, jax.random.PRNGKey(1), 16, 16)
    g_head = units_gradients(m, params, dev, proj, router_term=False)
    g_full = units_gradients(m, params, dev, proj, router_term=True)
    assert g_full.shape[1] > g_head.shape[1], (g_full.shape, g_head.shape)
    # the head block is unchanged by appending the router block
    assert np.allclose(np.asarray(g_full[:, :g_head.shape[1]]),
                       np.asarray(g_head), rtol=1e-4, atol=1e-5)
    router_block = np.asarray(g_full[:, g_head.shape[1]:])
    assert np.isfinite(router_block).all()
    assert np.abs(router_block).max() > 0, "router receives no gradient"
    # definition check: per-unit autodiff grads over every router leaf
    unit0 = {k: v[0] for k, v in dev.items()}
    grads = moe_router_grads(m, params, unit0)
    assert len(grads) >= 1
    for g in grads:
        assert g.dtype == jnp.float32 and bool(jnp.isfinite(g).all())


def test_moe_router_term_rejects_routerless_params():
    """A family='moe' bundle whose params lost their router leaves must
    fail loudly, not silently return a head-only representation."""
    from repro.core.lastlayer import moe_router_grads
    cfg = get_config("mixtral-8x7b-smoke")
    m = build_model(cfg)
    units = lm_units(make_lm_corpus(0, 2, 8, cfg.vocab_size), unit_size=1)
    unit0 = {k: jnp.asarray(v[0]) for k, v in units.items()}
    params = m.init_params(jax.random.PRNGKey(0))

    def drop_router(t):
        if isinstance(t, dict):
            return {k: drop_router(v) for k, v in t.items()
                    if k != "router"}
        if isinstance(t, (list, tuple)):
            return type(t)(drop_router(v) for v in t)
        return t

    with pytest.raises(ValueError, match="router"):
        moe_router_grads(m, drop_router(params), unit0)


# ---------------------------------------------------------------------------
# Engine matrix: 4-device subprocess sharded smokes (slow tier)
# ---------------------------------------------------------------------------

def _run(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert p.returncode == 0, p.stderr[-3000:]
    return p.stdout


@pytest.mark.slow
def test_moe_expert_sharded_engine_smoke():
    """Mixtral-smoke on a (2,2) ``data x expert`` mesh with
    ``spec_mode='expert'``: expert banks shard their leading E dim over
    the expert axis while the router stays replicated (asserted on the
    sharded state), and two training epochs stay within 1e-3 of the
    single-device engine."""
    out = _run(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        import jax.tree_util as jtu
        from repro.configs import get_config
        from repro.configs.base import PGMConfig, TrainConfig
        from repro.data.pipeline import lm_units
        from repro.data.synthetic import make_lm_corpus
        from repro.models.api import build_model
        from repro.train.engine import EpochEngine
        from repro.train.optim import make_update_for
        assert jax.device_count() == 4
        cfg = get_config("mixtral-8x7b-smoke")
        m = build_model(cfg)
        units = lm_units(make_lm_corpus(0, 16, 10, cfg.vocab_size,
                                        hard_fraction=0.4), 2)
        tc = TrainConfig(lr=0.2, optimizer="sgd", epochs=2, pgm=PGMConfig())
        mesh = jax.make_mesh((2, 2), ("data", "expert"))
        eng = EpochEngine(m, tc, units, batch_units=2, mesh=mesh,
                          spec_mode="expert")
        opt_init, _ = make_update_for(tc)
        p = m.init_params(jax.random.PRNGKey(0)); o = opt_init(p)
        p, o = eng.shard_state(p, o)
        # the expert banks shard E over 'expert'; the router replicates
        flat = jtu.tree_flatten_with_path(p)[0]
        n_expert = n_router = 0
        for path, leaf in flat:
            ks = jtu.keystr(path)
            spec = leaf.sharding.spec
            if ks.endswith("['w_in']") or ks.endswith("['w_out']") \\
                    or ks.endswith("['w_gate']"):
                assert "expert" in jtu.tree_leaves(tuple(spec)), (ks, spec)
                n_expert += 1
            if ks.endswith("['router']"):
                assert all(s is None for s in spec), (ks, spec)
                n_router += 1
        assert n_expert >= 2 and n_router >= 1, (n_expert, n_router)
        losses = []
        for e in range(tc.epochs):
            p, o, l = eng.run_epoch(p, o, tc.lr, eng.full_plan(e))
            losses.append(np.asarray(l))
        # single-device reference
        eng1 = EpochEngine(m, tc, units, batch_units=2)
        p1 = m.init_params(jax.random.PRNGKey(0)); o1 = opt_init(p1)
        for e in range(tc.epochs):
            p1, o1, l1 = eng1.run_epoch(p1, o1, tc.lr, eng1.full_plan(e))
            assert np.allclose(losses[e], np.asarray(l1),
                               rtol=1e-3, atol=1e-3), (e, losses[e], l1)
        print("MOE-EXPERT-SHARDED-OK")
    """))
    assert "MOE-EXPERT-SHARDED-OK" in out


@pytest.mark.slow
def test_recurrent_sharded_engine_smoke():
    """RWKV6-smoke on a 4-way pure-data mesh: the scan-of-scan (epoch
    scan over the time-recurrent forward) compiles and trains on the
    sharded engine within 1e-3 of single device."""
    out = _run(textwrap.dedent("""
        import jax, numpy as np
        from repro.configs import get_config
        from repro.configs.base import PGMConfig, TrainConfig
        from repro.data.pipeline import lm_units
        from repro.data.synthetic import make_lm_corpus
        from repro.models.api import build_model
        from repro.train.engine import EpochEngine
        from repro.train.optim import make_update_for
        assert jax.device_count() == 4
        cfg = get_config("rwkv6-3b-smoke")
        m = build_model(cfg)
        units = lm_units(make_lm_corpus(0, 16, 10, cfg.vocab_size,
                                        hard_fraction=0.4), 2)
        tc = TrainConfig(lr=0.2, optimizer="sgd", epochs=2, pgm=PGMConfig())
        mesh = jax.make_mesh((4,), ("data",))
        eng = EpochEngine(m, tc, units, batch_units=2, mesh=mesh)
        opt_init, _ = make_update_for(tc)
        p = m.init_params(jax.random.PRNGKey(0)); o = opt_init(p)
        p, o = eng.shard_state(p, o)
        eng1 = EpochEngine(m, tc, units, batch_units=2)
        p1 = m.init_params(jax.random.PRNGKey(0)); o1 = opt_init(p1)
        for e in range(tc.epochs):
            p, o, l = eng.run_epoch(p, o, tc.lr, eng.full_plan(e))
            p1, o1, l1 = eng1.run_epoch(p1, o1, tc.lr, eng1.full_plan(e))
            assert np.allclose(np.asarray(l), np.asarray(l1),
                               rtol=1e-3, atol=1e-3), (e, l, l1)
        print("RECURRENT-SHARDED-OK")
    """))
    assert "RECURRENT-SHARDED-OK" in out


# ---------------------------------------------------------------------------
# _topk_dispatch capacity regression (satellite a)
# ---------------------------------------------------------------------------

def test_topk_dispatch_conserves_gates_at_capacity_one():
    """Capacity 1, top-1: each expert keeps exactly its first-routed
    token per group; every kept token's combine weights sum to 1 (its
    whole top-k renormalized mass), dropped tokens to 0 — drop never
    redistributes mass to other tokens."""
    from repro.models.moe import _topk_dispatch
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(2, 24, 4)), jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    dispatch, combine = _topk_dispatch(gates, top_k=1, capacity=1)
    occ = np.asarray(dispatch.sum(axis=(1, 3)))          # (G,E) tokens kept
    assert occ.max() <= 1.0 + 1e-6, occ
    tok_mass = np.asarray(combine.sum(axis=(2, 3)))      # (G,S)
    kept = np.asarray(dispatch.sum(axis=(2, 3))) > 0
    assert np.allclose(tok_mass[kept], 1.0, atol=1e-6), tok_mass[kept]
    assert np.allclose(tok_mass[~kept], 0.0), tok_mass[~kept]
    # top-2 partial drop: a token keeping one of two experts renormalizes
    # over the kept one only — still exactly mass 1
    d2, c2 = _topk_dispatch(gates, top_k=2, capacity=1)
    mass2 = np.asarray(c2.sum(axis=(2, 3)))
    kept_any = np.asarray(d2.sum(axis=(2, 3))) > 0
    assert np.allclose(mass2[kept_any], 1.0, atol=1e-6)


def test_topk_dispatch_bf16_positions_exact_past_256_tokens():
    """bf16 gates with >256 tokens per group: position bookkeeping must
    stay exact (int32) — the old float cumsum collided slot positions,
    multi-filling capacity slots."""
    from repro.models.moe import _topk_dispatch
    rng = np.random.default_rng(1)
    S, E = 600, 2
    logits = rng.normal(size=(1, S, E)).astype(np.float32)
    gates = jax.nn.softmax(jnp.asarray(logits, jnp.bfloat16)
                           .astype(jnp.float32), -1).astype(jnp.bfloat16)
    cap = 512
    dispatch, combine = _topk_dispatch(gates, top_k=1, capacity=cap)
    d = np.asarray(dispatch, np.float32)
    # every (expert, slot) cell holds at most one token ...
    assert d.sum(axis=1).max() <= 1.0 + 1e-6
    # ... and exactly min(S routed to e, cap) tokens are kept per expert
    routed = np.asarray(
        jax.nn.one_hot(jnp.argmax(gates.astype(jnp.float32), -1), E)
    ).sum(axis=1)[0]
    want_kept = np.minimum(routed, cap).sum()
    assert d.sum() == pytest.approx(want_kept), (d.sum(), want_kept)
    mass = np.asarray(combine.astype(jnp.float32).sum(axis=(2, 3)))
    kept = d.sum(axis=(2, 3)) > 0
    assert np.allclose(mass[kept], 1.0, atol=2e-2)  # bf16 round-trip
