"""Per-architecture smoke tests (deliverable f): every assigned arch (and
the paper's RNN-T) instantiates a REDUCED config, runs one forward and one
train step on CPU, asserts output shapes and finiteness; decoder archs
additionally check prefill->decode consistency against the full forward."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models.api import build_model
from repro.train.optim import make_optimizer, clip_by_global_norm

# one compile per arch adds up to minutes — slow tier (the fast tier
# exercises the LM + RNN-T smoke configs via tests/test_train_engine.py)
pytestmark = pytest.mark.slow

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch + "-smoke")
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init_params(key)
    batch = m.make_batch(key, 2, 32)
    loss, metrics = m.loss_fn(params, batch)
    assert jnp.isfinite(loss), (arch, metrics)
    per_ex = m.per_example_loss(params, batch)
    assert per_ex.shape == (2,)
    assert jnp.isfinite(per_ex).all()

    # one SGD step decreases nothing catastrophic and keeps params finite
    opt_init, opt_update = make_optimizer("sgd")
    opt_state = opt_init(params)
    grads = jax.grad(lambda p: m.loss_fn(p, batch)[0])(params)
    grads, gnorm = clip_by_global_norm(grads, 5.0)
    assert jnp.isfinite(gnorm) and gnorm > 0
    params2, _ = opt_update(params, grads, opt_state, lr=0.1)
    loss2, _ = m.loss_fn(params2, batch)
    assert jnp.isfinite(loss2)


DECODER_ARCHS = [a for a in ARCHS
                 if get_config(a).family not in ("rnnt", "encdec", "vlm")]


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_prefill_decode_consistency(arch):
    from repro.models import transformer as T
    cfg = get_config(arch + "-smoke")
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key)
    B, S = 2, 24
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    xfull = T.embed_tokens(params, cfg, tokens)
    hfull, _, _ = T.forward_hidden(params, cfg, xfull, remat=False)
    xpre = T.embed_tokens(params, cfg, tokens[:, :S])
    _, _, cache = T.forward_hidden(params, cfg, xpre, remat=False,
                                   collect_cache=True, cache_len=S + 4)
    xt = T.embed_tokens(params, cfg, tokens[:, S:S + 1])
    hdec, _ = T.decode_step(params, cfg, xt, cache)
    err = float(jnp.max(jnp.abs(hdec[:, 0] - hfull[:, S])))
    assert err < 5e-4, (arch, err)


@pytest.mark.parametrize("arch", ["seamless-m4t-medium", "paligemma-3b"])
def test_frontend_archs_serve(arch):
    cfg = get_config(arch + "-smoke")
    m = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = m.init_params(key)
    batch = m.make_batch(key, 2, 24)
    logits, cache = m.prefill(params, batch, cache_len=32)
    assert logits.shape == (2, cfg.vocab_size)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = m.decode(params, cache, tok)
    assert logits2.shape == (2, cfg.vocab_size)
    assert jnp.isfinite(logits2).all()


def test_rnnt_loss_decreases_with_training_signal():
    """The RNN-T on learnable synthetic speech: a few SGD steps reduce loss."""
    from repro.data.synthetic import make_asr_corpus
    from repro.data.pipeline import asr_units
    cfg = get_config("rnnt-crdnn-smoke")
    m = build_model(cfg)
    corpus = make_asr_corpus(0, 32, n_feats=cfg.rnnt.n_feats,
                             vocab_size=cfg.rnnt.vocab_size)
    units = asr_units(corpus, 4)
    batch = {k: jnp.asarray(v[0]) for k, v in units.items()}
    params = m.init_params(jax.random.PRNGKey(0))
    opt_init, opt_update = make_optimizer("adamw")
    opt = opt_init(params)
    first = last = None
    for i in range(8):
        (l, _), g = jax.value_and_grad(
            lambda p: m.loss_fn(p, batch), has_aux=True)(params)
        g, _ = clip_by_global_norm(g, 5.0)
        params, opt = opt_update(params, g, opt, lr=3e-3)
        first = first if first is not None else float(l)
        last = float(l)
    assert last < first, (first, last)
