"""Resident selection rounds + retrace-free subset plans (DESIGN.md §1/§3):

* the epoch executable compiles exactly once across selection rounds with
  different ``n_selected`` (padded plans share one shape);
* weight-0 padding rows are bit-exact no-ops for ``(params, opt_state)``
  and contribute nothing to metrics;
* ``ResidentSelector`` stage A matches the host ``units_gradients`` path
  to fp32 tolerance on both the LM and RNN-T smoke configs, and the
  resulting selections agree;
* the end-to-end ``resident_selection=True`` training loop matches the
  host-selection scan loop.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.contracts import assert_retrace_free
from repro.configs import get_config
from repro.configs.base import PGMConfig, TrainConfig
from repro.core.lastlayer import make_proj_for, units_gradients
from repro.core.pgm import ResidentSelector, pgm_select
from repro.data.pipeline import lm_units, subset_epoch_plan, subset_iterator
from repro.data.synthetic import make_lm_corpus
from repro.models.api import build_model
from repro.train.engine import EpochEngine
from repro.train.loop import make_train_step, train_with_selection
from repro.train.optim import make_update_for


def _lm_engine(n_examples=64, seq=12, unit_size=4, batch_units=2,
               optimizer="adamw"):
    cfg = get_config("starcoder2-3b-smoke")
    m = build_model(cfg)
    units = lm_units(make_lm_corpus(0, n_examples, seq, cfg.vocab_size,
                                    hard_fraction=0.4), unit_size=unit_size)
    tc = TrainConfig(lr=0.5, optimizer=optimizer, epochs=1, pgm=PGMConfig())
    return m, units, tc, EpochEngine(m, tc, units, batch_units=batch_units)


def _stacked_units(m, n_units, B=2, S=16, seed0=0):
    return jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[m.make_batch(jax.random.PRNGKey(seed0 + i), B, S)
          for i in range(n_units)])


# ---------------------------------------------------------------------------
# Retrace-freedom
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_epoch_executable_compiles_once_across_rounds():
    """≥3 subset rounds with different n_selected inside one padding
    bucket must share one compiled epoch executable: after the first
    subset round compiles the bucket shape, the remaining rounds must
    dispatch with zero fresh XLA compilations (asserted through the
    shared ``analysis.contracts`` retrace contract, which counts real
    compiles — not a per-function side-effect counter)."""
    m, units, tc, eng = _lm_engine(n_examples=128, batch_units=1)
    assert eng.steps_per_epoch_max == 32 and eng.plan_granule == 4
    opt_init, _ = make_update_for(tc)
    params = m.init_params(jax.random.PRNGKey(0))
    opt = opt_init(params)
    params, opt, _ = eng.run_epoch(params, opt, tc.lr, eng.full_plan(0))
    rounds = []
    for rnd, n_sel in enumerate((13, 14, 16)):
        idx = np.arange(n_sel, dtype=np.int32)
        w = np.linspace(0.5, 2.0, n_sel).astype(np.float32)
        plan = eng.subset_plan(idx, w, epoch=rnd + 1)
        assert plan[0].shape == (16, 1)      # one bucket for all 3 rounds
        rounds.append((n_sel, plan))
    # round 1 compiles the bucket-shape executable; rounds 2-3 must not
    n_sel, plan = rounds[0]
    params, opt, losses = eng.run_epoch(params, opt, tc.lr, plan)
    with assert_retrace_free("subset rounds sharing a padding bucket"):
        for n_sel, plan in rounds[1:]:
            params, opt, losses = eng.run_epoch(params, opt, tc.lr, plan)
            assert int(eng.plan_live_steps(plan).sum()) == n_sel
            assert np.isfinite(np.asarray(losses)).all()


def test_subset_plan_padding_shape_and_sentinels():
    idx = np.asarray([3, 7, 1, 5], np.int32)
    w = np.asarray([1.0, 2.0, 0.5, 1.5], np.float32)
    pi, pw = subset_epoch_plan(idx, w, seed=0, epoch=0, batch_units=2,
                               pad_to_steps=5)
    assert pi.shape == pw.shape == (5, 2)
    assert (pi[2:] == -1).all() and (pw[2:] == 0).all()
    assert (pi[:2] >= 0).all()
    # padding never truncates real steps
    with pytest.raises(ValueError):
        subset_epoch_plan(idx, w, seed=0, epoch=0, batch_units=2,
                          pad_to_steps=1)
    # unpadded (legacy) shape is untouched
    pi0, _ = subset_epoch_plan(idx, w, seed=0, epoch=0, batch_units=2)
    assert pi0.shape == (2, 2)


def test_bucketed_padding_bounds_subset_epoch_cost():
    """Padding must not erase the subset-compute saving: the padded plan
    runs at most one granule (1/8 epoch) beyond the live steps, not the
    full-data step count."""
    m, units, tc, eng = _lm_engine(n_examples=128, batch_units=1)  # 32 units
    # never 0: an (almost-)empty selection stays in the bucket family
    assert [eng.bucket_steps(n) for n in (0, 1, 4, 5, 9, 31, 32)] == \
        [4, 4, 4, 8, 12, 32, 32]
    idx = np.arange(10, dtype=np.int32)          # 30% subset
    plan = eng.subset_plan(idx, np.ones(10, np.float32), epoch=0)
    n_steps = plan[0].shape[0]
    assert n_steps == 12                          # not steps_per_epoch_max
    assert n_steps - 10 < eng.plan_granule
    assert int(eng.plan_live_steps(plan).sum()) == 10
    # a selection smaller than one batch still pads into the bucket family
    # (an all-padding one-granule plan, not a fresh zero-length executable)
    m2, units2, tc2, eng2 = _lm_engine()         # batch_units=2
    tiny = eng2.subset_plan(np.asarray([0], np.int32),
                            np.ones(1, np.float32), epoch=0)
    assert tiny[0].shape == (eng2.plan_granule, eng2.batch_units)
    assert int(eng2.plan_live_steps(tiny).sum()) == 0
    p = m2.init_params(jax.random.PRNGKey(0))
    opt_init2, _ = make_update_for(tc2)
    o = opt_init2(p)
    leaf0 = np.asarray(jax.tree.leaves(p)[0])
    p2, o2, losses = eng2.run_epoch(p, o, tc2.lr, tiny)
    assert np.array_equal(leaf0, np.asarray(jax.tree.leaves(p2)[0]))
    assert int(o2["step"]) == 0                  # nothing advanced


# ---------------------------------------------------------------------------
# Padding rows are no-ops
# ---------------------------------------------------------------------------

def test_padding_batches_are_bit_exact_noops():
    """A padded subset epoch must leave (params, opt_state) bit-identical
    to the unpadded epoch (same executable math, gated selects), and the
    padding steps must report zero metric contribution."""
    m, units, tc, eng = _lm_engine()
    opt_init, _ = make_update_for(tc)
    idx = np.arange(6, dtype=np.int32)
    w = np.linspace(0.5, 2.0, 6).astype(np.float32)

    def run(pad_to_steps):
        p = m.init_params(jax.random.PRNGKey(1))
        o = opt_init(p)
        plan = eng.subset_plan(idx, w, epoch=0, pad_to_steps=pad_to_steps)
        p, o, losses = eng.run_epoch(p, o, tc.lr, plan)
        return p, o, losses, plan

    pp, po, lp, plan_pad = run(eng.steps_per_epoch_max)  # maximal padding
    up, uo, lu, _ = run(0)                 # legacy unpadded shape
    for a, b in zip(jax.tree.leaves((pp, po)), jax.tree.leaves((up, uo))):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            "padding steps advanced params/opt_state"
    live = eng.plan_live_steps(plan_pad)
    assert np.array_equal(np.asarray(lp)[live], np.asarray(lu))
    assert (np.asarray(lp)[~live] == 0.0).all()


@pytest.mark.slow
def test_padded_scan_matches_host_loop():
    """The padded scan epoch matches the legacy host loop over the same
    (unpadded) subset schedule; the host loop compiles its step
    independently, so parity is numerical (PR1 tolerance), not bitwise."""
    m, units, tc, eng = _lm_engine()
    opt_init, _ = make_update_for(tc)
    idx = np.arange(6, dtype=np.int32)
    w = np.linspace(0.5, 2.0, 6).astype(np.float32)

    p = m.init_params(jax.random.PRNGKey(1))
    o = opt_init(p)
    p, o, _ = eng.run_epoch(p, o, tc.lr, eng.subset_plan(idx, w, epoch=0))

    hp = m.init_params(jax.random.PRNGKey(1))
    ho = opt_init(hp)
    step_fn = make_train_step(m, tc)
    for batch in subset_iterator(units, idx, w, tc.seed, 0,
                                 eng.batch_units):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        hp, ho, _ = step_fn(hp, ho, batch, tc.lr)

    assert int(o["step"]) == int(ho["step"])     # padding: no counter ticks
    for a, b in zip(jax.tree.leaves(hp), jax.tree.leaves(p)):
        assert np.allclose(np.asarray(a), np.asarray(b), atol=1e-3)


# ---------------------------------------------------------------------------
# Resident stage A parity
# ---------------------------------------------------------------------------

def _stage_a_parity(arch, atol):
    cfg = get_config(arch)
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init_params(key)
    units = _stacked_units(m, 8)
    proj = make_proj_for(m, key, 16, 16)
    pc = PGMConfig(subset_fraction=0.5, n_partitions=2,
                   sketch_dim_h=16, sketch_dim_v=16)
    g_host = units_gradients(m, params, units, proj)
    selector = ResidentSelector(m, pc, proj)
    g_res = selector.stage_a(params, units)
    assert g_res.shape == g_host.shape
    scale = float(jnp.abs(g_host).max())
    assert np.allclose(np.asarray(g_res), np.asarray(g_host),
                       atol=atol * max(scale, 1.0)), \
        float(jnp.abs(g_res - g_host).max())
    sel_h = pgm_select(m, params, units, pc, proj)
    sel_r = selector(params, units)
    assert np.asarray(sel_h.indices).tolist() == \
        np.asarray(sel_r.indices).tolist()
    assert np.allclose(np.asarray(sel_h.weights), np.asarray(sel_r.weights),
                       atol=1e-4)


def test_resident_stage_a_matches_host_lm():
    _stage_a_parity("starcoder2-3b-smoke", atol=1e-5)


@pytest.mark.slow
def test_resident_stage_a_matches_host_rnnt():
    _stage_a_parity("rnnt-crdnn-smoke", atol=1e-5)


def test_resident_selector_exact_mode():
    """Paper-faithful exact gradients also route through the batched
    scanned pass (no sketch projections)."""
    cfg = get_config("starcoder2-3b-smoke")
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    units = _stacked_units(m, 4)
    pc = PGMConfig(subset_fraction=0.5, n_partitions=2, use_sketch=False)
    g_host = units_gradients(m, params, units, None, exact=True)
    g_res = ResidentSelector(m, pc, None).stage_a(params, units)
    assert np.allclose(np.asarray(g_res), np.asarray(g_host), atol=1e-5)


def test_resident_selector_reuses_one_stage_a_executable():
    """Across rounds (changing params, fixed unit shapes) stage A must be
    a jit cache hit — the projections are closed over the executable."""
    cfg = get_config("starcoder2-3b-smoke")
    m = build_model(cfg)
    units = _stacked_units(m, 8)
    proj = make_proj_for(m, jax.random.PRNGKey(3), 16, 16)
    pc = PGMConfig(subset_fraction=0.5, n_partitions=2)
    selector = ResidentSelector(m, pc, proj)
    p1 = m.init_params(jax.random.PRNGKey(0))
    p2 = m.init_params(jax.random.PRNGKey(1))
    selector(p1, units)
    misses0 = selector._stage_a._cache_size()
    selector(p2, units)
    assert selector._stage_a._cache_size() == misses0


# ---------------------------------------------------------------------------
# End-to-end loop wiring
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_train_with_resident_selection_matches_host_selection():
    cfg = get_config("starcoder2-3b-smoke")
    m = build_model(cfg)
    units = lm_units(make_lm_corpus(0, 32, 12, cfg.vocab_size,
                                    hard_fraction=0.4), unit_size=4)
    val = lm_units(make_lm_corpus(7, 16, 12, cfg.vocab_size), unit_size=4)
    tc = TrainConfig(
        lr=0.5, optimizer="sgd", epochs=4,
        pgm=PGMConfig(subset_fraction=0.5, n_partitions=2, select_every=2,
                      warm_start_epochs=1, sketch_dim_h=24, sketch_dim_v=24))
    h_ref = train_with_selection(m, units, tc, method="pgm", val_units=val,
                                 engine="scan")
    h_res = train_with_selection(m, units, tc, method="pgm", val_units=val,
                                 engine="scan", resident_selection=True)
    assert np.allclose(h_ref.train_loss, h_res.train_loss, atol=1e-3)
    assert np.allclose(h_ref.val_loss, h_res.val_loss, atol=1e-3)
    for sr, ss in zip(h_ref.selections, h_res.selections):
        assert sr["indices"] == ss["indices"]
    assert h_ref.cost_units == pytest.approx(h_res.cost_units)
