"""Property-based tests on the system's core invariants, swept over
explicit + seeded-random cases with the in-repo ``proptest`` helper
(hypothesis is not installed in this offline environment; the file name
is kept from the original hypothesis port so history lines up).

Covered properties: gram_omp budget/padding/duplicate invariants, tensor-
JL sketch distortion bounds and inner-product symmetry, partition-offset
globalization in partitioned_gm, streamed_er2 vocab-chunk invariance, and
RNN-T loss validity as an NLL."""
import jax
import jax.numpy as jnp
import numpy as np

from proptest import rand_cases, sweep
from repro.core.gm import gm_select
from repro.core.lastlayer import streamed_er2
from repro.core.pgm import partitioned_gm
from repro.core.rnnt_loss import rnnt_loss_from_logits
from repro.core.sketch import (
    exact_from_factors,
    make_projections,
    sketch_from_factors,
)


@sweep(rand_cases(8, 0,
                  seed=range(10_000),
                  n=(8, 16, 24),
                  D=(16, 48),
                  budget=(1, 3, 6)))
def test_omp_invariants(seed, n, D, budget):
    """For any gradient matrix/target: no duplicate picks, budget
    respected, non-negative weights, padded slots zeroed, finite error."""
    rng = np.random.default_rng(seed)
    G = jnp.asarray(rng.normal(size=(n, D)), jnp.float32)
    g_t = jnp.asarray(rng.normal(size=(D,)), jnp.float32)
    res = gm_select(G, g_t, budget=budget, lam=1e-3)
    sel = [int(i) for i in res.indices if i >= 0]
    assert len(sel) == len(set(sel))
    assert len(sel) <= budget
    assert float(res.weights.min()) >= 0.0
    for i, w in zip(res.indices, res.weights):
        if int(i) < 0:
            assert float(w) == 0.0
    assert np.isfinite(float(res.error))


@sweep(rand_cases(5, 1,
                  seed=range(10_000),
                  n_tok=(4, 12, 20),
                  vocab=(5, 16, 40),
                  chunk=(3, 7, 16)))
def test_streamed_er2_chunk_invariance(seed, n_tok, vocab, chunk):
    """E @ R2 must not depend on the vocab streaming chunk size."""
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.normal(size=(n_tok, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(8, vocab)), jnp.float32)
    rv = jnp.asarray(rng.normal(size=(vocab, 4)), jnp.float32)
    t = jnp.asarray(rng.integers(0, vocab, n_tok), jnp.int32)
    s = jnp.asarray(rng.uniform(0.1, 1.0, n_tok), jnp.float32)
    a = streamed_er2(h, w, t, s, rv, chunk=chunk)
    b = streamed_er2(h, w, t, s, rv, chunk=vocab)
    assert jnp.allclose(a, b, atol=1e-4), float(jnp.abs(a - b).max())


@sweep(rand_cases(6, 2, seed=range(10_000)))
def test_sketch_inner_product_symmetry(seed):
    """<S1,S2> == <S2,S1> and ||S||^2 >= 0 for any factors/projections."""
    rng = np.random.default_rng(seed)
    proj = make_projections(jax.random.PRNGKey(seed % 97), 6, 30, 8, 8)
    h1, h2 = (jnp.asarray(rng.normal(size=(5, 6)), jnp.float32)
              for _ in range(2))
    e1, e2 = (jnp.asarray(rng.normal(size=(5, 30)), jnp.float32)
              for _ in range(2))
    s1 = sketch_from_factors(h1, e1, proj)
    s2 = sketch_from_factors(h2, e2, proj)
    assert np.isclose(float(s1 @ s2), float(s2 @ s1), rtol=1e-5)
    assert float(s1 @ s1) >= 0.0


@sweep(rand_cases(6, 3, seed=range(10_000)))
def test_sketch_jl_distortion_bound(seed):
    """Tensor-JL estimate is unbiased; with k1=k2=32 on rank-limited
    factors the squared-norm distortion stays within a loose
    multiplicative band (these seeds are deterministic, so this is a
    regression bound, not a probabilistic claim)."""
    rng = np.random.default_rng(seed)
    proj = make_projections(jax.random.PRNGKey(seed % 89), 12, 40, 32, 32)
    h = jnp.asarray(rng.normal(size=(6, 12)), jnp.float32)
    e = jnp.asarray(rng.normal(size=(6, 40)), jnp.float32)
    s = sketch_from_factors(h, e, proj)
    g = exact_from_factors(h, e)
    ratio = float(s @ s) / max(float(g @ g), 1e-9)
    assert 0.2 < ratio < 5.0, ratio


@sweep(rand_cases(6, 4,
                  seed=range(10_000),
                  n_parts=(2, 4),
                  per=(3, 5, 8),
                  budget=(1, 2)))
def test_partition_offset_globalization(seed, n_parts, per, budget):
    """partitioned_gm returns *global* unit ids: every non-padded pick
    from partition p lies in [p*per, (p+1)*per), -1 padding passes
    through, and running each partition standalone reproduces the same
    local picks shifted by the partition offset."""
    rng = np.random.default_rng(seed)
    n = n_parts * per
    G = jnp.asarray(rng.normal(size=(n, 16)), jnp.float32)
    sel = partitioned_gm(G, n_parts, budget, lam=1e-3)
    idx = np.asarray(sel.indices).reshape(n_parts, budget)
    for p in range(n_parts):
        picks = [i for i in idx[p] if i >= 0]
        assert all(p * per <= i < (p + 1) * per for i in picks), idx
        # standalone OMP on the partition block reproduces the picks
        block = G[p * per:(p + 1) * per]
        solo = gm_select(block, block.sum(axis=0), budget=budget, lam=1e-3)
        solo_glob = sorted(int(i) + p * per for i in solo.indices if i >= 0)
        assert solo_glob == sorted(picks), (p, solo_glob, picks)


@sweep(rand_cases(6, 5,
                  seed=range(10_000),
                  T=(3, 5, 7),
                  U=(1, 4),
                  V=(3, 8)))
def test_rnnt_loss_is_valid_nll(seed, T, U, V):
    """Transducer NLL is finite and non-negative for any logits (it is a
    -log of a probability marginalized over alignments)."""
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(2, T, U + 1, V)), jnp.float32)
    labels = jnp.asarray(rng.integers(1, V, (2, U)), jnp.int32)
    t_lens = jnp.asarray([T, max(T - 1, U)], jnp.int32)
    u_lens = jnp.asarray([U, max(U - 1, 1)], jnp.int32)
    nll = rnnt_loss_from_logits(logits, labels, t_lens, u_lens)
    assert bool(jnp.isfinite(nll).all())
    assert float(nll.min()) >= 0.0
