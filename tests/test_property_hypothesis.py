"""Hypothesis property-based tests on the system's core invariants
(complements the explicit seeded sweeps in proptest.py)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.gm import gm_select
from repro.core.lastlayer import streamed_er2
from repro.core.rnnt_loss import rnnt_loss_from_logits
from repro.core.sketch import exact_from_factors, make_projections, sketch_from_factors

FAST = settings(max_examples=10, deadline=None)


@FAST
@given(st.integers(0, 10_000), st.integers(6, 24), st.integers(8, 48),
       st.integers(1, 6))
def test_omp_invariants(seed, n, D, budget):
    """For any gradient matrix/target: no duplicate picks, budget
    respected, non-negative weights, padded slots zeroed, finite error."""
    rng = np.random.default_rng(seed)
    G = jnp.asarray(rng.normal(size=(n, D)), jnp.float32)
    g_t = jnp.asarray(rng.normal(size=(D,)), jnp.float32)
    res = gm_select(G, g_t, budget=budget, lam=1e-3)
    sel = [int(i) for i in res.indices if i >= 0]
    assert len(sel) == len(set(sel))
    assert len(sel) <= budget
    assert float(res.weights.min()) >= 0.0
    for i, w in zip(res.indices, res.weights):
        if int(i) < 0:
            assert float(w) == 0.0
    assert np.isfinite(float(res.error))


@FAST
@given(st.integers(0, 10_000), st.integers(4, 20), st.integers(5, 40),
       st.sampled_from([3, 7, 16]))
def test_streamed_er2_chunk_invariance(seed, n_tok, vocab, chunk):
    """E @ R2 must not depend on the vocab streaming chunk size."""
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.normal(size=(n_tok, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(8, vocab)), jnp.float32)
    rv = jnp.asarray(rng.normal(size=(vocab, 4)), jnp.float32)
    t = jnp.asarray(rng.integers(0, vocab, n_tok), jnp.int32)
    s = jnp.asarray(rng.uniform(0.1, 1.0, n_tok), jnp.float32)
    a = streamed_er2(h, w, t, s, rv, chunk=chunk)
    b = streamed_er2(h, w, t, s, rv, chunk=vocab)
    assert jnp.allclose(a, b, atol=1e-4), float(jnp.abs(a - b).max())


@FAST
@given(st.integers(0, 10_000))
def test_sketch_inner_product_symmetry(seed):
    """<S1,S2> == <S2,S1> and ||S||^2 >= 0 for any factors/projections."""
    rng = np.random.default_rng(seed)
    proj = make_projections(jax.random.PRNGKey(seed % 97), 6, 30, 8, 8)
    h1, h2 = (jnp.asarray(rng.normal(size=(5, 6)), jnp.float32)
              for _ in range(2))
    e1, e2 = (jnp.asarray(rng.normal(size=(5, 30)), jnp.float32)
              for _ in range(2))
    s1 = sketch_from_factors(h1, e1, proj)
    s2 = sketch_from_factors(h2, e2, proj)
    assert np.isclose(float(s1 @ s2), float(s2 @ s1), rtol=1e-5)
    assert float(s1 @ s1) >= 0.0


@FAST
@given(st.integers(0, 10_000), st.integers(3, 7), st.integers(1, 4),
       st.integers(3, 8))
def test_rnnt_loss_is_valid_nll(seed, T, U, V):
    """Transducer NLL is finite and non-negative for any logits (it is a
    -log of a probability marginalized over alignments)."""
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(2, T, U + 1, V)), jnp.float32)
    labels = jnp.asarray(rng.integers(1, V, (2, U)), jnp.int32)
    t_lens = jnp.asarray([T, max(T - 1, U)], jnp.int32)
    u_lens = jnp.asarray([U, max(U - 1, 1)], jnp.int32)
    nll = rnnt_loss_from_logits(logits, labels, t_lens, u_lens)
    assert bool(jnp.isfinite(nll).all())
    assert float(nll.min()) >= 0.0
