"""Compressed pod-axis gradient collectives inside the sharded scanned
step (``train/engine.py`` two-level ``data x pod`` mode, DESIGN.md §5):

* fast tier — cheap in-process pieces on a degenerate (1,1)
  ``data x pod`` mesh (the whole vmap/pmean/error-feedback machinery
  runs, collectives are size-1): config validation, error-state shapes
  and donation, bit-exact no-op padding semantics for the
  error-feedback state, and the err sharding/restore spec rules;
* slow tier — full training runs: (1,1)-mesh parity vs the plain scan
  engine, graceful cross-compress-mode resume, and the 4-device
  subprocess suite (style of
  ``tests/test_sharded_engine.py``): ``compress_mode="none"`` is
  bit-close to both the single-device engine and the existing
  GSPMD-only ``data x model`` engine; top-k + error feedback trains the
  LM smoke to within 5% relative final val loss of dense; mid-run
  checkpoint resume with error-feedback state is bit-exact vs
  uninterrupted; and the lowered step reduces the pod collective at
  bf16 width while the compiled module carries pod-group all-reduces.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.contracts import assert_retrace_free
from repro.configs import get_config
from repro.configs.base import PGMConfig, TrainConfig
from repro.data.pipeline import lm_units
from repro.data.synthetic import make_lm_corpus
from repro.models.api import build_model
from repro.train.engine import EpochEngine, make_engine
from repro.train.loop import train_with_selection
from repro.train.optim import make_update_for

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _lm_setup(n=16, seq=10, epochs=2, compress_mode="none", k_frac=0.1):
    cfg = get_config("starcoder2-3b-smoke")
    m = build_model(cfg)
    units = lm_units(make_lm_corpus(0, n, seq, cfg.vocab_size,
                                    hard_fraction=0.4), unit_size=4)
    val = lm_units(make_lm_corpus(7, 8, seq, cfg.vocab_size), unit_size=4)
    tc = TrainConfig(lr=0.5, optimizer="sgd", epochs=epochs,
                     compress_mode=compress_mode, compress_k_frac=k_frac,
                     pgm=PGMConfig())
    return m, units, val, tc


def _bitwise_equal(tree_a, tree_b):
    return all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(tree_a),
                               jax.tree.leaves(tree_b)))


# ---------------------------------------------------------------------------
# Degenerate (1,1) data x pod mesh: full machinery, single device (fast)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_pod_modes_match_plain_engine_on_1x1_mesh():
    m, units, val, tc = _lm_setup()
    h_plain = train_with_selection(m, units, tc, method="full",
                                   val_units=val, engine="scan")
    mesh = jax.make_mesh((1, 1), ("data", "pod"))
    h_none = train_with_selection(
        m, units, dataclasses.replace(tc, compress_mode="none"),
        method="full", val_units=val, engine="scan", mesh=mesh)
    assert np.allclose(h_plain.train_loss, h_none.train_loss,
                       rtol=1e-3, atol=1e-3)
    assert np.allclose(h_plain.val_loss, h_none.val_loss,
                       rtol=1e-3, atol=1e-3)
    # (bf16 parity is covered by the 4-device slow suite, where the
    # collective is real)  topk still trains and carries residuals
    h_topk = train_with_selection(
        m, units, dataclasses.replace(tc, compress_mode="topk"),
        method="full", val_units=val, engine="scan", mesh=mesh)
    assert np.isfinite(h_topk.train_loss).all()


def test_topk_engine_error_state_shape_and_donation():
    m, units, _, tc = _lm_setup(compress_mode="topk")
    mesh = jax.make_mesh((1, 1), ("data", "pod"))
    eng = EpochEngine(m, tc, units, batch_units=2, mesh=mesh)
    assert eng.uses_error_feedback and eng.n_pods == 1
    opt_init, _ = make_update_for(tc)
    p = m.init_params(jax.random.PRNGKey(0))
    o = opt_init(p)
    p, o = eng.shard_state(p, o)
    p, o, losses = eng.run_epoch(p, o, tc.lr, eng.full_plan(0))
    err = eng.compress_state
    assert err is not None
    for pl, el in zip(jax.tree.leaves(p), jax.tree.leaves(err)):
        assert el.shape == (1,) + pl.shape
        assert el.dtype == jnp.float32
    # residuals are live after a top-k epoch
    assert any(float(jnp.abs(l).max()) > 0 for l in jax.tree.leaves(err))


def test_padding_steps_leave_error_state_bitwise():
    """An all-padding plan must advance nothing: params, opt state AND
    the error-feedback residuals come back bit-identical."""
    m, units, _, tc = _lm_setup(compress_mode="topk")
    mesh = jax.make_mesh((1, 1), ("data", "pod"))
    eng = EpochEngine(m, tc, units, batch_units=2, mesh=mesh)
    opt_init, _ = make_update_for(tc)
    p = m.init_params(jax.random.PRNGKey(0))
    o = opt_init(p)
    p, o = eng.shard_state(p, o)
    p, o, _ = eng.run_epoch(p, o, tc.lr, eng.full_plan(0))
    before = (jax.tree.map(np.asarray, p), jax.tree.map(np.asarray, o),
              jax.tree.map(np.asarray, eng.compress_state))
    pad_plan = (jnp.full((2, 2), -1, jnp.int32),
                jnp.zeros((2, 2), jnp.float32))
    p, o, losses = eng.run_epoch(p, o, tc.lr, pad_plan)
    assert np.asarray(losses).tolist() == [0.0, 0.0]
    after = (p, o, eng.compress_state)
    for b, a in zip(before, after):
        assert _bitwise_equal(b, a)


def test_recurrent_padding_on_pod_mesh_bitwise():
    """The weight-0 gate must hold through the pod-mode step on the
    recurrent substrate too (DESIGN.md §8): an all-padding plan on a
    (1,1) ``data x pod`` top-k engine leaves RWKV6 params, opt state and
    the error-feedback residuals bit-identical."""
    cfg = get_config("rwkv6-3b-smoke")
    m = build_model(cfg)
    units = lm_units(make_lm_corpus(0, 8, 10, cfg.vocab_size,
                                    hard_fraction=0.4), unit_size=2)
    tc = TrainConfig(lr=0.2, optimizer="sgd", epochs=1,
                     compress_mode="topk", compress_k_frac=0.1,
                     pgm=PGMConfig())
    mesh = jax.make_mesh((1, 1), ("data", "pod"))
    eng = EpochEngine(m, tc, units, batch_units=2, mesh=mesh)
    opt_init, _ = make_update_for(tc)
    p = m.init_params(jax.random.PRNGKey(0))
    o = opt_init(p)
    p, o = eng.shard_state(p, o)
    p, o, _ = eng.run_epoch(p, o, tc.lr, eng.full_plan(0))
    before = (jax.tree.map(np.asarray, p), jax.tree.map(np.asarray, o),
              jax.tree.map(np.asarray, eng.compress_state))
    pad_plan = (jnp.full((2, 2), -1, jnp.int32),
                jnp.zeros((2, 2), jnp.float32))
    p, o, losses = eng.run_epoch(p, o, tc.lr, pad_plan)
    assert np.asarray(losses).tolist() == [0.0, 0.0]
    for b, a in zip(before, (p, o, eng.compress_state)):
        assert _bitwise_equal(b, a)


def test_guard_composes_with_pod_compression_bitwise():
    """The non-finite guard under pod-mode top-k compression
    (DESIGN.md §10): guard-on over finite data is bit-identical to
    guard-off — params, opt state AND error-feedback residuals — and a
    fully poisoned plan rolls all three back bit-exactly (the residuals
    gate through the same ``gate_step`` select as the padding rows)."""
    outs = {}
    for guard in (False, True):
        m, units, _, tc = _lm_setup(compress_mode="topk")
        tc = dataclasses.replace(tc, nonfinite_guard=guard)
        mesh = jax.make_mesh((1, 1), ("data", "pod"))
        eng = EpochEngine(m, tc, units, batch_units=2, mesh=mesh)
        opt_init, _ = make_update_for(tc)
        p = m.init_params(jax.random.PRNGKey(0))
        o = opt_init(p)
        p, o = eng.shard_state(p, o)
        p, o, _ = eng.run_epoch(p, o, tc.lr, eng.full_plan(0))
        outs[guard] = (p, o, eng.compress_state, eng)
    for a, b in zip(outs[False][:3], outs[True][:3]):
        assert _bitwise_equal(a, b)
    # a poisoned epoch on the guarded engine: every step gated off,
    # residuals included — and no retrace
    p, o, err, eng = outs[True]
    before = (jax.tree.map(np.asarray, p), jax.tree.map(np.asarray, o),
              jax.tree.map(np.asarray, err))
    idx, w = eng.full_plan(1)
    w = jnp.full_like(w, jnp.nan)
    with assert_retrace_free("guarded compressed epoch on poisoned plan"):
        p, o, losses = eng.run_epoch(p, o, 0.5, (idx, w))
    assert int(eng.last_n_skipped) == int(idx.shape[0])
    assert np.asarray(losses).tolist() == [0.0] * int(idx.shape[0])
    for b, a in zip(before, (p, o, eng.compress_state)):
        assert _bitwise_equal(b, a)


def test_compress_config_validation():
    m, units, _, tc = _lm_setup(compress_mode="bf16")
    # compression without a pod axis on the mesh is a config error …
    with pytest.raises(ValueError, match="pod"):
        EpochEngine(m, tc, units, batch_units=2)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="pod"):
        EpochEngine(m, tc, units, batch_units=2, mesh=mesh)
    # … and the host loop refuses it loudly instead of training dense
    # under a label that says compressed
    with pytest.raises(ValueError, match="scan"):
        make_engine("host", m, tc, units, batch_units=2)
    host = make_engine(
        "host", m, dataclasses.replace(tc, compress_mode="none"), units,
        batch_units=2)
    assert host.uses_error_feedback is False and host.compress_state is None


@pytest.mark.slow
def test_resume_across_compress_modes_is_graceful(tmp_path):
    """A topk resume from a checkpoint written without error-feedback
    state (different compress_mode) must warn and start residuals from
    zero — not KeyError on the missing 'err' arrays — and the reverse
    direction must warn about the mode switch."""
    m, units, val, tc = _lm_setup(epochs=2)
    mesh = jax.make_mesh((1, 1), ("data", "pod"))
    d = str(tmp_path / "ck")
    train_with_selection(
        m, units, dataclasses.replace(tc, compress_mode="none"),
        method="full", val_units=val, engine="scan", mesh=mesh, ckpt_dir=d)
    logs = []
    h = train_with_selection(
        m, units, dataclasses.replace(tc, compress_mode="topk", epochs=3),
        method="full", val_units=val, engine="scan", mesh=mesh,
        ckpt_dir=d, resume=True, log_fn=logs.append)
    assert np.isfinite(h.train_loss).all()
    assert any("compress_mode" in l for l in logs)
    assert any("residuals restart from zero" in l for l in logs)
    # reverse: dense resume from a topk checkpoint ignores the err
    # arrays but flags the switch
    logs2 = []
    h2 = train_with_selection(
        m, units, dataclasses.replace(tc, compress_mode="none", epochs=4),
        method="full", val_units=val, engine="scan", mesh=mesh,
        ckpt_dir=d, resume=True, log_fn=logs2.append)
    assert np.isfinite(h2.train_loss).all()
    assert any("compress_mode" in l for l in logs2)


def test_err_sharding_and_restore_specs():
    m, units, _, tc = _lm_setup(compress_mode="topk")
    mesh = jax.make_mesh((1, 1), ("data", "pod"))
    eng = EpochEngine(m, tc, units, batch_units=2, mesh=mesh)
    p = m.init_params(jax.random.PRNGKey(0))
    err = eng.init_compress_state(p)
    shs = eng.err_shardings(err)
    for sh in jax.tree.leaves(shs):
        assert sh.spec[0] == "pod"        # leading pod dim, always
    # checkpoint-tree paths: err leaves reshard with the pod-leading
    # spec, params/opt leaves with the plain param spec
    w = np.zeros((1, 64, 64), np.float32)
    sh = eng.restore_sharding("['err']['blocks']['attn']['wq']", w)
    assert sh.spec[0] == "pod"
    sh_p = eng.restore_sharding("['params']['blocks']['attn']['wq']",
                                w[0])
    assert sh_p.spec[0] != "pod"


# ---------------------------------------------------------------------------
# 4-device subprocess parity / convergence / resume (slow tier)
# ---------------------------------------------------------------------------

def _run(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert p.returncode == 0, p.stderr[-3000:]
    return p.stdout


_SETUP = """
import dataclasses
import numpy as np, jax
from repro.configs import get_config
from repro.configs.base import PGMConfig, TrainConfig
from repro.data.pipeline import lm_units
from repro.data.synthetic import make_lm_corpus
from repro.models.api import build_model
from repro.train.loop import train_with_selection
assert jax.device_count() == 4
cfg = get_config("starcoder2-3b-smoke")
m = build_model(cfg)
units = lm_units(make_lm_corpus(0, 32, 12, cfg.vocab_size,
                                hard_fraction=0.4), 4)
val = lm_units(make_lm_corpus(7, 16, 12, cfg.vocab_size), 4)
pod_mesh = jax.make_mesh((2, 2), ("data", "pod"))
"""


@pytest.mark.slow
def test_pod_none_matches_gspmd_only_engine():
    """The restructured step (per-pod grads + explicit fp32 pod pmean)
    must stay on the trajectory of both the single-device scan engine
    and the existing GSPMD-only data x model engine — same tolerance
    family as tests/test_sharded_engine.py."""
    out = _run(_SETUP + textwrap.dedent("""
        tc = TrainConfig(lr=0.5, optimizer="sgd", epochs=4,
                         pgm=PGMConfig(subset_fraction=0.5, n_partitions=2,
                                       select_every=2, warm_start_epochs=1,
                                       sketch_dim_h=24, sketch_dim_v=24))
        h1 = train_with_selection(m, units, tc, method="pgm",
                                  val_units=val, engine="scan",
                                  batch_units=2)
        gspmd = jax.make_mesh((2, 2), ("data", "model"))
        h2 = train_with_selection(m, units, tc, method="pgm",
                                  val_units=val, engine="scan",
                                  mesh=gspmd, batch_units=2)
        tcn = dataclasses.replace(tc, compress_mode="none")
        h3 = train_with_selection(m, units, tcn, method="pgm",
                                  val_units=val, engine="scan",
                                  mesh=pod_mesh, batch_units=2)
        for name, ref in (("single", h1), ("gspmd", h2)):
            assert np.allclose(ref.train_loss, h3.train_loss,
                               rtol=1e-3, atol=1e-3), \\
                (name, ref.train_loss, h3.train_loss)
            assert np.allclose(ref.val_loss, h3.val_loss,
                               rtol=1e-3, atol=1e-3), (name,)
            for sa, sb in zip(ref.selections, h3.selections):
                assert sa["indices"] == sb["indices"], (name, sa, sb)
        # chunked pod dispatch stays on the same trajectory
        h4 = train_with_selection(m, units, tcn, method="pgm",
                                  val_units=val, engine="scan",
                                  mesh=pod_mesh, batch_units=2,
                                  epoch_chunk=4)
        assert np.allclose(h3.train_loss, h4.train_loss, atol=1e-3)
        print("POD-NONE-OK")
    """))
    assert "POD-NONE-OK" in out


@pytest.mark.slow
def test_pod_topk_trains_within_5pct_of_dense():
    """Top-k (10% of entries per leaf) + error feedback must reach a
    final validation loss within 5% relative of the dense pod run on the
    LM smoke — the convergence-preservation claim of Stich et al."""
    out = _run(_SETUP + textwrap.dedent("""
        base = TrainConfig(lr=0.3, optimizer="sgd", epochs=8,
                           pgm=PGMConfig())
        finals = {}
        for mode in ("none", "bf16", "topk"):
            tc = dataclasses.replace(base, compress_mode=mode,
                                     compress_k_frac=0.1)
            h = train_with_selection(m, units, tc, method="full",
                                     val_units=val, engine="scan",
                                     mesh=pod_mesh, batch_units=2)
            finals[mode] = h.val_loss[-1]
        rel_topk = abs(finals["topk"] - finals["none"]) / finals["none"]
        rel_bf16 = abs(finals["bf16"] - finals["none"]) / finals["none"]
        assert rel_topk <= 0.05, (finals, rel_topk)
        assert rel_bf16 <= 0.05, (finals, rel_bf16)
        print(f"POD-TOPK-OK rel_topk={rel_topk:.4f} rel_bf16={rel_bf16:.4f}")
    """))
    assert "POD-TOPK-OK" in out


@pytest.mark.slow
def test_pod_topk_resume_bit_exact():
    """Interrupt a chunked top-k run mid-way and resume: because the
    per-pod error-feedback residuals are checkpointed and restored, the
    remaining epochs are bit-identical to the uninterrupted run."""
    out = _run(_SETUP + textwrap.dedent("""
        import tempfile
        tc = TrainConfig(lr=0.5, optimizer="sgd", epochs=6,
                         compress_mode="topk", compress_k_frac=0.1,
                         pgm=PGMConfig(subset_fraction=0.5, n_partitions=2,
                                       select_every=2, warm_start_epochs=1,
                                       sketch_dim_h=24, sketch_dim_v=24))
        with tempfile.TemporaryDirectory() as d:
            h_full = train_with_selection(
                m, units, tc, method="pgm", val_units=val, engine="scan",
                mesh=pod_mesh, batch_units=2, epoch_chunk=2,
                ckpt_dir=d + "/full")
            tc4 = dataclasses.replace(tc, epochs=4)
            train_with_selection(
                m, units, tc4, method="pgm", val_units=val, engine="scan",
                mesh=pod_mesh, batch_units=2, epoch_chunk=2,
                ckpt_dir=d + "/cut")
            h_res = train_with_selection(
                m, units, tc, method="pgm", val_units=val, engine="scan",
                mesh=pod_mesh, batch_units=2, epoch_chunk=2,
                ckpt_dir=d + "/cut", resume=True)
            import json, os
            man = json.load(open(os.path.join(
                d, "full", "step_5", "manifest.json")))
            assert man["compress_mode"] == "topk", man["compress_mode"]
            assert any("'err'" in k for k in man["arrays"]), \\
                list(man["arrays"])[:3]
        assert h_res.train_loss == h_full.train_loss[4:], \\
            (h_res.train_loss, h_full.train_loss)
        assert h_res.val_loss == h_full.val_loss[4:]
        print("POD-RESUME-OK")
    """))
    assert "POD-RESUME-OK" in out


@pytest.mark.slow
def test_pod_step_hlo_collective_and_divisibility():
    """The pod step's compiled artifacts satisfy the level-2 contracts
    (repro.analysis.contracts): in bf16 mode the lowered module reduces
    the gradient leaves at bf16 width — one reduce per param leaf, wire
    width checked pre-optimization — the compiled module's all-reduces
    group over the pod axis on both a 2x2 (data, pod) mesh (pairs
    {0,2},{1,3}) and a 1x4 all-pod mesh ({0,1,2,3}), the donated carry
    is marked donor, and the epoch body stays device-resident.
    Indivisible per-pod batches are a build-time error."""
    out = _run(textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.analysis.contracts import (
            assert_collective_width, assert_donated,
            assert_no_host_transfers, assert_replica_groups)
        from repro.configs import get_config
        from repro.configs.base import PGMConfig, TrainConfig
        from repro.data.pipeline import lm_units
        from repro.data.synthetic import make_lm_corpus
        from repro.models.api import build_model
        from repro.train.engine import EpochEngine
        from repro.train.optim import make_update_for
        cfg = get_config("starcoder2-3b-smoke")
        m = build_model(cfg)
        units = lm_units(make_lm_corpus(0, 16, 10, cfg.vocab_size), 4)
        tc = TrainConfig(lr=0.5, optimizer="sgd", epochs=1,
                         compress_mode="bf16", pgm=PGMConfig())
        for shape in ((2, 2), (1, 4)):
            mesh = jax.make_mesh(shape, ("data", "pod"))
            eng = EpochEngine(m, tc, units, batch_units=2, mesh=mesh)
            opt_init, _ = make_update_for(tc)
            p = m.init_params(jax.random.PRNGKey(0))
            o = opt_init(p)
            p, o = eng.shard_state(p, o)
            idx, w = eng.full_plan(0)
            low = eng._run.lower(p, o, None, idx, w, jnp.float32(0.5))
            n_leaves = len(jax.tree.leaves(p))
            # wire width: one bf16 pod reduce per gradient leaf, read
            # off the lowered module (XLA:CPU float-normalization
            # promotes compiled reduces, so compiled text can't prove
            # this)
            assert_collective_width(low, dtype="bf16",
                                    n_expected=n_leaves)
            # the (params, opt_state) carry is donated into the scan
            assert_donated(low, (p, o))
            txt = low.compile().as_text()
            # real all-reduces grouped exactly over the pod axis
            assert_replica_groups(txt, mesh, "pod")
            # the whole epoch dispatch stays device-resident
            assert_no_host_transfers(low, txt)
        # unit_size=3 batches cannot split across 2 pods
        mesh = jax.make_mesh((2, 2), ("data", "pod"))
        units_odd = lm_units(make_lm_corpus(0, 16, 10, cfg.vocab_size), 3)
        try:
            EpochEngine(m, tc, units_odd, batch_units=1, mesh=mesh)
            raise SystemExit("expected ValueError")
        except ValueError as e:
            assert "pod" in str(e)
        print("POD-HLO-OK")
    """))
    assert "POD-HLO-OK" in out
