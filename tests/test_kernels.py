"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp
oracle, per the deliverable-c requirement.  Everything here carries the
``kernel`` marker (and none is ``slow``), so the fast tier
(``pytest -m "not slow"``) covers the whole sweep and ``-m kernel``
selects just it."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import rand_cases

pytestmark = pytest.mark.kernel

RNG = np.random.default_rng(42)


def _arr(shape, dtype=jnp.float32, scale=1.0, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    return jnp.asarray(rng.normal(size=shape) * scale, dtype)


# ---------------------------------------------------------------------------
# grad_sketch
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "N,d,V,k1,k2,tn,tv,dtype",
    [(40, 32, 300, 16, 16, 16, 128, jnp.float32),
     (256, 64, 1000, 32, 32, 128, 256, jnp.float32),
     (100, 48, 517, 8, 24, 32, 100, jnp.float32),
     (64, 32, 301, 16, 16, 32, 64, jnp.bfloat16),
     (17, 16, 64, 8, 8, 8, 32, jnp.float32)])
def test_grad_sketch_matches_oracle(N, d, V, k1, k2, tn, tv, dtype):
    from repro.kernels.grad_sketch.kernel import grad_sketch
    from repro.kernels.grad_sketch.ref import grad_sketch_ref
    h = _arr((N, d), dtype)
    w = _arr((d, V), dtype, 0.1)
    rh, rv = _arr((d, k1)), _arr((V, k2))
    t = jnp.asarray(RNG.integers(0, V, N), jnp.int32)
    s = jnp.asarray(RNG.uniform(0.5, 1.0, N), jnp.float32)
    want = grad_sketch_ref(h, w, rh, rv, t, s)
    got = grad_sketch(h, w, rh, rv, t, s, tn=tn, tv=tv, interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    rel = float(jnp.abs(got - want).max() / (jnp.abs(want).max() + 1e-9))
    assert rel < tol, rel


def test_grad_sketch_op_jnp_path_matches():
    from repro.kernels.grad_sketch.ops import grad_sketch_op
    from repro.kernels.grad_sketch.ref import grad_sketch_ref
    h, w = _arr((50, 24)), _arr((24, 400), scale=0.1)
    rh, rv = _arr((24, 12)), _arr((400, 12))
    t = jnp.asarray(RNG.integers(0, 400, 50), jnp.int32)
    s = jnp.ones((50,), jnp.float32)
    want = grad_sketch_ref(h, w, rh, rv, t, s)
    got = grad_sketch_op(h, w, rh, rv, t, s, use_pallas=False, vocab_chunk=128)
    assert jnp.allclose(got, want, atol=1e-4)


# ---------------------------------------------------------------------------
# omp_gram
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,D,ti,td,dtype",
                         [(40, 100, 16, 64, jnp.float32),
                          (130, 257, 64, 64, jnp.float32),
                          (64, 128, 32, 128, jnp.bfloat16),
                          (7, 9, 8, 8, jnp.float32)])
def test_omp_gram_matches_oracle(n, D, ti, td, dtype):
    from repro.kernels.omp_gram.kernel import omp_gram
    from repro.kernels.omp_gram.ref import omp_gram_ref
    g = _arr((n, D), dtype)
    got = omp_gram(g, ti=ti, tj=ti, td=td, interpret=True)
    want = omp_gram_ref(g)
    tol = 1e-3 if dtype == jnp.float32 else 1e-1
    assert jnp.allclose(got, want, atol=tol), float(jnp.abs(got - want).max())


# ---------------------------------------------------------------------------
# swa_attn
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,H,S,hd,W,tq,dtype",
                         [(2, 3, 128, 32, 32, 16, jnp.float32),
                          (1, 2, 256, 64, 64, 32, jnp.float32),
                          (2, 2, 64, 16, 16, 16, jnp.float32),
                          (1, 2, 128, 32, 64, 32, jnp.bfloat16),
                          (1, 1, 96, 16, 32, 32, jnp.float32)])
def test_swa_attn_matches_oracle(B, H, S, hd, W, tq, dtype):
    from repro.kernels.swa_attn.kernel import swa_attn
    from repro.kernels.swa_attn.ref import swa_attn_ref
    q, k, v = (_arr((B, H, S, hd), dtype) for _ in range(3))
    got = swa_attn(q, k, v, window=W, tq=tq, interpret=True)
    want = swa_attn_ref(q, k, v, window=W)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    assert jnp.allclose(got.astype(jnp.float32), want.astype(jnp.float32),
                        atol=tol)


# ---------------------------------------------------------------------------
# rnnt_lattice
# ---------------------------------------------------------------------------
NEG = -1e30


def _lattice_inputs(T, B, U1, seed):
    """Random lattice rows with the kernel's structural invariants:
    emit[:, :, 0] = NEG, sparse additive seeds like the alpha/beta uses."""
    rng = np.random.default_rng(seed)
    mult = jnp.asarray(rng.normal(size=(T, B, U1)), jnp.float32)
    add = jnp.where(jnp.asarray(rng.uniform(size=(T, B, U1))) < 0.3,
                    jnp.asarray(rng.normal(size=(T, B, U1)), jnp.float32),
                    NEG)
    emit = jnp.asarray(rng.normal(size=(T, B, U1)),
                       jnp.float32).at[:, :, 0].set(NEG)
    return mult, add, emit


@pytest.mark.parametrize("T,B,U1",
                         [(1, 1, 1), (5, 2, 2), (7, 3, 5), (12, 2, 8),
                          (4, 4, 17), (9, 1, 33)])
def test_rnnt_lattice_matches_oracle(T, B, U1):
    from repro.kernels.rnnt_lattice.kernel import rnnt_lattice
    from repro.kernels.rnnt_lattice.ref import rnnt_lattice_ref
    mult, add, emit = _lattice_inputs(T, B, U1, seed=T * 100 + U1)
    got = rnnt_lattice(mult, add, emit, interpret=True)
    want = rnnt_lattice_ref(mult, add, emit)
    assert got.shape == (T, B, U1)
    assert jnp.allclose(got, want, atol=1e-4), \
        float(jnp.abs(got - want).max())


def test_rnnt_lattice_op_dispatch_matches():
    from repro.kernels.rnnt_lattice.ops import rnnt_lattice_op
    from repro.kernels.rnnt_lattice.ref import rnnt_lattice_ref
    mult, add, emit = _lattice_inputs(6, 2, 4, seed=0)
    want = rnnt_lattice_ref(mult, add, emit)
    got_ref = rnnt_lattice_op(mult, add, emit, use_pallas=False)
    got_pal = rnnt_lattice_op(mult, add, emit, use_pallas=True,
                              interpret=True)
    assert jnp.allclose(got_ref, want, atol=1e-5)
    assert jnp.allclose(got_pal, want, atol=1e-4)


def test_rnnt_lattice_kernel_through_fused_loss():
    """End to end: the fused transducer loss with the interpret-mode
    Pallas lattice agrees with the dense oracle on values and head
    gradients (ragged lengths included)."""
    from repro.core.rnnt_loss import rnnt_loss_from_logits, rnnt_loss_fused
    rng = np.random.default_rng(3)
    B, T, U, J, V = 3, 6, 4, 5, 11
    ze = jnp.asarray(rng.normal(size=(B, T, J)), jnp.float32)
    zp = jnp.asarray(rng.normal(size=(B, U + 1, J)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(J, V)) * 0.5, jnp.float32)
    labels = jnp.asarray(rng.integers(1, V, (B, U)), jnp.int32)
    t_lens = jnp.asarray([6, 1, 4], jnp.int32)
    u_lens = jnp.asarray([4, 0, 2], jnp.int32)

    def dense(w):
        logits = jnp.tanh(ze[:, :, None, :] + zp[:, None, :, :]) @ w
        return rnnt_loss_from_logits(logits, labels, t_lens, u_lens)

    fused = lambda w: rnnt_loss_fused(ze, zp, w, labels, t_lens, u_lens,
                                      lattice_impl="interpret")
    assert jnp.allclose(fused(w), dense(w), atol=1e-5)
    gd = jax.grad(lambda w: dense(w).sum())(w)
    gf = jax.grad(lambda w: fused(w).sum())(w)
    rel = float(jnp.abs(gf - gd).max() / (jnp.abs(gd).max() + 1e-9))
    assert rel < 1e-4, rel


# ---------------------------------------------------------------------------
# rwkv6 chunked WKV
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,N,C",
                         [(2, 64, 2, 16, 16), (1, 128, 3, 32, 32),
                          (2, 96, 1, 8, 32), (1, 64, 2, 64, 64)])
def test_rwkv6_wkv_matches_sequential(B, S, H, N, C):
    from repro.kernels.rwkv6_scan.kernel import rwkv6_wkv
    from repro.kernels.rwkv6_scan.ref import rwkv6_wkv_ref
    r, k, v = (_arr((B, S, H, N)) for _ in range(3))
    w = jnp.asarray(RNG.uniform(0.4, 0.99, (B, S, H, N)), jnp.float32)
    u = _arr((H, N), scale=0.1)
    y_got, s_got = rwkv6_wkv(r, k, v, w, u, chunk=C, interpret=True)
    y_want, s_want = rwkv6_wkv_ref(r, k, v, w, u)
    assert jnp.allclose(y_got, y_want, atol=1e-3)
    assert jnp.allclose(s_got, s_want, atol=1e-3)


def test_rwkv6_extreme_decays_stable():
    """Near-zero decays (log w very negative) must not overflow/NaN."""
    from repro.kernels.rwkv6_scan.kernel import rwkv6_wkv
    B, S, H, N = 1, 64, 1, 8
    r, k, v = (_arr((B, S, H, N)) for _ in range(3))
    w = jnp.full((B, S, H, N), 1e-6)
    y, s = rwkv6_wkv(r, k, v, w, _arr((H, N)), chunk=16, interpret=True)
    assert jnp.isfinite(y).all() and jnp.isfinite(s).all()
