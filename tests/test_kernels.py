"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp
oracle, per the deliverable-c requirement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import rand_cases

RNG = np.random.default_rng(42)


def _arr(shape, dtype=jnp.float32, scale=1.0, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    return jnp.asarray(rng.normal(size=shape) * scale, dtype)


# ---------------------------------------------------------------------------
# grad_sketch
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "N,d,V,k1,k2,tn,tv,dtype",
    [(40, 32, 300, 16, 16, 16, 128, jnp.float32),
     (256, 64, 1000, 32, 32, 128, 256, jnp.float32),
     (100, 48, 517, 8, 24, 32, 100, jnp.float32),
     (64, 32, 301, 16, 16, 32, 64, jnp.bfloat16),
     (17, 16, 64, 8, 8, 8, 32, jnp.float32)])
def test_grad_sketch_matches_oracle(N, d, V, k1, k2, tn, tv, dtype):
    from repro.kernels.grad_sketch.kernel import grad_sketch
    from repro.kernels.grad_sketch.ref import grad_sketch_ref
    h = _arr((N, d), dtype)
    w = _arr((d, V), dtype, 0.1)
    rh, rv = _arr((d, k1)), _arr((V, k2))
    t = jnp.asarray(RNG.integers(0, V, N), jnp.int32)
    s = jnp.asarray(RNG.uniform(0.5, 1.0, N), jnp.float32)
    want = grad_sketch_ref(h, w, rh, rv, t, s)
    got = grad_sketch(h, w, rh, rv, t, s, tn=tn, tv=tv, interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    rel = float(jnp.abs(got - want).max() / (jnp.abs(want).max() + 1e-9))
    assert rel < tol, rel


def test_grad_sketch_op_jnp_path_matches():
    from repro.kernels.grad_sketch.ops import grad_sketch_op
    from repro.kernels.grad_sketch.ref import grad_sketch_ref
    h, w = _arr((50, 24)), _arr((24, 400), scale=0.1)
    rh, rv = _arr((24, 12)), _arr((400, 12))
    t = jnp.asarray(RNG.integers(0, 400, 50), jnp.int32)
    s = jnp.ones((50,), jnp.float32)
    want = grad_sketch_ref(h, w, rh, rv, t, s)
    got = grad_sketch_op(h, w, rh, rv, t, s, use_pallas=False, vocab_chunk=128)
    assert jnp.allclose(got, want, atol=1e-4)


# ---------------------------------------------------------------------------
# omp_gram
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,D,ti,td,dtype",
                         [(40, 100, 16, 64, jnp.float32),
                          (130, 257, 64, 64, jnp.float32),
                          (64, 128, 32, 128, jnp.bfloat16),
                          (7, 9, 8, 8, jnp.float32)])
def test_omp_gram_matches_oracle(n, D, ti, td, dtype):
    from repro.kernels.omp_gram.kernel import omp_gram
    from repro.kernels.omp_gram.ref import omp_gram_ref
    g = _arr((n, D), dtype)
    got = omp_gram(g, ti=ti, tj=ti, td=td, interpret=True)
    want = omp_gram_ref(g)
    tol = 1e-3 if dtype == jnp.float32 else 1e-1
    assert jnp.allclose(got, want, atol=tol), float(jnp.abs(got - want).max())


# ---------------------------------------------------------------------------
# swa_attn
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,H,S,hd,W,tq,dtype",
                         [(2, 3, 128, 32, 32, 16, jnp.float32),
                          (1, 2, 256, 64, 64, 32, jnp.float32),
                          (2, 2, 64, 16, 16, 16, jnp.float32),
                          (1, 2, 128, 32, 64, 32, jnp.bfloat16),
                          (1, 1, 96, 16, 32, 32, jnp.float32)])
def test_swa_attn_matches_oracle(B, H, S, hd, W, tq, dtype):
    from repro.kernels.swa_attn.kernel import swa_attn
    from repro.kernels.swa_attn.ref import swa_attn_ref
    q, k, v = (_arr((B, H, S, hd), dtype) for _ in range(3))
    got = swa_attn(q, k, v, window=W, tq=tq, interpret=True)
    want = swa_attn_ref(q, k, v, window=W)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    assert jnp.allclose(got.astype(jnp.float32), want.astype(jnp.float32),
                        atol=tol)


# ---------------------------------------------------------------------------
# rwkv6 chunked WKV
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,N,C",
                         [(2, 64, 2, 16, 16), (1, 128, 3, 32, 32),
                          (2, 96, 1, 8, 32), (1, 64, 2, 64, 64)])
def test_rwkv6_wkv_matches_sequential(B, S, H, N, C):
    from repro.kernels.rwkv6_scan.kernel import rwkv6_wkv
    from repro.kernels.rwkv6_scan.ref import rwkv6_wkv_ref
    r, k, v = (_arr((B, S, H, N)) for _ in range(3))
    w = jnp.asarray(RNG.uniform(0.4, 0.99, (B, S, H, N)), jnp.float32)
    u = _arr((H, N), scale=0.1)
    y_got, s_got = rwkv6_wkv(r, k, v, w, u, chunk=C, interpret=True)
    y_want, s_want = rwkv6_wkv_ref(r, k, v, w, u)
    assert jnp.allclose(y_got, y_want, atol=1e-3)
    assert jnp.allclose(s_got, s_want, atol=1e-3)


def test_rwkv6_extreme_decays_stable():
    """Near-zero decays (log w very negative) must not overflow/NaN."""
    from repro.kernels.rwkv6_scan.kernel import rwkv6_wkv
    B, S, H, N = 1, 64, 1, 8
    r, k, v = (_arr((B, S, H, N)) for _ in range(3))
    w = jnp.full((B, S, H, N), 1e-6)
    y, s = rwkv6_wkv(r, k, v, w, _arr((H, N)), chunk=16, interpret=True)
    assert jnp.isfinite(y).all() and jnp.isfinite(s).all()
