"""Selection-round kernel parity + backend plumbing (DESIGN.md §9).

The fused Pallas grad-sketch / Gram kernels are validated in interpret
mode against the XLA streamed paths end to end: a full
``ResidentSelector`` round with ``kernel_impl="pallas"`` must pick the
*identical* subset as ``kernel_impl="xla"`` (scores to fp32 tolerance,
indices bit-equal), on the LM and RNN-T smoke configs and under a
4-device ``pgm_select_sharded`` round.  Also covered: the incremental-
Cholesky OMP refit vs the dense oracle, the shared ``auto_vocab_chunk``
resolver, the engine's ``loss_vocab_chunk`` auto-tune, and the
once-per-build backend log.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import PGMConfig
from repro.core.chunking import LANE, VMEM_BUDGET_BYTES, auto_vocab_chunk
from repro.core.gm import gram, gram_omp
from repro.core.lastlayer import make_proj_for
from repro.core.pgm import ResidentSelector, partitioned_gm
from repro.models.api import build_model

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _stacked_units(m, n_units, B=2, S=16, seed0=0):
    return jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[m.make_batch(jax.random.PRNGKey(seed0 + i), B, S)
          for i in range(n_units)])


def _round_parity(arch, n_units=8):
    """Full selection round, Pallas (interpret) vs XLA: stage-A scores
    rtol 1e-4, selected indices identical, weights atol 1e-4."""
    cfg = get_config(arch)
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    units = _stacked_units(m, n_units)
    proj = make_proj_for(m, jax.random.PRNGKey(0), 16, 16)
    pc = PGMConfig(subset_fraction=0.5, n_partitions=2,
                   sketch_dim_h=16, sketch_dim_v=16)
    out = {}
    for impl in ("xla", "pallas"):
        sel_obj = ResidentSelector(
            m, dataclasses.replace(pc, kernel_impl=impl), proj)
        out[impl] = (sel_obj.stage_a(params, units),
                     sel_obj(params, units))
    g_x, sel_x = out["xla"]
    g_p, sel_p = out["pallas"]
    scale = max(float(jnp.abs(g_x).max()), 1e-6)
    assert np.allclose(np.asarray(g_p), np.asarray(g_x),
                       atol=1e-4 * scale), \
        float(jnp.abs(g_p - g_x).max() / scale)
    assert np.asarray(sel_p.indices).tolist() == \
        np.asarray(sel_x.indices).tolist()
    assert np.allclose(np.asarray(sel_p.weights),
                       np.asarray(sel_x.weights), atol=1e-4)


def test_lm_round_pallas_matches_xla():
    _round_parity("starcoder2-3b-smoke")


def test_rnnt_round_pallas_matches_xla():
    # stage A rides the fused loss's dw_out factors on both backends;
    # what the pallas variant changes for RNN-T is the stage-B Gram build
    _round_parity("rnnt-crdnn-smoke", n_units=4)


@pytest.mark.slow
def test_sharded_round_pallas_matches_xla():
    """One 4-device ``pgm_select_sharded`` round with the Gram kernel
    forced on (interpret under shard_map) vs the XLA reference."""
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.configs.base import PGMConfig
        from repro.core.pgm import pgm_select_sharded
        mesh = Mesh(np.array(jax.devices()).reshape(4), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (64, 96), jnp.float32)
        sels = {}
        for impl in ("xla", "pallas"):
            pc = PGMConfig(subset_fraction=0.5, n_partitions=4,
                           kernel_impl=impl)
            sels[impl] = pgm_select_sharded(mesh, "data", g, pc)
        a, b = sels["xla"], sels["pallas"]
        assert np.asarray(a.indices).tolist() == \\
            np.asarray(b.indices).tolist()
        assert np.allclose(np.asarray(a.weights), np.asarray(b.weights),
                           atol=1e-4)
        assert int(a.n_selected) > 0
        print("SHARDED_KERNEL_PARITY_OK", int(a.n_selected))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert p.returncode == 0, p.stderr[-3000:]
    assert "SHARDED_KERNEL_PARITY_OK" in p.stdout


# ---------------------------------------------------------------------------
# Stage B: incremental Cholesky vs dense oracle
# ---------------------------------------------------------------------------

def test_gram_omp_chol_matches_dense_solver():
    rng = np.random.default_rng(7)
    g = jnp.asarray(rng.standard_normal((64, 48)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((48,)), jnp.float32)
    K, c, tsq = gram(g), g @ t, t @ t
    # budgets stay within rank(K)=48: beyond it the λ-ridge system is
    # fp32-singular and the two solvers legitimately diverge
    for budget in (1, 5, 17, 40):
        for nonneg in (True, False):
            for lam in (0.5, 1e-4):
                a = gram_omp(K, c, tsq, budget, lam, 1e-10, nonneg,
                             solver="chol")
                b = gram_omp(K, c, tsq, budget, lam, 1e-10, nonneg,
                             solver="dense")
                assert a.indices.tolist() == b.indices.tolist(), \
                    (budget, nonneg, lam)
                assert np.allclose(np.asarray(a.weights),
                                   np.asarray(b.weights), atol=1e-3)
                assert float(abs(a.error - b.error)) < 1e-3


def test_partitioned_gm_solver_parity_and_unknown_solver():
    g = jax.random.normal(jax.random.PRNGKey(3), (32, 24), jnp.float32)
    a = partitioned_gm(g, 4, 4, solver="chol")
    b = partitioned_gm(g, 4, 4, solver="dense")
    assert np.asarray(a.indices).tolist() == np.asarray(b.indices).tolist()
    assert np.allclose(np.asarray(a.weights), np.asarray(b.weights),
                       atol=1e-4)
    with pytest.raises(ValueError, match="solver"):
        gram_omp(gram(g), g @ g[0], g[0] @ g[0], 4, solver="lu")


# ---------------------------------------------------------------------------
# Backend resolution + config plumbing
# ---------------------------------------------------------------------------

def test_backend_resolution_off_tpu():
    from repro.kernels.backend import pallas_flags, resolve_kernel_impl
    assert resolve_kernel_impl("auto") in ("pallas", "xla")
    if jax.default_backend() != "tpu":
        assert resolve_kernel_impl("auto") == "xla"
        assert pallas_flags("pallas") == (True, True)   # interpret mode
        assert pallas_flags("xla") == (False, True)
    with pytest.raises(ValueError, match="kernel_impl"):
        resolve_kernel_impl("cuda")


def test_resident_selector_logs_resolved_backend():
    cfg = get_config("starcoder2-3b-smoke")
    m = build_model(cfg)
    proj = make_proj_for(m, jax.random.PRNGKey(0), 16, 16)
    lines = []
    pc = PGMConfig(sketch_dim_h=16, sketch_dim_v=16, kernel_impl="auto")
    sel = ResidentSelector(m, pc, proj, log_fn=lines.append)
    assert len(lines) == 1 and "requested=auto" in lines[0]
    assert f"resolved={sel.kernel_impl}" in lines[0]
    if jax.default_backend() != "tpu":
        assert sel.kernel_impl == "xla"


def test_train_cli_exposes_selection_kernels_flag():
    from repro.launch.train import main  # noqa: F401 — import side checks
    import repro.launch.train as lt
    src = open(lt.__file__).read()
    assert "--selection-kernels" in src and "kernel_impl" in src


# ---------------------------------------------------------------------------
# auto_vocab_chunk resolver + engine loss_vocab_chunk auto-tune
# ---------------------------------------------------------------------------

def test_auto_vocab_chunk_properties():
    # full slab fits -> whole vocab (smoke shapes keep exact numerics)
    assert auto_vocab_chunk(64, 277) == 277
    # over budget -> lane-aligned, within budget, floored at one lane
    rows, V = 4096, 262144
    chunk = auto_vocab_chunk(rows, V)
    assert chunk % LANE == 0
    assert rows * chunk * 4 <= VMEM_BUDGET_BYTES
    assert auto_vocab_chunk(10**9, V) == LANE          # floor
    assert auto_vocab_chunk(1, V) == V                  # tiny rows: fits
    # never wider than the vocab
    assert auto_vocab_chunk(4096, 200) == 200


def test_engine_autotunes_rnnt_loss_vocab_chunk():
    from repro.train.engine import autotune_loss_vocab_chunk
    cfg = get_config("rnnt-crdnn-smoke")
    m = build_model(cfg)
    units = _stacked_units(m, 4)
    # smoke vocab: auto resolves to the full vocab, bundle untouched
    b2, tuned = autotune_loss_vocab_chunk(m, units, batch_units=2)
    assert b2 is m and tuned == cfg.rnnt.vocab_size
    # explicit width always respected
    cfg_fixed = dataclasses.replace(
        cfg, rnnt=dataclasses.replace(cfg.rnnt, loss_vocab_chunk=16))
    m_fixed = build_model(cfg_fixed)
    b3, tuned3 = autotune_loss_vocab_chunk(m_fixed, units, batch_units=2)
    assert b3 is m_fixed and tuned3 == 16
    # big vocab: rebuilt on a lane-aligned chunk below the vocab
    cfg_big = dataclasses.replace(
        cfg, rnnt=dataclasses.replace(cfg.rnnt, vocab_size=65536))
    m_big = build_model(cfg_big)
    units_big = _stacked_units(m_big, 4)
    b4, tuned4 = autotune_loss_vocab_chunk(m_big, units_big, batch_units=2)
    assert 0 < tuned4 < 65536 and tuned4 % LANE == 0
    assert b4.cfg.rnnt.loss_vocab_chunk == tuned4
    # LM families: no-op
    lm = build_model(get_config("starcoder2-3b-smoke"))
    b5, tuned5 = autotune_loss_vocab_chunk(lm, units, batch_units=2)
    assert b5 is lm and tuned5 is None
