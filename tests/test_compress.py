"""Compressor math for the pod-axis gradient collectives
(``train/compress.py``), previously untested:

* ``topk_compress`` selects *exactly* k entries per leaf — regression
  for the tie over-selection and the zero-threshold case (a mostly-zero
  leaf whose k-th largest |g| is 0 used to select the entire tensor,
  silently degrading the collective back to dense);
* the error-feedback invariant ``sent + new_err == g + old_err`` holds
  bit-for-bit, and residual accumulation telescopes over steps;
* ``mode="none"`` is a plain fp32 pmean;
* the bf16 collective reduces at bf16 width in the *lowered* HLO (the
  cast must precede the pmean; XLA:CPU float-normalization promotes the
  compiled reduce to f32, so the wire-width claim is asserted on the
  pre-optimization module through
  ``repro.analysis.contracts.assert_collective_width``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.contracts import assert_collective_width
from repro.compat import shard_map
from repro.train.compress import (bf16_compress, compressed_psum,
                                  init_error_state, topk_compress)


def _tree(rng):
    return {"w": jnp.asarray(rng.normal(size=(16, 8)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(24,)), jnp.float32)}


# ---------------------------------------------------------------------------
# top-k selection size
# ---------------------------------------------------------------------------

def test_topk_never_selects_more_than_k_on_zero_threshold():
    """Mostly-zero leaf (sparse/embedding-style): the k-th largest |g| is
    0, and the old `abs >= thresh` mask selected the whole tensor."""
    g = {"emb": jnp.zeros((100,), jnp.float32).at[jnp.asarray([3, 50, 97])]
         .set(jnp.asarray([1.0, -2.0, 0.5]))}
    err = init_error_state(g)
    sent, new_err = topk_compress(g, err, k_frac=0.1)     # k = 10
    nz = int((sent["emb"] != 0).sum())
    assert nz <= 10, f"transmitted {nz} > k=10 entries"
    # the real (nonzero) entries must all be selected
    assert float(sent["emb"][3]) == 1.0
    assert float(sent["emb"][50]) == -2.0
    assert float(sent["emb"][97]) == 0.5


def test_topk_exact_k_on_ties():
    """All-equal magnitudes: a threshold mask keeps every entry; the
    index-scatter form keeps exactly k."""
    g = {"w": jnp.ones((20,), jnp.float32)}
    sent, _ = topk_compress(g, init_error_state(g), k_frac=0.25)  # k = 5
    assert int((sent["w"] != 0).sum()) == 5


def test_topk_k_floor_is_one():
    g = {"w": jnp.asarray([0.5, -3.0], jnp.float32)}
    sent, _ = topk_compress(g, init_error_state(g), k_frac=0.0)
    assert int((sent["w"] != 0).sum()) == 1
    assert float(sent["w"][1]) == -3.0


# ---------------------------------------------------------------------------
# error feedback
# ---------------------------------------------------------------------------

def test_error_feedback_invariant_bitwise():
    """sent + new_err == g + old_err, exactly (same fp additions on both
    sides: the residual is flat - sent with sent a masked copy)."""
    rng = np.random.default_rng(0)
    g = _tree(rng)
    err = jax.tree.map(
        lambda l: jnp.asarray(rng.normal(size=l.shape) * 0.1, jnp.float32),
        g)
    sent, new_err = topk_compress(g, err, k_frac=0.2)
    for k in g:
        lhs = np.asarray(sent[k] + new_err[k])
        rhs = np.asarray(g[k] + err[k])
        assert np.array_equal(lhs, rhs), k


def test_error_feedback_residual_telescopes_over_steps():
    """Over T steps, cumulative transmitted mass equals cumulative
    gradient mass minus the final residual, exactly per step — nothing
    is ever dropped, only delayed."""
    rng = np.random.default_rng(1)
    err = {"w": jnp.zeros((32,), jnp.float32)}
    sent_sum = np.zeros((32,), np.float64)
    g_sum = np.zeros((32,), np.float64)
    for t in range(6):
        g = {"w": jnp.asarray(rng.normal(size=(32,)), jnp.float32)}
        sent, err = topk_compress(g, err, k_frac=0.1)
        sent_sum += np.asarray(sent["w"], np.float64)
        g_sum += np.asarray(g["w"], np.float64)
    assert np.allclose(sent_sum + np.asarray(err["w"], np.float64), g_sum,
                       atol=1e-5)
    # the residual is actually doing work: some mass is still pending
    assert float(np.abs(np.asarray(err["w"])).max()) > 0.0


def test_init_error_state_pod_leading_dim():
    p = {"w": jnp.zeros((4, 3)), "b": jnp.zeros((5,))}
    e = init_error_state(p, n_pods=2)
    assert e["w"].shape == (2, 4, 3) and e["b"].shape == (2, 5)
    assert all(l.dtype == jnp.float32 for l in jax.tree.leaves(e))
    e1 = init_error_state(p)
    assert e1["w"].shape == (4, 3)


# ---------------------------------------------------------------------------
# compressed_psum modes (axis bound by vmap, as the engine does)
# ---------------------------------------------------------------------------

def _vmapped_psum(g_stacked, mode, err=None, k_frac=0.05):
    def per_pod(g, e):
        red, e_new = compressed_psum(g, "pod", mode, err=e, k_frac=k_frac)
        return red, e_new
    return jax.vmap(per_pod, in_axes=(0, 0), out_axes=(None, 0),
                    axis_name="pod")(g_stacked, err)


def test_mode_none_is_plain_fp32_pmean():
    rng = np.random.default_rng(2)
    g = {"w": jnp.asarray(rng.normal(size=(4, 8, 3)), jnp.bfloat16)}
    red, err = _vmapped_psum(g, "none")
    assert err is None
    assert red["w"].dtype == jnp.float32
    want = np.asarray(g["w"].astype(jnp.float32)).mean(0)
    assert np.allclose(np.asarray(red["w"]), want, atol=1e-6)


def test_mode_bf16_reduces_bf16_values():
    rng = np.random.default_rng(3)
    g = {"w": jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)}
    red, _ = _vmapped_psum(g, "bf16")
    assert red["w"].dtype == jnp.float32
    # mean of bf16-rounded values, computed at bf16 precision
    want = np.asarray(g["w"].astype(jnp.bfloat16)).mean(0)
    assert np.allclose(np.asarray(red["w"]), want, atol=0.05)


def test_mode_topk_mean_of_sent():
    g = {"w": jnp.asarray([[4.0, 0.1, 0.0, 0.2],
                           [0.3, -8.0, 0.1, 0.0]], jnp.float32)}
    err = {"w": jnp.zeros((2, 4), jnp.float32)}
    red, new_err = _vmapped_psum(g, "topk", err=err, k_frac=0.25)  # k=1
    # each pod sends only its single largest entry; the mean keeps zeros
    # elsewhere
    assert np.allclose(np.asarray(red["w"]), [2.0, -4.0, 0.0, 0.0])
    assert new_err["w"].shape == (2, 4)


def test_unknown_mode_raises():
    with pytest.raises(ValueError):
        compressed_psum({"w": jnp.zeros((2,))}, "pod", mode="int4")


# ---------------------------------------------------------------------------
# reduce dtype in the lowered HLO
# ---------------------------------------------------------------------------

def _lowered_compressed_psum(mode):
    """Lowered module of a shard_map'd compressed_psum (1-device 'pod'
    mesh: lowering — unlike compilation — still emits the collective)."""
    mesh = jax.make_mesh((1,), ("pod",))

    def f(g):
        red, _ = compressed_psum(g, "pod", mode=mode)
        return red

    sm = shard_map(f, mesh=mesh, in_specs=({"w": P("pod")},),
                   out_specs={"w": P("pod")})
    return jax.jit(sm).lower({"w": jnp.ones((8, 4), jnp.float32)})


def test_bf16_collective_reduces_at_bf16_width_in_lowered_hlo():
    assert_collective_width(_lowered_compressed_psum("bf16"), dtype="bf16")


def test_none_collective_reduces_at_f32_width_in_lowered_hlo():
    assert_collective_width(_lowered_compressed_psum("none"), dtype="f32")


def test_bf16_compress_casts_only():
    g = {"w": jnp.asarray([1.0, 2.5], jnp.float32)}
    c = bf16_compress(g)
    assert c["w"].dtype == jnp.bfloat16
