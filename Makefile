# Developer entry points.  PYTHONPATH=src is applied here so the targets
# work from a clean checkout.

PY := python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test-fast test-all test-archs test-chaos check-static bench \
	bench-sharded bench-rnnt bench-compress bench-serve bench-archs \
	bench-selection docs-check

# fast tier: static contracts + everything not marked slow (~3-4 min) —
# the development loop
test-fast: check-static
	$(PY) -m pytest -q -m "not slow"

# level-1 static contracts (repro.analysis): AST lints over the repo's
# implicit invariants — host syncs, key reuse, dtype drift, collective
# cast order, Pallas hygiene, bench/docs drift, noqa hygiene.  Exits
# non-zero on any finding; `--json` for machine output, `--list` for
# the rule catalog (DESIGN.md §11)
check-static:
	$(PY) -m repro.analysis --root .

# tier-1 verify: the full suite, fail-fast (what the CI gate runs).
# The forced host-device count makes the in-process mesh paths (and the
# sharded-epoch parity tests, which also force it in their own
# subprocesses) exercised under multiple devices.  The chaos suite
# (tests/test_chaos.py) is part of this tier — test-chaos below is the
# targeted selector for iterating on fault-recovery work.
test-all:
	XLA_FLAGS="--xla_force_host_platform_device_count=4" \
	    $(PY) -m pytest -x -q

# chaos tier: deterministic fault injection (train/faults.py) — every
# injected fault must recover with the semantics documented in
# DESIGN.md §10 (non-finite step guard, watchdog rollback, corrupt
# checkpoint fallback, preemption + resume, prefetch retries, selection
# kernel degradation)
test-chaos:
	$(PY) -m pytest -q -m chaos tests/test_chaos.py

# per-arch engine + selection matrix (smokes, host-vs-scan parity, MoE
# router-term definition, 4-device sharded smokes, resident selection
# rounds).  No XLA_FLAGS here: the in-process smokes must see the single
# real CPU device; the sharded smokes force their own device counts in
# subprocesses.
test-archs:
	$(PY) -m pytest -q -m archs tests/test_archs_smoke.py

# paper tables + kernel micro-benchmarks + train-loop / selection-round /
# sharded-epoch benchmarks (writes BENCH_*.json at the repo root)
bench:
	$(PY) -m benchmarks.run

# just the sharded/chunked epoch benchmark (4-device subprocess;
# writes BENCH_sharded_epoch.json)
bench-sharded:
	$(PY) -m benchmarks.bench_sharded_epoch

# just the RNN-T loss path benchmark: dense vs fused, fwd + grad
# steps/sec and compiled peak temp memory (writes BENCH_rnnt_loss.json)
bench-rnnt:
	$(PY) -m benchmarks.bench_rnnt_loss

# just the compressed pod-collective step benchmark: data x pod engine
# (none/bf16/topk compressed_psum) vs the GSPMD-only data x model engine
# on a 4-device subprocess (writes BENCH_compressed_step.json)
bench-compress:
	$(PY) -m benchmarks.bench_compressed_step

# just the serving benchmark: continuous batching vs one-shot generate
# at equal offered load, saturation curve, RNN-T streaming row
# (writes BENCH_serve.json)
bench-serve:
	$(PY) -m benchmarks.bench_serve

# just the per-arch scanned-epoch throughput rows, one smoke config per
# substrate family (writes BENCH_archs.json)
bench-archs:
	$(PY) -m benchmarks.bench_archs

# just the selection-round benchmark (host/resident/kernel-on/off +
# stage-B chol-vs-dense rows) and the kernels-on/off selection-round
# roofline from compiled HLO (DESIGN.md §9)
bench-selection:
	$(PY) -m benchmarks.bench_selection_round
	$(PY) -c "from repro.launch.roofline import selection_table; \
	    print(selection_table())"

# docs integrity: no dangling file refs / make targets / DESIGN.md § cites
docs-check:
	$(PY) -m pytest -q tests/test_docs.py
