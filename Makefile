# Developer entry points.  PYTHONPATH=src is applied here so the targets
# work from a clean checkout.

PY := python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test-fast test-all bench docs-check

# fast tier: everything not marked slow (< ~2 min) — the development loop
test-fast:
	$(PY) -m pytest -q -m "not slow"

# tier-1 verify: the full suite, fail-fast (what the CI gate runs)
test-all:
	$(PY) -m pytest -x -q

# paper tables + kernel micro-benchmarks + train-loop / selection-round
# benchmarks (writes BENCH_*.json at the repo root)
bench:
	$(PY) -m benchmarks.run

# docs integrity: no dangling file refs / make targets / DESIGN.md § cites
docs-check:
	$(PY) -m pytest -q tests/test_docs.py
