"""Compressed pod-collective step benchmark: steady-state epoch
throughput of the two-level ``data x pod`` engine (explicit
``compressed_psum`` on the pod axis inside the scan — modes ``none`` /
``bf16`` / ``topk``) against the GSPMD-only ``data x model`` engine on a
simulated 4-device host mesh.

The measurement runs in a subprocess because the 4 host devices must be
forced via ``XLA_FLAGS`` before jax initializes; the parent parses one
JSON line and writes ``BENCH_compressed_step.json`` at the repo root.

Methodology (DESIGN.md §7): variants interleave round by round so they
sample the same container state, warmup rounds pay compile + allocator
effects, per-variant headlines are best-of over rounds, and speedups are
medians of per-round ratios.  On one CPU socket the pod collective is a
memory shuffle, not a DCN wire, so the mode-over-GSPMD ratios track the
*overhead* of the restructured step (per-pod vmap + explicit collective
+ top-k selection), not real cross-pod bandwidth wins — the wire-width
claim itself is a compiler fact asserted by
``tests/test_compressed_engine.py`` on the lowered HLO.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List

_CHILD = """
import dataclasses, json, time
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import PGMConfig, TrainConfig
from repro.data.pipeline import lm_units
from repro.data.synthetic import make_lm_corpus
from repro.models.api import build_model
from repro.train.engine import EpochEngine
from repro.train.optim import make_update_for

N_EX, SEQ, UNIT, BATCH_UNITS = 64, 8, 1, 4
ROUNDS, WARMUP = 4, 2

cfg = get_config("starcoder2-3b-smoke")
bundle = build_model(cfg)
units = lm_units(make_lm_corpus(0, N_EX, SEQ, cfg.vocab_size,
                                hard_fraction=0.4), unit_size=UNIT)
base = TrainConfig(lr=0.5, optimizer="sgd", epochs=1, pgm=PGMConfig())
gspmd_mesh = jax.make_mesh((2, 2), ("data", "model"))
pod_mesh = jax.make_mesh((2, 2), ("data", "pod"))

variants = {
    "gspmd": (base, gspmd_mesh),
    "pod_none": (dataclasses.replace(base, compress_mode="none"), pod_mesh),
    "pod_bf16": (dataclasses.replace(base, compress_mode="bf16"), pod_mesh),
    "pod_topk": (dataclasses.replace(base, compress_mode="topk",
                                     compress_k_frac=0.05), pod_mesh),
}
engines, state = {}, {}
for name, (tc, mesh) in variants.items():
    eng = EpochEngine(bundle, tc, units, batch_units=BATCH_UNITS, mesh=mesh)
    opt_init, _ = make_update_for(tc)
    p = bundle.init_params(jax.random.PRNGKey(0))
    o = opt_init(p)
    engines[name] = (eng, tc)
    state[name] = eng.shard_state(p, o)

def epoch(name, e):
    eng, tc = engines[name]
    p, o = state[name]
    p, o, losses = eng.run_epoch(p, o, tc.lr, eng.full_plan(e))
    jax.block_until_ready(losses)
    state[name] = (p, o)
    return int(losses.shape[0])

for r in range(WARMUP):
    for name in variants:
        epoch(name, r)

rates = {k: [] for k in variants}
for r in range(WARMUP, WARMUP + ROUNDS):
    for name in variants:
        t0 = time.time()
        steps = epoch(name, r)
        rates[name].append(steps / (time.time() - t0))

out = {name + "_steps_per_s": max(rs) for name, rs in rates.items()}
for name in ("pod_none", "pod_bf16", "pod_topk"):
    out[name + "_over_gspmd"] = float(np.median(
        [s / g for g, s in zip(rates["gspmd"], rates[name])]))
print("BENCH_JSON=" + json.dumps(out))
"""


def bench_compressed_step() -> List[Dict]:
    root = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    p = subprocess.run([sys.executable, "-c", _CHILD], capture_output=True,
                       text=True, env=env, timeout=900)
    if p.returncode != 0:
        raise RuntimeError(p.stderr[-2000:])
    line = next(l for l in p.stdout.splitlines()
                if l.startswith("BENCH_JSON="))
    rec = json.loads(line[len("BENCH_JSON="):])

    import time
    rec_out = dict(rec, time=time.time())
    out_path = os.path.join(root, "BENCH_compressed_step.json")
    with open(out_path, "w") as f:
        json.dump({k: (round(v, 3) if isinstance(v, float) else v)
                   for k, v in rec_out.items()}, f, indent=2)
    print(f"# wrote {os.path.normpath(out_path)}", file=sys.stderr)

    rows = []
    for name in ("gspmd", "pod_none", "pod_bf16", "pod_topk"):
        sps = rec[name + "_steps_per_s"]
        rows.append({"name": f"compressed_step/{name}",
                     "us_per_call": 1e6 / sps,
                     "derived": f"steps_per_s={sps:.1f}",
                     "steps_per_s": sps})
    for name in ("pod_none", "pod_bf16", "pod_topk"):
        key = name + "_over_gspmd"
        rows.append({"name": f"compressed_step/{key}", "us_per_call": 0.0,
                     "derived": f"{key}={rec[key]:.2f}x",
                     "steps_per_s": 0.0, "speedup": rec[key]})
    return rows


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    for r in bench_compressed_step():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
