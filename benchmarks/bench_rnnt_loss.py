"""RNN-T loss benchmark: dense (materialized joint + autodiff) vs fused
(custom_vjp alpha/beta lattice, vocab-streamed joint), forward and grad
step, at the largest smoke-ish shape that fits both paths on CPU.

Two kinds of numbers (DESIGN.md §7):

* wall-clock steps/sec — interleaved round-by-round, headline best-of
  per variant, speedup as the *median of per-round ratios* (shared
  containers drift ±30%);
* compiled peak temp memory from ``.memory_analysis()`` — deterministic,
  no interleaving needed.  The fused grad step must stay below one
  ``(B, T, U+1, V)`` joint tensor; the dense one cannot.

Writes ``BENCH_rnnt_loss.json`` at the repo root (like the other
BENCH_* trajectory artifacts).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

# Largest-smoke loss shape: smoke-vocab-scale head on realistic lattice
# extents.  The dense grad step peaks at ~5x the 35 MB joint tensor here;
# the fused one stays in the hundreds of KB.
B, T, U, J, V = 8, 64, 16, 64, 1000


def _setup():
    from repro.core.rnnt_loss import rnnt_loss_from_logits, rnnt_loss_fused
    rng = np.random.default_rng(0)
    ze = jnp.asarray(rng.normal(size=(B, T, J)), jnp.float32)
    zp = jnp.asarray(rng.normal(size=(B, U + 1, J)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(J, V)) * 0.3, jnp.float32)
    labels = jnp.asarray(rng.integers(1, V, (B, U)), jnp.int32)
    t_lens = jnp.full((B,), T, jnp.int32)
    u_lens = jnp.full((B,), U, jnp.int32)

    def dense(ze, zp, w):
        logits = jnp.tanh(ze[:, :, None, :] + zp[:, None, :, :]) @ w
        return rnnt_loss_from_logits(logits, labels, t_lens, u_lens).sum()

    def fused(ze, zp, w):
        return rnnt_loss_fused(ze, zp, w, labels, t_lens, u_lens,
                               lattice_impl="ref").sum()

    fns = {}
    for name, loss in (("dense", dense), ("fused", fused)):
        fns[name + "_fwd"] = jax.jit(loss)
        fns[name + "_grad"] = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))
    return fns, (ze, zp, w)


def _temp_bytes(fn, args) -> int:
    return int(fn.lower(*args).compile().memory_analysis()
               .temp_size_in_bytes)


def _time_one(fn, args, repeats: int) -> float:
    t0 = time.time()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return repeats / (time.time() - t0)          # calls/sec


def bench_rnnt_loss(rounds: int = 5, repeats: int = 3,
                    write_json: bool = True) -> List[Dict]:
    fns, args = _setup()
    for f in fns.values():                       # compile outside timing
        jax.block_until_ready(f(*args))

    # interleaved rounds: every variant samples each round's machine state
    rates: Dict[str, List[float]] = {k: [] for k in fns}
    for _ in range(rounds):
        for k, f in fns.items():
            rates[k].append(_time_one(f, args, repeats))

    mem = {k: _temp_bytes(fns[k], args)
           for k in ("dense_grad", "fused_grad")}
    joint_bytes = 4 * B * T * (U + 1) * V

    rows = []
    record = {"time": time.time(),
              "shape": f"B{B}xT{T}xU{U}xJ{J}xV{V}",
              "joint_tensor_bytes": joint_bytes}
    for k in fns:
        best = max(rates[k])
        rows.append({"name": f"rnnt_loss/{k}", "us_per_call": 1e6 / best,
                     "derived": f"steps_per_s={best:.1f}",
                     "steps_per_s": best})
        record[k + "_steps_per_s"] = round(best, 2)
    for kind in ("fwd", "grad"):
        sp = float(np.median([f / d for d, f in
                              zip(rates[f"dense_{kind}"],
                                  rates[f"fused_{kind}"])]))
        rows.append({"name": f"rnnt_loss/{kind}_speedup",
                     "us_per_call": 0.0,
                     "derived": f"fused_over_dense={sp:.2f}x",
                     "steps_per_s": 0.0, "speedup": sp})
        record[f"fused_over_dense_{kind}_speedup"] = round(sp, 3)
    for k, v in mem.items():
        rows.append({"name": f"rnnt_loss/{k}_temp_mem",
                     "us_per_call": 0.0,
                     "derived": f"temp_bytes={v}"
                                f" ({v / joint_bytes:.2f}x joint)",
                     "steps_per_s": 0.0})
        record[k + "_temp_bytes"] = v

    if write_json:
        out = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_rnnt_loss.json")
        with open(out, "w") as f:
            json.dump(record, f, indent=2)
    return rows


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    for r in bench_rnnt_loss():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
