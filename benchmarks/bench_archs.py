"""Per-architecture engine throughput: steady-state scanned-epoch SGD
steps/sec of ``EpochEngine`` on one smoke config per substrate family —
the dense-LM baseline plus both MoE archs and both recurrent substrates
the selection matrix covers (DESIGN.md §8).  One row per arch; writes
``BENCH_archs.json`` at the repo root so stacked PRs can track how each
family's epoch hot path moves.

Methodology (DESIGN.md §7): warmup epochs pay compile, the per-arch
headline is best-of over timed epochs (container CPU drifts on the
benchmark timescale; there is no cross-engine ratio here, so best-of
per cell is the whole story).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Sequence

import jax
import numpy as np

ARCHS: Sequence[str] = ("starcoder2-3b", "mixtral-8x7b", "olmoe-1b-7b",
                        "rwkv6-3b", "recurrentgemma-9b")


def bench_archs(archs: Sequence[str] = ARCHS, n_examples: int = 64,
                seq: int = 8, unit_size: int = 2, epochs: int = 3,
                warmup_epochs: int = 2) -> List[Dict]:
    from repro.configs import get_config
    from repro.configs.base import PGMConfig, TrainConfig
    from repro.data.pipeline import lm_units
    from repro.data.synthetic import make_lm_corpus
    from repro.models.api import build_model
    from repro.train.engine import EpochEngine
    from repro.train.optim import make_update_for

    scale = os.environ.get("REPRO_BENCH_SCALE", "")
    if scale == "micro":
        n_examples, epochs = max(n_examples // 4, 8), 2

    rows: List[Dict] = []
    record: Dict = {"time": time.time()}
    for arch in archs:
        cfg = get_config(arch + "-smoke")
        bundle = build_model(cfg)
        units = lm_units(make_lm_corpus(0, n_examples, seq, cfg.vocab_size,
                                        hard_fraction=0.4),
                         unit_size=unit_size)
        tc = TrainConfig(lr=0.1, optimizer="sgd", epochs=1, pgm=PGMConfig())
        eng = EpochEngine(bundle, tc, units, batch_units=2)
        opt_init, _ = make_update_for(tc)
        params = bundle.init_params(jax.random.PRNGKey(0))
        opt = opt_init(params)

        def epoch(params, opt, e):
            params, opt, losses = eng.run_epoch(params, opt, tc.lr,
                                                eng.full_plan(e))
            jax.block_until_ready(losses)
            return params, opt, int(losses.shape[0])

        for e in range(warmup_epochs):
            params, opt, _ = epoch(params, opt, e)
        rates = []
        for e in range(warmup_epochs, warmup_epochs + epochs):
            t0 = time.time()
            params, opt, steps = epoch(params, opt, e)
            rates.append(steps / (time.time() - t0))
        sps = float(np.max(rates))
        rows.append({"name": f"archs/{arch}", "us_per_call": 1e6 / sps,
                     "derived": f"steps_per_s={sps:.1f}",
                     "steps_per_s": sps})
        record[f"{arch}_steps_per_s"] = round(sps, 2)

    out = os.path.join(os.path.dirname(__file__), "..", "BENCH_archs.json")
    with open(out, "w") as f:
        json.dump(record, f, indent=2)
    return rows


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    for r in bench_archs():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
