"""Sharded scanned-epoch benchmark: steady-state epoch throughput of the
single-device scan engine vs the mesh-native engine on a simulated
4-device host mesh (2x2 data x model), plus the dispatch overhead saved
by multi-epoch chunking (``run_epochs`` over 4 epochs vs 4 per-epoch
dispatches).

The measurement runs in a subprocess because the 4 host devices must be
forced via ``XLA_FLAGS`` before jax initializes; the parent parses one
JSON line and writes ``BENCH_sharded_epoch.json`` at the repo root.

Methodology (DESIGN.md §7): variants are interleaved round by round so
they sample the same container state, warmup rounds pay compile +
allocator effects, per-variant headlines are best-of over rounds, and
speedups are medians of per-round ratios.  On a CPU host the "4-device
mesh" shares one socket, so sharded throughput *below* 1x is expected —
the number tracks partitioning overhead trends, not real-mesh scaling.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List

_CHILD = """
import json, time
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import PGMConfig, TrainConfig
from repro.data.pipeline import lm_units
from repro.data.synthetic import make_lm_corpus
from repro.models.api import build_model
from repro.train.engine import EpochEngine
from repro.train.optim import make_update_for

N_EX, SEQ, UNIT, BATCH_UNITS = 64, 8, 1, 4
ROUNDS, WARMUP, CHUNK = 4, 2, 4

cfg = get_config("starcoder2-3b-smoke")
bundle = build_model(cfg)
units = lm_units(make_lm_corpus(0, N_EX, SEQ, cfg.vocab_size,
                                hard_fraction=0.4), unit_size=UNIT)
tc = TrainConfig(lr=0.5, optimizer="sgd", epochs=1, pgm=PGMConfig())
opt_init, _ = make_update_for(tc)
mesh = jax.make_mesh((2, 2), ("data", "model"))

engines = {
    "scan": EpochEngine(bundle, tc, units, batch_units=BATCH_UNITS),
    "sharded": EpochEngine(bundle, tc, units, batch_units=BATCH_UNITS,
                           mesh=mesh),
}
state = {}
for name, eng in engines.items():
    p = bundle.init_params(jax.random.PRNGKey(0))
    o = opt_init(p)
    state[name] = eng.shard_state(p, o)

def epoch(name, e):
    eng = engines[name]
    p, o = state[name]
    p, o, losses = eng.run_epoch(p, o, tc.lr, eng.full_plan(e))
    jax.block_until_ready(losses)
    state[name] = (p, o)
    return int(losses.shape[0])

# chunk-dispatch benchmark state: two more single-device engines so the
# chunked and per-epoch executables both stay warm
for name in ("perepoch", "chunked"):
    eng = EpochEngine(bundle, tc, units, batch_units=BATCH_UNITS)
    p = bundle.init_params(jax.random.PRNGKey(0))
    engines[name] = eng
    state[name] = (p, opt_init(p))

def perepoch(e0):
    eng = engines["perepoch"]
    p, o = state["perepoch"]
    steps = 0
    for e in range(e0, e0 + CHUNK):
        p, o, losses = eng.run_epochs(p, o, tc.lr, float("inf"),
                                      [eng.full_plan(e)])[:3]
        steps += int(losses.shape[-1])
    jax.block_until_ready(losses)
    state["perepoch"] = (p, o)
    return steps

def chunked(e0):
    eng = engines["chunked"]
    p, o = state["chunked"]
    plans = [eng.full_plan(e) for e in range(e0, e0 + CHUNK)]
    p, o, losses = eng.run_epochs(p, o, tc.lr, float("inf"), plans)[:3]
    jax.block_until_ready(losses)
    state["chunked"] = (p, o)
    return int(np.prod(losses.shape))

for r in range(WARMUP):
    epoch("scan", r); epoch("sharded", r)
    perepoch(r * CHUNK); chunked(r * CHUNK)

rates = {k: [] for k in engines}
for r in range(WARMUP, WARMUP + ROUNDS):
    for name, fn in (("scan", lambda: epoch("scan", r)),
                     ("sharded", lambda: epoch("sharded", r)),
                     ("perepoch", lambda: perepoch(r * CHUNK)),
                     ("chunked", lambda: chunked(r * CHUNK))):
        t0 = time.time()
        steps = fn()
        rates[name].append(steps / (time.time() - t0))

out = {name + "_steps_per_s": max(rs) for name, rs in rates.items()}
out["sharded_over_scan_speedup"] = float(np.median(
    [s / h for h, s in zip(rates["scan"], rates["sharded"])]))
out["chunked_over_perepoch_speedup"] = float(np.median(
    [c / p for p, c in zip(rates["perepoch"], rates["chunked"])]))
print("BENCH_JSON=" + json.dumps(out))
"""


def bench_sharded_epoch() -> List[Dict]:
    root = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    p = subprocess.run([sys.executable, "-c", _CHILD], capture_output=True,
                       text=True, env=env, timeout=900)
    if p.returncode != 0:
        raise RuntimeError(p.stderr[-2000:])
    line = next(l for l in p.stdout.splitlines()
                if l.startswith("BENCH_JSON="))
    rec = json.loads(line[len("BENCH_JSON="):])

    import time
    rec_out = dict(rec, time=time.time())
    out_path = os.path.join(root, "BENCH_sharded_epoch.json")
    with open(out_path, "w") as f:
        json.dump({k: (round(v, 3) if isinstance(v, float) else v)
                   for k, v in rec_out.items()}, f, indent=2)
    print(f"# wrote {os.path.normpath(out_path)}", file=sys.stderr)

    rows = []
    for name in ("scan", "sharded", "perepoch", "chunked"):
        sps = rec[name + "_steps_per_s"]
        rows.append({"name": f"sharded_epoch/{name}",
                     "us_per_call": 1e6 / sps,
                     "derived": f"steps_per_s={sps:.1f}",
                     "steps_per_s": sps})
    for key, label in (("sharded_over_scan_speedup", "sharded_over_scan"),
                       ("chunked_over_perepoch_speedup",
                        "chunked_over_perepoch")):
        rows.append({"name": f"sharded_epoch/{label}", "us_per_call": 0.0,
                     "derived": f"{label}={rec[key]:.2f}x",
                     "steps_per_s": 0.0, "speedup": rec[key]})
    return rows


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    for r in bench_sharded_epoch():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
