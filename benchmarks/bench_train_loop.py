"""Train-loop engine benchmark: steady-state epoch throughput (SGD
steps/sec, validation included in the epoch wall time) of the legacy
host loop (one jit call per host-assembled batch + one eval call per
validation unit) vs the scanned epoch engine (device-resident units,
one donated jit(lax.scan) per epoch + one vmapped validation call) on
the LM-smoke config.  Compile/warmup epochs are excluded — this measures
the dispatch/transfer/per-example-eval overhead the engine removes,
which is the training hot path once selection has paid for itself.

Also measures the scanned engine with the in-scan non-finite step guard
enabled (``nonfinite_guard``, DESIGN.md §10) against the unguarded
engine: the guard adds two scalar ``isfinite`` checks (loss + the
grad norm the clip already computes) and a leafwise select per step,
all inside the jitted scan — the
``guard_on_over_off`` ratio published in BENCH_train_loop.json is the
evidence that it stays within noise of free (acceptance: <~3%
overhead)."""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np


def _setup(n_examples: int, seq: int, unit_size: int):
    from repro.configs import get_config
    from repro.configs.base import PGMConfig, TrainConfig
    from repro.data.pipeline import lm_units
    from repro.data.synthetic import make_lm_corpus
    from repro.models.api import build_model

    cfg = get_config("starcoder2-3b-smoke")
    bundle = build_model(cfg)
    corpus = make_lm_corpus(0, n_examples, seq, cfg.vocab_size,
                            hard_fraction=0.4)
    units = lm_units(corpus, unit_size=unit_size)
    val = lm_units(make_lm_corpus(7, max(n_examples // 4, 8), seq,
                                  cfg.vocab_size), unit_size=unit_size)
    tc = TrainConfig(lr=0.5, optimizer="sgd", epochs=1, pgm=PGMConfig())
    return bundle, units, val, tc


def bench_train_loop(n_examples: int = 128, seq: int = 4,
                     unit_size: int = 1, epochs: int = 5,
                     warmup_epochs: int = 2) -> List[Dict]:
    # unit_size=1 puts the loop in the dispatch-bound regime the engine
    # targets (per-example batches, like the legacy validation path); at
    # larger per-step compute XLA:CPU kernel time dominates both engines.
    # Two warmup epochs: the first scanned epoch pays compile, the second
    # still pays allocator warm-up under donation.
    from repro.data.pipeline import full_iterator
    from repro.train.engine import EpochEngine
    from repro.train.loop import make_eval, make_train_step

    bundle, units, val, tc = _setup(n_examples, seq, unit_size)
    n_units = units["tokens"].shape[0]
    key = jax.random.PRNGKey(0)

    # --- host loop (per-batch jit + per-unit validation, like the legacy
    # train_with_selection engine="host" path) ---
    from repro.train.optim import make_update_for
    opt_init, _ = make_update_for(tc)
    params = bundle.init_params(key)
    opt_state = opt_init(params)
    step_fn = make_train_step(bundle, tc)
    eval_fn = make_eval(bundle)
    units_host = {k: np.asarray(v) for k, v in units.items()}
    val_dev = {k: jnp.asarray(v) for k, v in val.items()}
    n_val = val["tokens"].shape[0]

    def host_epoch(params, opt_state, epoch):
        steps = 0
        for batch in full_iterator(units_host, tc.seed, epoch, 1):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, m = step_fn(params, opt_state, batch, tc.lr)
            steps += 1
        float(np.mean([float(eval_fn(params,
                                     {k: v[i] for k, v in val_dev.items()}))
                       for i in range(n_val)]))
        jax.block_until_ready(params)
        return params, opt_state, steps

    # --- scanned engine (guard off = the headline engine) ---
    eng = EpochEngine(bundle, tc, units, val_units=val, batch_units=1)
    s_params = bundle.init_params(key)
    s_opt = opt_init(s_params)

    # --- scanned engine with the non-finite step guard in the scan ---
    tc_g = dataclasses.replace(tc, nonfinite_guard=True)
    eng_g = EpochEngine(bundle, tc_g, units, val_units=val, batch_units=1)
    g_params = bundle.init_params(key)
    g_opt = opt_init(g_params)

    def scan_epoch_on(engine, s_params, s_opt, epoch):
        s_params, s_opt, losses = engine.run_epoch(
            s_params, s_opt, tc.lr, engine.full_plan(epoch))
        engine.validate(s_params)
        jax.block_until_ready(losses)
        return s_params, s_opt, int(losses.shape[0])

    for e in range(warmup_epochs):
        params, opt_state, _ = host_epoch(params, opt_state, e)
        s_params, s_opt, _ = scan_epoch_on(eng, s_params, s_opt, e)
        g_params, g_opt, _ = scan_epoch_on(eng_g, g_params, g_opt, e)

    # interleaved per-epoch timing + best-of: container CPU speed drifts
    # on the benchmark's timescale, so the engines must sample the same
    # noise and one slow epoch must not sink the steady-state number
    host_rates, scan_rates, guard_rates = [], [], []
    for e in range(warmup_epochs, warmup_epochs + epochs):
        t0 = time.time()
        params, opt_state, s = host_epoch(params, opt_state, e)
        host_rates.append(s / (time.time() - t0))
        t0 = time.time()
        s_params, s_opt, s2 = scan_epoch_on(eng, s_params, s_opt, e)
        scan_rates.append(s2 / (time.time() - t0))
        t0 = time.time()
        g_params, g_opt, s3 = scan_epoch_on(eng_g, g_params, g_opt, e)
        guard_rates.append(s3 / (time.time() - t0))
    host_sps = max(host_rates)
    scan_sps = max(scan_rates)
    guard_sps = max(guard_rates)
    # per-round speedups share the round's machine state; the median round
    # is the robust headline
    speedup = float(np.median([s / h for h, s in
                               zip(host_rates, scan_rates)]))
    guard_ratio = float(np.median([g / s for s, g in
                                   zip(scan_rates, guard_rates)]))
    return [
        {"name": "train_loop/host", "us_per_call": 1e6 / host_sps,
         "derived": f"steps_per_s={host_sps:.1f}",
         "steps_per_s": host_sps},
        {"name": "train_loop/scan", "us_per_call": 1e6 / scan_sps,
         "derived": f"steps_per_s={scan_sps:.1f}",
         "steps_per_s": scan_sps},
        {"name": "train_loop/speedup", "us_per_call": 0.0,
         "derived": f"scan_over_host={speedup:.2f}x",
         "steps_per_s": 0.0, "speedup": speedup},
        {"name": "train_loop/guard_off", "us_per_call": 1e6 / scan_sps,
         "derived": f"steps_per_s={scan_sps:.1f}",
         "steps_per_s": scan_sps},
        {"name": "train_loop/guard_on", "us_per_call": 1e6 / guard_sps,
         "derived": f"steps_per_s={guard_sps:.1f}",
         "steps_per_s": guard_sps},
        {"name": "train_loop/guard_overhead", "us_per_call": 0.0,
         "derived": f"guard_on_over_off={guard_ratio:.3f}x",
         "steps_per_s": 0.0, "speedup": guard_ratio,
         "speedup_key": "guard_on_over_off"},
    ]


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    for r in bench_train_loop():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
