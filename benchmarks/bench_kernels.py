"""Kernel micro-benchmarks: wall time of the jnp reference path on CPU +
correctness deltas vs the Pallas kernels in interpret mode.  (Interpret-
mode wall time is NOT a TPU estimate — the roofline tables carry the perf
analysis; this records call latency and agreement.)"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps


def bench_kernels() -> List[dict]:
    rng = np.random.default_rng(0)
    rows = []

    # grad_sketch
    from repro.kernels.grad_sketch.ops import grad_sketch_op
    from repro.kernels.grad_sketch.ref import grad_sketch_ref
    N, d, V, k = 512, 64, 2048, 32
    h = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, V)) * 0.1, jnp.float32)
    rh = jnp.asarray(rng.normal(size=(d, k)), jnp.float32)
    rv = jnp.asarray(rng.normal(size=(V, k)), jnp.float32)
    tg = jnp.asarray(rng.integers(0, V, N), jnp.int32)
    sc = jnp.ones((N,), jnp.float32)
    f_ref = jax.jit(lambda *a: grad_sketch_ref(*a))
    t = _time(f_ref, h, w, rh, rv, tg, sc)
    err = float(jnp.abs(
        grad_sketch_op(h, w, rh, rv, tg, sc, use_pallas=True, interpret=True)
        - f_ref(h, w, rh, rv, tg, sc)).max())
    rows.append({"name": "kernel/grad_sketch", "us_per_call": t * 1e6,
                 "derived": f"pallas_vs_ref_maxerr={err:.2e}"})

    # omp_gram
    from repro.kernels.omp_gram.kernel import omp_gram
    from repro.kernels.omp_gram.ref import omp_gram_ref
    g = jnp.asarray(rng.normal(size=(256, 1024)), jnp.float32)
    f_ref = jax.jit(omp_gram_ref)
    t = _time(f_ref, g)
    err = float(jnp.abs(omp_gram(g, interpret=True) - f_ref(g)).max())
    rows.append({"name": "kernel/omp_gram", "us_per_call": t * 1e6,
                 "derived": f"pallas_vs_ref_maxerr={err:.2e}"})

    # swa_attn
    from repro.kernels.swa_attn.kernel import swa_attn
    from repro.kernels.swa_attn.ref import swa_attn_ref
    q, kk, v = (jnp.asarray(rng.normal(size=(1, 4, 512, 64)), jnp.float32)
                for _ in range(3))
    f_ref = jax.jit(lambda q, k, v: swa_attn_ref(q, k, v, window=128))
    t = _time(f_ref, q, kk, v)
    err = float(jnp.abs(swa_attn(q, kk, v, window=128, tq=128,
                                 interpret=True)
                        - f_ref(q, kk, v)).max())
    rows.append({"name": "kernel/swa_attn", "us_per_call": t * 1e6,
                 "derived": f"pallas_vs_ref_maxerr={err:.2e}"})

    # rwkv6 chunked
    from repro.kernels.rwkv6_scan.kernel import rwkv6_wkv
    from repro.kernels.rwkv6_scan.ref import rwkv6_wkv_ref
    B, S, H, Nh = 1, 256, 4, 32
    r, kk2, v2 = (jnp.asarray(rng.normal(size=(B, S, H, Nh)), jnp.float32)
                  for _ in range(3))
    w2 = jnp.asarray(rng.uniform(0.5, 0.99, (B, S, H, Nh)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, Nh)) * 0.1, jnp.float32)
    f_ref = jax.jit(lambda *a: rwkv6_wkv_ref(*a)[0])
    t = _time(f_ref, r, kk2, v2, w2, u)
    err = float(jnp.abs(rwkv6_wkv(r, kk2, v2, w2, u, chunk=64,
                                  interpret=True)[0]
                        - f_ref(r, kk2, v2, w2, u)).max())
    rows.append({"name": "kernel/rwkv6_wkv", "us_per_call": t * 1e6,
                 "derived": f"pallas_vs_ref_maxerr={err:.2e}"})
    return rows
