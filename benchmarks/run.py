"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Prints ``name,us_per_call,derived`` CSV — one row per measured cell, one
section per paper table/figure (benchmarks/tables.py), plus kernel
micro-benchmarks, the train-loop engine benchmark and the
selection-round/rnnt-loss/sharded-epoch benchmarks (also written to
``BENCH_train_loop.json`` / ``BENCH_selection_round.json`` /
``BENCH_rnnt_loss.json`` / ``BENCH_sharded_epoch.json`` at the repo
root so PRs can track the trajectory) and (when dry-run artifacts
exist) the roofline table.
REPRO_BENCH_SCALE=micro|small scales corpus/epoch counts.
"""
from __future__ import annotations

import json
import os
import sys
import time


def main() -> None:
    t_start = time.time()
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from benchmarks.tables import ALL_TABLES
    from benchmarks.bench_kernels import bench_kernels
    from benchmarks.bench_train_loop import bench_train_loop

    print("name,us_per_call,derived")

    # static-contract gate duration: `make check-static` runs on every
    # `make test-fast`, so its wall time is part of the dev loop and is
    # tracked like any other cell
    try:
        from pathlib import Path

        from repro.analysis import all_rules, run_lint
        t0 = time.time()
        findings = run_lint(Path(__file__).resolve().parent.parent)
        dt = time.time() - t0
        print(f"check_static/full_repo,{dt*1e6:.1f},"
              f"findings={len(findings)};rules={len(all_rules())}")
    except Exception as e:
        print(f"check_static,0,ERROR={type(e).__name__}:{e}")

    for fn in ALL_TABLES:
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # keep the harness green; report the failure
            print(f"{fn.__name__},0,ERROR={type(e).__name__}:{e}")
            continue
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
        print(f"# {fn.__name__} done in {time.time()-t0:.1f}s",
              file=sys.stderr)
    for r in bench_kernels():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")

    # engine + selection-round benchmarks, each with a JSON trajectory
    # artifact at the repo root
    def run_json_bench(fn, out_name, value_key, value_suffix, speedup_key):
        try:
            rows = fn()
        except Exception as e:
            print(f"{fn.__name__},0,ERROR={type(e).__name__}:{e}")
            return
        record = {"time": time.time()}
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
            key = r["name"].split("/", 1)[1]
            if r[value_key]:
                record[key + value_suffix] = round(r[value_key], 2)
            if "speedup" in r:
                # rows may carry their own key (kernel-on/off and
                # chol-vs-dense deltas next to the headline speedup)
                record[r.get("speedup_key", speedup_key)] = \
                    round(r["speedup"], 3)
        out = os.path.join(os.path.dirname(__file__), "..", out_name)
        with open(out, "w") as f:
            json.dump(record, f, indent=2)
        print(f"# wrote {os.path.normpath(out)}", file=sys.stderr)

    def _bench_selection_round():
        # deferred import so a broken bench module reports as an ERROR row
        # instead of aborting the harness before the other benchmarks
        from benchmarks.bench_selection_round import bench_selection_round
        return bench_selection_round()
    _bench_selection_round.__name__ = "bench_selection_round"

    run_json_bench(bench_train_loop, "BENCH_train_loop.json",
                   "steps_per_s", "_steps_per_s", "scan_over_host_speedup")
    run_json_bench(_bench_selection_round, "BENCH_selection_round.json",
                   "round_ms", "_round_ms", "resident_over_host_speedup")

    # benchmarks that write their own BENCH_*.json (multiple speedup /
    # memory keys per record): the RNN-T loss path comparison and the
    # sharded/chunked epoch benchmark (4-device subprocess)
    def run_self_writing_bench(mod_name, fn_name):
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=[fn_name])
            for r in getattr(mod, fn_name)():
                print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
            print(f"# wrote BENCH artifact of {mod_name}", file=sys.stderr)
        except Exception as e:
            print(f"{fn_name},0,ERROR={type(e).__name__}:{e}")

    run_self_writing_bench("bench_rnnt_loss", "bench_rnnt_loss")
    run_self_writing_bench("bench_sharded_epoch", "bench_sharded_epoch")
    run_self_writing_bench("bench_compressed_step", "bench_compressed_step")
    run_self_writing_bench("bench_serve", "bench_serve")
    run_self_writing_bench("bench_archs", "bench_archs")

    # selection-round roofline (DESIGN.md §9): compile the round with
    # kernels on vs off and analyze the optimized HLO — reproducible
    # here with no artifacts needed
    try:
        from repro.launch.roofline import selection_round_records
        for rec in selection_round_records():
            t = rec["terms"]
            print(f"roofline/{rec['variant']},{t['bound_s']*1e6:.1f},"
                  f"dom={t['dominant']};flops={rec['flops']:.3e};"
                  f"hbm_bytes={rec['bytes_accessed']:.3e}")
    except Exception as e:
        print(f"selection_round_records,0,ERROR={type(e).__name__}:{e}")

    # roofline table from dry-run artifacts, if the sweep has run
    art = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                       "dryrun")
    if os.path.isdir(art) and any(f.endswith(".json")
                                  for f in os.listdir(art)):
        from repro.launch.roofline import load_artifacts
        for rec in load_artifacts(art):
            t = rec["terms"]
            print(f"roofline/{rec['arch']}@{rec['shape']}@{rec['mesh']},"
                  f"{t['bound_s']*1e6:.1f},"
                  f"dom={t['dominant']};roofline={100*t['roofline_fraction']:.1f}%;"
                  f"useful={t['useful_ratio'] and round(t['useful_ratio'],2)}")
    print(f"# total {time.time()-t_start:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
