"""Selection-round benchmark: latency of one full PGM selection round
(stage A gradient sketching + stage B partitioned OMP) via the legacy
host path (``pgm_select``: sequential per-unit ``lax.map`` dispatched
from host each round) vs the resident path (``ResidentSelector``: one
jitted batch-scanned pass over the device-resident units, executable and
projections cached across rounds) on the LM-smoke config, plus the
selection-kernel deltas of DESIGN.md §9:

* ``resident_kernels`` — the same resident round with the fused Pallas
  grad-sketch + Gram kernels forced on (``kernel_impl="pallas"``).
  Off-TPU this times the *interpreter*, so expect ``kernels_over_xla``
  well under 1x on CPU — the row exists to track the TPU path's shape
  and to keep the comparison honest, not to advertise a CPU win.
* ``stageb_chol`` / ``stageb_dense`` — stage B alone at a
  selection-scale shape (n=2048 units, budget 256/partition), comparing
  the incremental-Cholesky OMP refit (O(k^2)/iteration) against the
  dense full-resolve oracle (O(k^3)/iteration).  This delta is backend-
  independent, so it is the one kernel-layer win measurable on CPU.
  Crossover (measured on XLA:CPU): ~1.0x at budget 128 (while-loop and
  gather overheads dominate), ~1.4x at 256, ~2.2x at 512 — the win is
  asymptotic in the budget, as the complexity argument predicts.

Methodology (DESIGN.md §7): container CPU speed drifts ±30% on ~10s
timescales, so variants are interleaved per round (all sample the same
noise), the headline per-path latency is best-of over rounds, and each
headline speedup is the median of per-round ratios.  Warmup rounds pay
compile for every path — this measures the steady-state per-round cost
Algorithm 1 pays every ``select_every`` epochs.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np


def bench_selection_round(n_examples: int = 128, seq: int = 12,
                          unit_size: int = 2, rounds: int = 5,
                          warmup_rounds: int = 2) -> List[Dict]:
    import dataclasses

    from repro.configs import get_config
    from repro.configs.base import PGMConfig
    from repro.core.lastlayer import make_proj_for
    from repro.core.pgm import ResidentSelector, partitioned_gm, pgm_select
    from repro.data.pipeline import lm_units
    from repro.data.synthetic import make_lm_corpus
    from repro.models.api import build_model

    cfg = get_config("starcoder2-3b-smoke")
    bundle = build_model(cfg)
    corpus = make_lm_corpus(0, n_examples, seq, cfg.vocab_size,
                            hard_fraction=0.4)
    units = {k: jnp.asarray(v)
             for k, v in lm_units(corpus, unit_size=unit_size).items()}
    n_units = int(units["tokens"].shape[0])
    key = jax.random.PRNGKey(0)
    params = bundle.init_params(key)
    pc = PGMConfig(subset_fraction=0.3, n_partitions=4,
                   sketch_dim_h=32, sketch_dim_v=32)
    proj = make_proj_for(bundle, jax.random.fold_in(key, 17), 32, 32)
    selector = ResidentSelector(bundle, pc, proj)
    selector_k = ResidentSelector(
        bundle, dataclasses.replace(pc, kernel_impl="pallas"), proj)

    def host_round():
        sel = pgm_select(bundle, params, units, pc, proj)
        jax.block_until_ready(sel.indices)

    def resident_round():
        sel = selector(params, units)
        jax.block_until_ready(sel.indices)

    def kernels_round():
        sel = selector_k(params, units)
        jax.block_until_ready(sel.indices)

    # stage B alone at selection scale: synthetic sketches, P partitions
    # of 512 units each, budget 256 per partition (subset_fraction 0.5)
    bP, bn, bD, bbudget = 4, 2048, 512, 256
    g_b = jax.random.normal(jax.random.fold_in(key, 23), (bn, bD),
                            jnp.float32)

    def stageb(solver):
        sel = partitioned_gm(g_b, bP, bbudget, pc.lam, pc.eps, True,
                             solver=solver)
        jax.block_until_ready(sel.indices)

    variants = [("host", host_round), ("resident", resident_round),
                ("resident_kernels", kernels_round),
                ("stageb_chol", lambda: stageb("chol")),
                ("stageb_dense", lambda: stageb("dense"))]
    for _ in range(warmup_rounds):
        for _, fn in variants:
            fn()

    times: Dict[str, List[float]] = {name: [] for name, _ in variants}
    for _ in range(rounds):
        for name, fn in variants:
            t0 = time.time()
            fn()
            times[name].append(time.time() - t0)

    def ratio(num, den):
        return float(np.median([a / b
                                for a, b in zip(times[num], times[den])]))

    rows = []
    for name, _ in variants:
        best = min(times[name])
        rows.append({"name": f"selection_round/{name}",
                     "us_per_call": best * 1e6,
                     "derived": f"round_ms={best*1e3:.1f};n_units="
                                f"{bn if name.startswith('stageb') else n_units}",
                     "round_ms": best * 1e3})
    rows.append({"name": "selection_round/speedup", "us_per_call": 0.0,
                 "derived": f"resident_over_host={ratio('host', 'resident'):.2f}x",
                 "round_ms": 0.0, "speedup": ratio("host", "resident")})
    rows.append({"name": "selection_round/kernels_speedup",
                 "us_per_call": 0.0,
                 "derived": f"kernels_over_xla="
                            f"{ratio('resident', 'resident_kernels'):.3f}x",
                 "round_ms": 0.0,
                 "speedup": ratio("resident", "resident_kernels"),
                 "speedup_key": "kernels_over_xla_speedup"})
    rows.append({"name": "selection_round/stageb_speedup",
                 "us_per_call": 0.0,
                 "derived": f"chol_over_dense="
                            f"{ratio('stageb_dense', 'stageb_chol'):.2f}x",
                 "round_ms": 0.0,
                 "speedup": ratio("stageb_dense", "stageb_chol"),
                 "speedup_key": "chol_over_dense_speedup"})
    return rows


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    for r in bench_selection_round():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
