"""Selection-round benchmark: latency of one full PGM selection round
(stage A gradient sketching + stage B partitioned OMP) via the legacy
host path (``pgm_select``: sequential per-unit ``lax.map`` dispatched
from host each round) vs the resident path (``ResidentSelector``: one
jitted batch-scanned pass over the device-resident units, executable and
projections cached across rounds) on the LM-smoke config.

Methodology (DESIGN.md §7): container CPU speed drifts ±30% on ~10s
timescales, so host/resident rounds are interleaved (both sample the
same noise), the headline per-path latency is best-of over rounds, and
the headline speedup is the median of per-round ratios.  Warmup rounds
pay compile for both paths — this measures the steady-state per-round
cost Algorithm 1 pays every ``select_every`` epochs.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np


def bench_selection_round(n_examples: int = 128, seq: int = 12,
                          unit_size: int = 2, rounds: int = 5,
                          warmup_rounds: int = 2) -> List[Dict]:
    from repro.configs import get_config
    from repro.configs.base import PGMConfig
    from repro.core.lastlayer import make_proj_for
    from repro.core.pgm import ResidentSelector, pgm_select
    from repro.data.pipeline import lm_units
    from repro.data.synthetic import make_lm_corpus
    from repro.models.api import build_model

    cfg = get_config("starcoder2-3b-smoke")
    bundle = build_model(cfg)
    corpus = make_lm_corpus(0, n_examples, seq, cfg.vocab_size,
                            hard_fraction=0.4)
    units = {k: jnp.asarray(v)
             for k, v in lm_units(corpus, unit_size=unit_size).items()}
    n_units = int(units["tokens"].shape[0])
    key = jax.random.PRNGKey(0)
    params = bundle.init_params(key)
    pc = PGMConfig(subset_fraction=0.3, n_partitions=4,
                   sketch_dim_h=32, sketch_dim_v=32)
    proj = make_proj_for(bundle, jax.random.fold_in(key, 17), 32, 32)
    selector = ResidentSelector(bundle, pc, proj)

    def host_round():
        sel = pgm_select(bundle, params, units, pc, proj)
        jax.block_until_ready(sel.indices)

    def resident_round():
        sel = selector(params, units)
        jax.block_until_ready(sel.indices)

    for _ in range(warmup_rounds):
        host_round()
        resident_round()

    host_s, res_s = [], []
    for _ in range(rounds):
        t0 = time.time()
        host_round()
        host_s.append(time.time() - t0)
        t0 = time.time()
        resident_round()
        res_s.append(time.time() - t0)
    host_best = min(host_s)
    res_best = min(res_s)
    speedup = float(np.median([h / r for h, r in zip(host_s, res_s)]))
    return [
        {"name": "selection_round/host", "us_per_call": host_best * 1e6,
         "derived": f"round_ms={host_best*1e3:.1f};n_units={n_units}",
         "round_ms": host_best * 1e3},
        {"name": "selection_round/resident", "us_per_call": res_best * 1e6,
         "derived": f"round_ms={res_best*1e3:.1f};n_units={n_units}",
         "round_ms": res_best * 1e3},
        {"name": "selection_round/speedup", "us_per_call": 0.0,
         "derived": f"resident_over_host={speedup:.2f}x",
         "round_ms": 0.0, "speedup": speedup},
    ]


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    for r in bench_selection_round():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
