"""Serving benchmark: continuous batching vs repeated one-shot
``generate`` at equal offered load on the LM smoke, plus an RNN-T
streaming row on the paper's CRDNN smoke.

Workload: requests share one prompt bucket but carry heterogeneous
decode budgets (4..32 new tokens, no eos) — the regime continuous
batching exists for.  The one-shot baseline batches ``n_slots``
requests at a time and must decode every batch to its *longest* budget;
the slot engine evicts each request the step its budget is met and
refills the slot from the queue.

Methodology (DESIGN.md §7): variants run interleaved round-by-round,
the headline is best-of per variant, the speedup is the median of
per-round ratios (shared containers drift ±30%).  The saturation curve
offers Poisson-free uniform arrivals at increasing rates (fractions of
the measured closed-loop capacity) and reports sustained req/s with
p50/p99 completion latency for both engines.

Writes ``BENCH_serve.json`` at the repo root.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

ARCH = "starcoder2-3b-smoke"
RNNT_ARCH = "rnnt-crdnn-smoke"
PROMPT_LEN = 16
N_SLOTS = 4
BUDGETS = (4, 8, 16, 32)       # heterogeneous decode budgets per request


def _scale():
    s = os.environ.get("REPRO_BENCH_SCALE", "")
    if s == "micro":
        return 8, 2       # n_requests, rounds
    if s == "small":
        return 16, 3
    return 24, 3


def _lm_requests(n, vocab, arrivals=None):
    from repro.serve.engine import Request
    rng = np.random.default_rng(0)
    return [
        Request(uid=i,
                inputs={"tokens": rng.integers(
                    0, vocab, (PROMPT_LEN,)).astype(np.int32)},
                max_new_tokens=BUDGETS[i % len(BUDGETS)],
                arrival_s=0.0 if arrivals is None else arrivals[i])
        for i in range(n)
    ]


def _run_oneshot(bundle, params, requests):
    """Static-batching baseline: serve arrivals in admission-order groups
    of ``N_SLOTS``; each batch decodes to its longest budget.  Returns
    per-request completion latencies (vs arrival) and the wall time."""
    import jax.numpy as jnp
    from repro.serve.engine import generate
    pending = sorted(requests, key=lambda r: (r.arrival_s, r.uid))
    lat = []
    t0 = time.time()
    i = 0
    while i < len(pending):
        group = pending[i: i + N_SLOTS]
        i += N_SLOTS
        wait = group[0].arrival_s - (time.time() - t0)
        if wait > 0:
            time.sleep(wait)
        # the whole batch decodes max(budget) steps — the static-batching tax
        prompts = jnp.stack([jnp.asarray(r.inputs["tokens"]) for r in group])
        new = max(r.max_new_tokens for r in group)
        generate(bundle, params, prompts, new, eos_id=None)
        done = time.time() - t0
        lat.extend(done - r.arrival_s for r in group)
    return lat, time.time() - t0


def _run_cb(engine, requests):
    t0 = time.time()
    comps = engine.run(requests)
    wall = time.time() - t0
    return [c.latency_s for c in comps], wall


def _pctl(xs, q):
    return float(np.percentile(np.asarray(xs), q))


def bench_serve(write_json: bool = True) -> List[Dict]:
    import jax
    from repro.configs import get_config
    from repro.models.api import build_model
    from repro.serve.engine import Request, SlotEngine

    n_req, rounds = _scale()
    cfg = get_config(ARCH)
    bundle = build_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    engine = SlotEngine(bundle, params, n_slots=N_SLOTS,
                        max_new_tokens=max(BUDGETS),
                        max_prompt_len=PROMPT_LEN, eos_id=None,
                        sync_every=4)
    reqs = _lm_requests(n_req, cfg.vocab_size)

    # warm both variants (compile prefill/decode executables)
    _run_cb(engine, _lm_requests(N_SLOTS, cfg.vocab_size))
    _run_oneshot(bundle, params, _lm_requests(N_SLOTS, cfg.vocab_size))

    # -- head-to-head at equal offered load (everything queued at t=0) --
    cb_rps, os_rps = [], []
    for _ in range(rounds):                     # interleaved rounds (§7)
        _, wall = _run_cb(engine, reqs)
        cb_rps.append(n_req / wall)
        _, wall = _run_oneshot(bundle, params, reqs)
        os_rps.append(n_req / wall)
    speedup = float(np.median([c / o for c, o in zip(cb_rps, os_rps)]))

    rows = [
        {"name": "serve/cb_closed_loop", "us_per_call": 1e6 / max(cb_rps),
         "derived": f"req_per_s={max(cb_rps):.2f}"},
        {"name": "serve/oneshot_closed_loop",
         "us_per_call": 1e6 / max(os_rps),
         "derived": f"req_per_s={max(os_rps):.2f}"},
        {"name": "serve/cb_over_oneshot", "us_per_call": 0.0,
         "derived": f"req_per_s_ratio={speedup:.2f}x"},
    ]
    record = {
        "time": time.time(), "arch": ARCH, "n_requests": n_req,
        "n_slots": N_SLOTS, "prompt_len": PROMPT_LEN,
        "budgets": list(BUDGETS),
        "cb_req_per_s_best": round(max(cb_rps), 3),
        "oneshot_req_per_s_best": round(max(os_rps), 3),
        "cb_over_oneshot_req_per_s": round(speedup, 3),
    }

    # -- saturation curve: uniform arrivals at fractions of capacity ----
    cap = max(cb_rps)
    curve = []
    for frac in (0.5, 0.8, 1.0, 1.3):
        rate = cap * frac
        arrivals = [i / rate for i in range(n_req)]
        point = {"offered_req_per_s": round(rate, 3)}
        for tag, run in (("cb", lambda rq: _run_cb(engine, rq)),
                         ("oneshot",
                          lambda rq: _run_oneshot(bundle, params, rq))):
            lat, wall = run(_lm_requests(n_req, cfg.vocab_size, arrivals))
            point[tag] = {
                "sustained_req_per_s": round(n_req / wall, 3),
                "p50_latency_ms": round(1e3 * _pctl(lat, 50), 1),
                "p99_latency_ms": round(1e3 * _pctl(lat, 99), 1),
            }
            rows.append({
                "name": f"serve/{tag}@{frac:.1f}x", "us_per_call": 0.0,
                "derived": (f"sustained={point[tag]['sustained_req_per_s']}"
                            f"rps;p50={point[tag]['p50_latency_ms']}ms;"
                            f"p99={point[tag]['p99_latency_ms']}ms")})
        curve.append(point)
    record["saturation"] = curve

    # -- RNN-T streaming row on the paper workload ----------------------
    rcfg = get_config(RNNT_ARCH)
    rbundle = build_model(rcfg)
    rparams = rbundle.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    rreqs = [Request(uid=i,
                     inputs={"feats": rng.normal(size=(
                         int(rng.integers(24, 49)),
                         rcfg.rnnt.n_feats)).astype(np.float32)},
                     max_new_tokens=64)
             for i in range(2 * N_SLOTS)]
    rengine = SlotEngine(rbundle, rparams, n_slots=N_SLOTS,
                         max_new_tokens=64, max_prompt_len=48,
                         sync_every=4)
    _run_cb(rengine, rreqs[:N_SLOTS])           # warm
    t0 = time.time()
    comps = rengine.run(rreqs)
    wall = time.time() - t0
    syms = sum(len(c.tokens) for c in comps)
    rows.append({"name": "serve/rnnt_streaming", "us_per_call":
                 1e6 * wall / len(rreqs),
                 "derived": f"req_per_s={len(rreqs)/wall:.2f};"
                            f"sym_per_s={syms/wall:.1f}"})
    record["rnnt_req_per_s"] = round(len(rreqs) / wall, 3)
    record["rnnt_sym_per_s"] = round(syms / wall, 1)

    if write_json:
        out = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_serve.json")
        with open(out, "w") as f:
            json.dump(record, f, indent=2)
    return rows


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    for r in bench_serve():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
