"""Benchmark harness: one function per paper table/figure (DESIGN.md §7).

Librispeech is not available offline; each benchmark reproduces the paper's
*experimental design* on seeded synthetic corpora (see data/synthetic.py):
LM corpora for the decoder-LM port and ASR corpora + the CRDNN RNN-T for
the paper-faithful setting.  "WER" columns are validation losses (the
monotone proxy available without an external decoder); "speedup" follows
the paper's accounting (full-epoch-equivalent cost units incl. selection
overhead).

Scale: REPRO_BENCH_SCALE=micro (default, minutes on 1 CPU core) | small.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import PGMConfig, TrainConfig
from repro.core.baselines import gradmatch_pb
from repro.core.lastlayer import make_proj_for, units_gradients
from repro.core.metrics import (
    noise_overlap_index,
    overlap_index,
    relative_test_error,
    speedup,
)
from repro.data.pipeline import asr_units, lm_units
from repro.data.synthetic import make_asr_corpus, make_lm_corpus
from repro.models.api import build_model
from repro.train.loop import train_with_selection

SCALE = os.environ.get("REPRO_BENCH_SCALE", "micro")
N_LM = {"micro": 80, "small": 192}[SCALE]
N_ASR = {"micro": 48, "small": 128}[SCALE]
EPOCHS = {"micro": 4, "small": 8}[SCALE]
Row = Dict[str, object]


def _lm_setup(noise=0.0, seed=0):
    cfg = get_config("starcoder2-3b-smoke")
    m = build_model(cfg)
    corpus = make_lm_corpus(seed, N_LM, 16, cfg.vocab_size,
                            hard_fraction=0.4, noise_fraction=noise)
    units = lm_units(corpus, 4)
    val = lm_units(make_lm_corpus(seed + 99, 16, 16, cfg.vocab_size), 4)
    return m, units, val, corpus


def _asr_setup(noise=0.0, seed=0):
    cfg = get_config("rnnt-crdnn-smoke")
    m = build_model(cfg)
    corpus = make_asr_corpus(seed, N_ASR, n_feats=cfg.rnnt.n_feats,
                             vocab_size=cfg.rnnt.vocab_size,
                             noise_fraction=noise)
    units = asr_units(corpus, 4)
    val_c = make_asr_corpus(seed + 77, 12, n_feats=cfg.rnnt.n_feats,
                            vocab_size=cfg.rnnt.vocab_size)
    return m, units, asr_units(val_c, 4), corpus


def _tc(frac, warm=1, select_every=2, val_matching=False, lr=0.5,
        epochs=None, partitions=2):
    return TrainConfig(
        lr=lr, optimizer="sgd", epochs=epochs or EPOCHS,
        pgm=PGMConfig(subset_fraction=frac, n_partitions=partitions,
                      select_every=select_every, warm_start_epochs=warm,
                      sketch_dim_h=24, sketch_dim_v=24,
                      val_matching=val_matching))


def _train(m, units, val, tc, method):
    t0 = time.time()
    h = train_with_selection(m, units, tc, method=method, val_units=val)
    return h, time.time() - t0


# ---------------------------------------------------------------------------
# Fig 2 + Fig 3: WER / relative test error vs speedup per method x fraction
# ---------------------------------------------------------------------------

def bench_fig2_fig3() -> List[Row]:
    m, units, val, _ = _lm_setup()
    rows = []
    h_full, t_full = _train(m, units, val, _tc(1.0), "full")
    base = h_full.val_loss[-1]
    rows.append({"name": "fig2/full", "us_per_call": t_full * 1e6,
                 "derived": f"val={base:.4f};speedup=1.00"})
    for frac in (0.1, 0.3):
        for method in ("pgm", "random", "large_only", "large_small"):
            h, t = _train(m, units, val, _tc(frac), method)
            rows.append({
                "name": f"fig2/{method}@{frac}",
                "us_per_call": t * 1e6,
                "derived": (f"val={h.val_loss[-1]:.4f};"
                            f"rel_err={relative_test_error(h.val_loss[-1], base):+.1f}%;"
                            f"speedup={speedup(h_full.cost_units, h.cost_units):.2f}"),
            })
    return rows


# ---------------------------------------------------------------------------
# Table 1: gradient memory footprint (the paper's core motivation)
# ---------------------------------------------------------------------------

def bench_table1_memory() -> List[Row]:
    rows = []
    # measured on the smoke RNN-T (paper's arch): exact joint-net gradient
    m, units, _, _ = _asr_setup()
    params = m.init_params(jax.random.PRNGKey(0))
    unit0 = {k: jnp.asarray(v[0]) for k, v in units.items()}
    from repro.core.lastlayer import rnnt_unit_exact, rnnt_unit_sketch
    t0 = time.time()
    g = rnnt_unit_exact(m, params, unit0)
    t_exact = time.time() - t0
    proj = make_proj_for(m, jax.random.PRNGKey(1), 64, 64)
    t0 = time.time()
    s = rnnt_unit_sketch(m, params, unit0, proj)
    t_sketch = time.time() - t0
    n_units = units["tokens"].shape[0]
    rows.append({"name": "table1/smoke-rnnt-exact",
                 "us_per_call": t_exact * 1e6,
                 "derived": f"bytes/unit={g.nbytes};total={g.nbytes*n_units}"})
    rows.append({"name": "table1/smoke-rnnt-sketch",
                 "us_per_call": t_sketch * 1e6,
                 "derived": (f"bytes/unit={s.nbytes};total={s.nbytes*n_units};"
                             f"compression={g.nbytes/s.nbytes:.0f}x")})
    # analytic at production scale (paper Table 1 analogue)
    for arch, n in [("rnnt-crdnn", 5135), ("gemma3-27b", 100000)]:
        cfg = get_config(arch)
        if cfg.rnnt:
            gbytes = cfg.rnnt.joint_dim * cfg.rnnt.vocab_size * 4
        else:
            gbytes = cfg.d_model * cfg.vocab_size * 4
        sk = 64 * 64 * 4
        rows.append({
            "name": f"table1/{arch}-analytic", "us_per_call": 0.0,
            "derived": (f"exact_total={gbytes*n/1e9:.1f}GB;"
                        f"sketch_total={sk*n/1e9:.3f}GB;"
                        f"compression={gbytes/sk:.0f}x"),
        })
    return rows


# ---------------------------------------------------------------------------
# Table 2: the paper-faithful RNN-T setting (960H analogue)
# ---------------------------------------------------------------------------

def bench_table2_scale() -> List[Row]:
    m, units, val, _ = _asr_setup()
    rows = []
    h_full, t_full = _train(m, units, val, _tc(1.0, lr=0.05), "full")
    base = h_full.val_loss[-1]
    rows.append({"name": "table2/full", "us_per_call": t_full * 1e6,
                 "derived": f"val={base:.4f}"})
    for frac in (0.1, 0.2, 0.3):
        for method in ("random", "pgm"):
            h, t = _train(m, units, val, _tc(frac, lr=0.05), method)
            rows.append({
                "name": f"table2/{method}@{frac}",
                "us_per_call": t * 1e6,
                "derived": (f"val={h.val_loss[-1]:.4f};"
                            f"rel_err={relative_test_error(h.val_loss[-1], base):+.1f}%;"
                            f"speedup={speedup(h_full.cost_units, h.cost_units):.2f}"),
            })
    return rows


# ---------------------------------------------------------------------------
# Table 3: noisy training data, validation-gradient matching
# ---------------------------------------------------------------------------

def bench_table3_noise() -> List[Row]:
    rows = []
    for noise in (0.1, 0.3):
        m, units, val, corpus = _lm_setup(noise=noise, seed=5)
        for method, vm in (("random", False), ("pgm", True)):
            tc = _tc(0.3, val_matching=vm)
            h, t = _train(m, units, val, tc, method)
            sel = h.selections[-1]["indices"] if h.selections else []
            unit_noise = corpus.noisy[: (len(corpus.noisy) // 4) * 4]
            unit_noise = unit_noise.reshape(-1, 4).any(axis=1)
            noi = noise_overlap_index(sel, unit_noise)
            rows.append({
                "name": f"table3/{method}@noise{int(noise*100)}",
                "us_per_call": t * 1e6,
                "derived": f"val={h.val_loss[-1]:.4f};NOI={noi:.2f}",
            })
    return rows


# ---------------------------------------------------------------------------
# Table 4: Overlap Index / Noise Overlap Index across selection rounds
# ---------------------------------------------------------------------------

def bench_table4_overlap() -> List[Row]:
    m, units, val, corpus = _lm_setup(noise=0.2, seed=9)
    rows = []
    for method in ("pgm", "random"):
        tc = _tc(0.3, select_every=1, epochs=max(EPOCHS, 5))
        h, t = _train(m, units, val, tc, method)
        ois = [s["overlap_index"] for s in h.selections[1:]]
        unit_noise = corpus.noisy[: (len(corpus.noisy) // 4) * 4]
        unit_noise = unit_noise.reshape(-1, 4).any(axis=1)
        nois = [noise_overlap_index(s["indices"], unit_noise)
                for s in h.selections]
        rows.append({
            "name": f"table4/{method}", "us_per_call": t * 1e6,
            "derived": (f"OI={np.nanmean(ois):.3f};"
                        f"NOI={np.mean(nois):.3f}"),
        })
    return rows


# ---------------------------------------------------------------------------
# Table 5: warm-start ablation
# ---------------------------------------------------------------------------

def bench_table5_warmstart() -> List[Row]:
    m, units, val, _ = _lm_setup(seed=11)
    rows = []
    for warm in (1, 2, 3):
        h, t = _train(m, units, val, _tc(0.2, warm=warm,
                                         epochs=max(EPOCHS, 5)), "pgm")
        rows.append({
            "name": f"table5/ws{warm}", "us_per_call": t * 1e6,
            "derived": f"val={h.val_loss[-1]:.4f};cost={h.cost_units:.2f}",
        })
    return rows


# ---------------------------------------------------------------------------
# Table 6: learning rate x data-parallel width
# ---------------------------------------------------------------------------

def bench_table6_lr() -> List[Row]:
    m, units, val, _ = _lm_setup(seed=13)
    rows = []
    for n_shards, lr in ((1, 0.5), (2, 0.5), (2, 1.0)):
        tc = _tc(0.3, lr=lr)
        t0 = time.time()
        h = train_with_selection(m, units, tc, method="pgm", val_units=val,
                                 batch_units=n_shards)
        rows.append({
            "name": f"table6/shards{n_shards}-lr{lr}",
            "us_per_call": (time.time() - t0) * 1e6,
            "derived": f"val={h.val_loss[-1]:.4f}",
        })
    return rows


# ---------------------------------------------------------------------------
# Table 7: PGM vs GRAD-MATCHPB (objective gap + quality)
# ---------------------------------------------------------------------------

def bench_table7_pgm_vs_gmpb() -> List[Row]:
    m, units, val, _ = _lm_setup(seed=17)
    rows = []
    for method in ("random", "large_small", "large_only", "gradmatch_pb",
                   "pgm"):
        h, t = _train(m, units, val, _tc(0.3, partitions=4), method)
        rows.append({
            "name": f"table7/{method}", "us_per_call": t * 1e6,
            "derived": f"val={h.val_loss[-1]:.4f};cost={h.cost_units:.2f}",
        })
    # objective-gap check (Appendix A): mean partition error >= full error
    units_dev = {k: jnp.asarray(v) for k, v in units.items()}
    params = m.init_params(jax.random.PRNGKey(0))
    proj = make_proj_for(m, jax.random.PRNGKey(1), 24, 24)
    t0 = time.time()
    g = units_gradients(m, params, units_dev, proj)
    t_g = time.time() - t0
    from repro.core.pgm import partitioned_gm
    selp = partitioned_gm(g, 4, max(int(0.3 * g.shape[0] / 4), 1))
    selg = gradmatch_pb(g, max(int(0.3 * g.shape[0]), 1))
    rows.append({
        "name": "table7/objective-gap", "us_per_call": t_g * 1e6,
        "derived": (f"pgm_mean_part_err={float(selp.errors.mean()):.3e};"
                    f"gmpb_err={float(selg.errors.mean()):.3e}"),
    })
    return rows


ALL_TABLES = [
    bench_fig2_fig3,
    bench_table1_memory,
    bench_table2_scale,
    bench_table3_noise,
    bench_table4_overlap,
    bench_table5_warmstart,
    bench_table6_lr,
    bench_table7_pgm_vs_gmpb,
]
